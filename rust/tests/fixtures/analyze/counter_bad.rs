//! counter-drift fixture: an EngineMetrics with a counter that is
//! aggregated but neither serialized nor documented, and one missing
//! from aggregation entirely. Never compiled — scanned as text.

pub struct EngineMetrics {
    pub completed: u64,
    pub ghost_counter: u64,
    pub unsummed_counter: u64,
}

const SUMMED_KEYS: [&str; 2] = ["completed", "ghost_counter"];

impl EngineMetrics {
    pub fn to_json(&self) -> String {
        obj(&[("completed", self.completed)])
    }
}

pub fn aggregate_stats(all: &[EngineMetrics]) -> u64 {
    all.iter().map(|m| m.completed).sum()
}
