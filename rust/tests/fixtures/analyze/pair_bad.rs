//! pair-discipline fixture: a file that acquires pins but can never
//! release them. Never compiled — scanned as text.

pub fn leaky(tree: &mut Tree, fp: u64) {
    // acquisition with no unpin_path anywhere in this file
    tree.pin_prefix(fp);
    let lease = tree.match_lease(fp); // no release_path either
    drop(lease);
}

fn pin_prefix_helper() {
    // definition-looking name; the call below still counts
}
