//! Cmd-coverage fixture: an enum with a variant nobody handles.
//! Never compiled — scanned as text.

enum Cmd {
    /// Handled below.
    Submit,
    /// Never matched outside the declaration: must be flagged.
    Orphan,
}

pub fn dispatch(c: Cmd) {
    match c {
        Cmd::Submit => {}
        _ => {}
    }
}
