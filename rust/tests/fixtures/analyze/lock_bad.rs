//! lock-order fixture: a nested acquisition that contradicts the
//! declared hierarchy. Never compiled — scanned as text.

// analyze:lock-order: shard_tx < salvaged < journal < outcomes < replicas

pub fn inverted(self_: &Pool) {
    let salvaged = self_.salvaged_lock.lock();
    {
        // acquiring shard_tx while holding salvaged: order violation
        let txs = self_.tx_lock.read();
        drop(txs);
    }
    drop(salvaged);
}
