//! panic-path fixture: every construct the pass must flag, plus the
//! look-alikes it must NOT flag. Never compiled — scanned as text.

pub fn hot(v: &[u32], i: usize) -> u32 {
    let a = v.first().unwrap();
    let b = v.get(i).expect("in range");
    let c = v[i];
    if *a > 10 {
        panic!("a too big");
    }
    match b {
        0 => unreachable!("zero filtered upstream"),
        _ => {}
    }
    *a + b + c
}

pub fn look_alikes(v: &[u32]) -> u32 {
    // none of these may fire:
    let s = "call .unwrap() and panic!(now)"; // inside a string
    // let x = v[9].unwrap();  (commented out)
    let d = v.first().copied().unwrap_or(0);
    let e = v.first().copied().unwrap_or_default();
    assert!(!v.is_empty(), "contract check, allowed");
    let f = v[0]; // literal index, allowed
    let arr: [u32; 2] = [d, e]; // array type/literal, allowed
    let g = &v[1..]; // range slice, allowed
    s.len() as u32 + f + arr[1] + g.len() as u32
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let v = vec![1u32, 2];
        let _ = v[1]; // indexing in tests never fires
        v.first().unwrap();
    }
}
