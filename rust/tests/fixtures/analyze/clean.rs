//! Clean fixture: hot-path-shaped code with none of the flagged
//! constructs. Never compiled — scanned as text.

#![warn(missing_docs)]

/// Sum the first two values, tolerating short input.
pub fn careful(v: &[u32]) -> u32 {
    let a = v.first().copied().unwrap_or(0);
    let b = v.get(1).copied().unwrap_or_default();
    a + b
}

/// Pin, use, and release a prefix — the paired shape.
pub fn paired(tree: &mut Tree, fp: u64) {
    tree.pin_prefix(fp);
    tree.unpin_path(fp);
}
