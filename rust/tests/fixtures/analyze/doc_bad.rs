//! doc-gate fixture: no missing_docs opt-in, an undocumented pub fn,
//! an undocumented struct field, and an undocumented enum variant.
//! Never compiled — scanned as text.

/// Documented: must not be flagged.
pub fn documented() {}

pub fn undocumented() {}

/// The container itself is documented…
pub struct Holder {
    /// …and so is this field.
    pub fine: u64,
    pub bare: u64,
}

/// Documented enum.
pub enum Kind {
    /// Documented variant.
    Fine,
    Bare,
}
