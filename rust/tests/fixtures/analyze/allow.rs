//! Allow-annotation fixture: every finding here is covered by an
//! escape hatch, so the file has findings but zero active ones.
//! Never compiled — scanned as text.

pub fn annotated(v: &[u32], i: usize) -> u32 {
    // analyze:allow(panic_path) caller validated i at the API boundary
    let a = v[i];
    // analyze:allow(panic_path) non-empty checked by caller
    v.first().unwrap() + a
}

// analyze:allow(panic_path, fn) indices come from enumerate() over v itself
pub fn fn_scoped(v: &[u32]) -> u32 {
    let mut total = 0;
    for (i, _) in v.iter().enumerate() {
        total += v[i];
    }
    total
}
