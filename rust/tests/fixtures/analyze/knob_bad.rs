//! knob-drift fixture: a ServerConfig with a field wired to no
//! serving surface at all. Never compiled — scanned as text.

pub struct ServerConfig {
    pub workers: usize,
    pub dead_knob_ms: u64,
}

pub fn from_json(j: &Json) -> ServerConfig {
    ServerConfig {
        workers: j.get("workers").unwrap_or(4),
        dead_knob_ms: 100, // hardcoded: no JSON key loads this field
    }
}
