//! Hot-context replication integration: a read-mostly shared prefix that
//! keeps spilling must stop paying per-spill page copies. One hot
//! workflow bursts parallel agents (shared context, shared adapter, one
//! tag) at an 8-shard pool in sequential waves. During warmup the spills
//! migrate (PR 3) and tally spill-misses; the repeat miss of the hot
//! read-mostly prefix on a shard plants a durable replica there, and
//! later waves route their spills onto verified holders as `replica_hits`
//! — so the per-wave migration count collapses after warmup while the
//! pool's matched-page rate stays within 10% of the same-seed
//! single-shard ceiling (where nothing ever spills).

use std::sync::Arc;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::SimExecutor;
use forkkv::server::Server;
use forkkv::util::json::Json;
use forkkv::util::tokenizer::HashTokenizer;
use forkkv::workload::SkewedWorkflowHttpSpec;

const SHARDS: usize = 8;
const PAGE_TOKENS: usize = 16;
const MAX_NEW: usize = 32;
const HOT_AGENTS: usize = 8;
const STAGGER_MS: u64 = 5;
const WAVES: usize = 3;

/// Shard pool with every supervisor parked (no rebalance, no prefetch,
/// no tier, no journal): the only moving parts are routing, migration
/// and — when armed — replication, so the per-wave counters below are
/// attributable.
fn pool(shards: usize, replicate: bool) -> (Arc<Server>, Vec<std::thread::JoinHandle<()>>) {
    let base = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig {
            page_tokens: PAGE_TOKENS,
            budget_bytes: 128 << 20,
            capacity_bytes: 0,
        },
        ..EngineConfig::default()
    };
    let engines: Vec<Engine> = (0..shards)
        .map(|i| {
            // wall-paced sim: requests overlap in wall time, so the
            // router's depth signal sees the burst and actually spills
            let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8])
                .unwrap()
                .with_wall_pace_us(2_500);
            Engine::new(base.shard_slice(i, shards), Box::new(sim)).unwrap()
        })
        .collect();
    let scfg = ServerConfig {
        migrate: true,
        migration_max_inflight: 8,
        replicate,
        // the detector needs a handful of fork observations before it
        // trusts a prefix; the primer plus the first wave provide them
        replicate_min_forks: 4,
        ..ServerConfig::default()
    };
    Server::start_sharded(engines, scfg)
}

fn spec() -> SkewedWorkflowHttpSpec {
    SkewedWorkflowHttpSpec {
        hot_agents: HOT_AGENTS,
        stagger_ms: STAGGER_MS,
        cold_workflows: 0,
        max_new: MAX_NEW,
        ..SkewedWorkflowHttpSpec::default()
    }
}

/// One staggered hot burst (the same per-agent prompts every wave).
fn run_wave(srv: &Arc<Server>, tok: &HashTokenizer, spec: &SkewedWorkflowHttpSpec) {
    let adapter = SkewedWorkflowHttpSpec::HOT_ADAPTER as u32;
    let mut clients = Vec::new();
    for a in 0..spec.hot_agents {
        let srv = srv.clone();
        let tokens = tok.encode(&spec.hot_prompt(a));
        clients.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(a as u64 * STAGGER_MS));
            srv.generate_tagged(tokens, adapter, MAX_NEW, SkewedWorkflowHttpSpec::HOT_TAG)
                .unwrap();
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
}

fn counter(j: &Json, path: &[&str]) -> f64 {
    j.at(path).as_f64().unwrap_or(0.0)
}

/// Aggregate matched-page rate of a full same-seed run; the single-shard
/// variant is the sharing ceiling (nothing ever spills or recomputes the
/// shared context).
fn matched_rate(shards: usize, replicate: bool) -> f64 {
    let (srv, handles) = pool(shards, replicate);
    let spec = spec();
    let tok = HashTokenizer::new(2048); // sim model vocab
    let adapter = SkewedWorkflowHttpSpec::HOT_ADAPTER as u32;
    let primer = tok.encode(&spec.hot_prompt(spec.hot_agents));
    srv.generate_tagged(primer, adapter, MAX_NEW, SkewedWorkflowHttpSpec::HOT_TAG)
        .unwrap();
    for _ in 0..WAVES {
        run_wave(&srv, &tok, &spec);
    }
    let m = srv.metrics_json().unwrap();
    assert_eq!(
        m.at(&["aggregate", "completed"]).as_usize().unwrap(),
        1 + WAVES * HOT_AGENTS,
        "shards={shards}: every request must complete"
    );
    let rate = counter(&m, &["aggregate", "matched_rate"]);
    srv.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    rate
}

#[test]
fn replication_absorbs_hot_spills_after_warmup() {
    let (srv, handles) = pool(SHARDS, true);
    let spec = spec();
    let tok = HashTokenizer::new(2048); // sim model vocab
    let adapter = SkewedWorkflowHttpSpec::HOT_ADAPTER as u32;

    // primer: runs alone so the home shard has the hot context published
    // (both cache components) before the burst can spill anyone
    let primer = tok.encode(&spec.hot_prompt(spec.hot_agents));
    srv.generate_tagged(primer, adapter, MAX_NEW, SkewedWorkflowHttpSpec::HOT_TAG)
        .unwrap();

    // drive the waves, snapshotting the migration/hit counters between
    // them: warmup waves migrate and plant, later waves hit replicas
    let mut migrations = Vec::new();
    let mut hits = Vec::new();
    for _ in 0..WAVES {
        let m0 = counter(&srv.router_stats(), &["migrations"]);
        let h0 = counter(&srv.replication_stats(), &["replica_hits"]);
        run_wave(&srv, &tok, &spec);
        migrations.push(counter(&srv.router_stats(), &["migrations"]) - m0);
        hits.push(counter(&srv.replication_stats(), &["replica_hits"]) - h0);
    }

    let rep = srv.replication_stats();
    assert_eq!(rep.at(&["enabled"]).as_bool(), Some(true));
    assert!(
        counter(&rep, &["replications"]) > 0.0,
        "the hot prefix never earned a replica: {rep}"
    );
    assert!(
        counter(&rep, &["replica_hits"]) > 0.0,
        "no spill was ever served by a replica holder: {rep}"
    );
    let router = srv.router_stats();
    assert!(
        counter(&router, &["spills"]) > 0.0,
        "the load failed to force a spill: {router}"
    );
    // warmup actually paid migrations...
    let warmup: f64 = migrations[..WAVES - 1].iter().sum();
    assert!(
        warmup > 0.0,
        "warmup waves never migrated (spills missing?): {migrations:?}"
    );
    // ...and the final wave pays (almost) none: its hot spills route to
    // the replicas planted during warmup instead of re-copying pages
    assert!(
        migrations[WAVES - 1] < warmup,
        "hot-context migrations did not collapse after warmup \
         (per-wave migrations {migrations:?}, per-wave hits {hits:?})"
    );
    assert!(
        hits[WAVES - 1] > 0.0,
        "the post-warmup wave hit no replicas \
         (per-wave migrations {migrations:?}, per-wave hits {hits:?})"
    );

    let multi = counter(&srv.metrics_json().unwrap(), &["aggregate", "matched_rate"]);
    srv.shutdown();
    for h in handles {
        h.join().unwrap();
    }

    // the replicated pool shares like a single shard: within 10% of the
    // same-seed single-shard ceiling, where no request ever spills
    let single = matched_rate(1, true);
    assert!(single > 0.0, "single-shard ceiling measured nothing");
    assert!(
        multi >= single * 0.9,
        "replicated matched rate {multi:.3} not within 10% of the \
         single-shard ceiling {single:.3}"
    );
}
