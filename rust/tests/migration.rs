//! Cross-shard page migration integration: a spill must cost bandwidth,
//! not FLOPs. One hot workflow bursts parallel agents (shared context,
//! shared adapter, one tag) at a 4-shard pool: affinity pins them to one
//! home shard until its depth crosses `imbalance_factor` and the later
//! agents spill. With `migrate: true` the spilled agents' cached pages
//! travel ahead of them, so they keep a matched-page rate on par with the
//! home shard and the pool prefills far fewer tokens than with
//! `migrate: false` — both asserted from the `/metrics` payload.

use std::sync::Arc;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::SimExecutor;
use forkkv::router::{RoutePolicy, Router};
use forkkv::server::Server;
use forkkv::util::json::Json;
use forkkv::util::tokenizer::HashTokenizer;
use forkkv::workload::SkewedWorkflowHttpSpec;

const SHARDS: usize = 4;
const PAGE_TOKENS: usize = 16;
const MAX_NEW: usize = 32;
const HOT_AGENTS: usize = 8;
const STAGGER_MS: u64 = 5;

fn pool(migrate: bool) -> (Arc<Server>, Vec<std::thread::JoinHandle<()>>) {
    let base = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: PAGE_TOKENS, budget_bytes: 128 << 20, capacity_bytes: 0 },
        ..EngineConfig::default()
    };
    let engines: Vec<Engine> = (0..SHARDS)
        .map(|i| {
            // wall-paced sim: requests overlap in wall time, so the
            // router's depth signal sees the burst and actually spills
            let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8])
                .unwrap()
                .with_wall_pace_us(2_500);
            Engine::new(base.shard_slice(i, SHARDS), Box::new(sim)).unwrap()
        })
        .collect();
    let scfg = ServerConfig {
        migrate,
        migration_max_inflight: 8,
        ..ServerConfig::default()
    };
    Server::start_sharded(engines, scfg)
}

/// Drive the forced-spill skewed load in-process (same prompts/adapters
/// the HTTP harness sends) and return the `/metrics` payload.
fn run_skewed(migrate: bool) -> Json {
    let (srv, handles) = pool(migrate);
    let spec = SkewedWorkflowHttpSpec {
        hot_agents: HOT_AGENTS,
        stagger_ms: STAGGER_MS,
        cold_workflows: 0,
        max_new: MAX_NEW,
        ..SkewedWorkflowHttpSpec::default()
    };
    let tok = HashTokenizer::new(2048); // sim model vocab
    let adapter = SkewedWorkflowHttpSpec::HOT_ADAPTER as u32;

    // primer: runs alone so the home shard has the hot context published
    // (both cache components) before the burst can spill anyone
    let primer = tok.encode(&spec.hot_prompt(spec.hot_agents));
    srv.generate_tagged(primer, adapter, MAX_NEW, SkewedWorkflowHttpSpec::HOT_TAG)
        .unwrap();

    // the burst: staggered so the home shard's in-flight depth is
    // visible to each successive placement decision
    let mut clients = Vec::new();
    for a in 0..spec.hot_agents {
        let srv = srv.clone();
        let tokens = tok.encode(&spec.hot_prompt(a));
        clients.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(a as u64 * STAGGER_MS));
            srv.generate_tagged(tokens, adapter, MAX_NEW, SkewedWorkflowHttpSpec::HOT_TAG)
                .unwrap();
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    let metrics = srv.metrics_json().unwrap();
    assert_eq!(
        metrics.at(&["aggregate", "completed"]).as_usize().unwrap(),
        1 + spec.hot_agents,
        "migrate={migrate}: every request must complete"
    );
    srv.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    metrics
}

/// The hot context's affinity home: same pure function the server's
/// router computes (policy, shard count, page window and factor all
/// match the pool under test).
fn home_shard(spec: &SkewedWorkflowHttpSpec) -> usize {
    let tok = HashTokenizer::new(2048);
    let tokens = tok.encode(&spec.hot_prompt(0));
    Router::new(RoutePolicy::Affinity, SHARDS, PAGE_TOKENS, 2.0)
        .affinity_shard(&tokens, SkewedWorkflowHttpSpec::HOT_TAG)
}

/// (matched-page rate of the home shard, matched-page rate across every
/// spilled-to shard, total prefilled tokens) from one `/metrics` payload.
fn digest(metrics: &Json) -> (f64, f64, f64) {
    let home = home_shard(&SkewedWorkflowHttpSpec::default());
    let per_shard = metrics.at(&["per_shard"]).as_arr().unwrap();
    assert_eq!(per_shard.len(), SHARDS);
    let matched = |s: &Json| {
        let prompt = s.at(&["prompt_tokens"]).as_f64().unwrap_or(0.0);
        let hit = s.at(&["hit_full_tokens"]).as_f64().unwrap_or(0.0)
            + s.at(&["hit_partial_tokens"]).as_f64().unwrap_or(0.0);
        (hit, prompt)
    };
    let (home_hit, home_prompt) = matched(&per_shard[home]);
    assert!(home_prompt > 0.0, "home shard {home} served nothing");
    let (mut spill_hit, mut spill_prompt) = (0.0, 0.0);
    for (i, s) in per_shard.iter().enumerate() {
        if i != home {
            let (h, p) = matched(s);
            spill_hit += h;
            spill_prompt += p;
        }
    }
    assert!(
        spill_prompt > 0.0,
        "no request spilled off the home shard — the load failed to force a spill \
         (per_shard: {per_shard:?})"
    );
    let computed = metrics
        .at(&["aggregate", "computed_prompt_tokens"])
        .as_f64()
        .unwrap();
    (home_hit / home_prompt, spill_hit / spill_prompt, computed)
}

#[test]
fn migration_keeps_spilled_matched_rate_and_cuts_prefill() {
    let on = run_skewed(true);
    let off = run_skewed(false);

    // migration actually ran and moved real pages
    let migrated = on.at(&["aggregate", "migrated_pages"]).as_f64().unwrap();
    let saved = on
        .at(&["aggregate", "recompute_tokens_saved"])
        .as_f64()
        .unwrap();
    assert!(migrated > 0.0, "no pages migrated: {on:?}");
    assert!(saved > 0.0, "no recompute saved: {on:?}");
    assert!(on.at(&["aggregate", "migrated_bytes"]).as_f64().unwrap() > 0.0);
    assert!(on.at(&["router", "spills"]).as_f64().unwrap() > 0.0);
    assert!(on.at(&["router", "migrations"]).as_f64().unwrap() > 0.0);

    // with migration off, spills exist but nothing moves
    assert_eq!(off.at(&["aggregate", "migrated_pages"]).as_f64().unwrap(), 0.0);
    assert!(off.at(&["router", "spills"]).as_f64().unwrap() > 0.0);
    assert_eq!(off.at(&["router", "migrations"]).as_f64().unwrap(), 0.0);

    // spilled requests match like home requests once their pages follow
    let (home_rate, spill_rate, computed_on) = digest(&on);
    assert!(
        spill_rate >= home_rate * 0.9,
        "spilled matched-page rate {spill_rate:.3} not within 10% of home rate \
         {home_rate:.3}: {on:?}"
    );

    // and the pool prefills measurably fewer tokens than recompute
    let (_, off_spill_rate, computed_off) = digest(&off);
    assert!(
        computed_on < computed_off,
        "migration did not reduce prefilled tokens: {computed_on} vs {computed_off}"
    );
    // sanity on the baseline: without migration the spilled requests
    // recompute cold (their matched rate collapses)
    assert!(
        off_spill_rate < spill_rate,
        "migrate off should not match like migrate on \
         ({off_spill_rate:.3} vs {spill_rate:.3})"
    );
}
