//! Real-mode engine integration: the full L3 stack over real PJRT
//! artifacts, verifying (a) policy equivalence where the math says they
//! must agree, (b) cross-adapter fork correctness, and (c) determinism of
//! the incremental decode-batch assembly against a cold engine.
//!
//! Skips cleanly when `make artifacts` has not run.

use std::path::{Path, PathBuf};

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::{Engine, Request, Tick};
use forkkv::exec::PjrtExecutor;
use forkkv::metrics::FinishedRequest;
use forkkv::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    // without the `pjrt` feature the runtime cannot load artifacts even
    // when they exist on disk — skip rather than fail
    if !cfg!(feature = "pjrt") {
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/llama3-8b-sim");
    dir.join("manifest.json").exists().then_some(dir)
}

fn engine(policy: CachePolicy, budget_mb: usize) -> Option<Engine> {
    let dir = artifacts_dir()?;
    let exec = PjrtExecutor::load(&dir).expect("load artifacts");
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20, capacity_bytes: 0 },
        seed: 3,
        ..EngineConfig::default()
    };
    Some(Engine::new(cfg, Box::new(exec)).expect("engine"))
}

fn drive(e: &mut Engine, n: usize) -> Vec<FinishedRequest> {
    let mut fin = Vec::new();
    while fin.len() < n {
        match e.tick().expect("tick") {
            Tick::Progress => fin.extend(e.drain_finished()),
            Tick::Idle => break,
        }
    }
    fin.sort_by_key(|f| f.id);
    fin
}

fn submit_stream(e: &mut Engine, shared: &[u32], n: usize, same_adapter: bool) {
    for i in 0..n {
        let mut tokens = shared.to_vec();
        tokens.extend(Rng::seeded(50 + i as u64).tokens(6, 2048));
        e.submit(Request {
            id: i as u64,
            tag: 0,
            adapter: if same_adapter { 1 } else { 1 + (i % 3) as u32 },
            tokens,
            max_new: 10,
            arrival_us: i as u64,
            ignore_eos: true,
            fan: 0,
        });
    }
}

/// Same adapter + same prefix: ForkKV's reconstruction is mathematically
/// exact (RoPE linearity), so its outputs must match lossless prefix
/// caching token-for-token.
#[test]
fn forkkv_equals_prefix_caching_for_same_adapter() {
    let Some(_) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let shared = Rng::seeded(9).tokens(180, 2048);
    let run = |policy| {
        let mut e = engine(policy, 64).unwrap();
        submit_stream(&mut e, &shared, 4, true);
        let fin = drive(&mut e, 4);
        e.check_quiescent().unwrap();
        fin.iter().map(|f| f.generated.clone()).collect::<Vec<_>>()
    };
    let fork = run(CachePolicy::Disaggregated);
    let prefix = run(CachePolicy::UnifiedPerAdapter);
    assert_eq!(fork.len(), 4);
    let mut agree = 0;
    let mut total = 0;
    for (a, b) in fork.iter().zip(prefix.iter()) {
        for (x, y) in a.iter().zip(b.iter()) {
            total += 1;
            agree += usize::from(x == y);
        }
    }
    // same-adapter reuse is exact; tiny drift can appear only through
    // f32 re-association in different batch shapes
    assert!(
        agree as f64 / total as f64 > 0.95,
        "same-adapter forkkv must match lossless baseline: {agree}/{total}"
    );
}

/// Cross-adapter streams: ForkKV inherits bCache (partial hits > 0)
/// while the unified baseline shares nothing.
#[test]
fn cross_adapter_inheritance_happens_on_the_real_path() {
    let Some(_) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let shared = Rng::seeded(10).tokens(160, 2048);

    let mut fork = engine(CachePolicy::Disaggregated, 64).unwrap();
    submit_stream(&mut fork, &shared, 4, false);
    let fin = drive(&mut fork, 4);
    assert_eq!(fin.len(), 4);
    let partial: usize = fin.iter().map(|f| f.hit_partial).sum();
    assert!(partial > 300, "expected bCache inheritance, got {partial}");

    let mut unified = engine(CachePolicy::UnifiedPerAdapter, 64).unwrap();
    submit_stream(&mut unified, &shared, 4, false);
    let fin_u = drive(&mut unified, 4);
    let shared_u: usize = fin_u.iter().map(|f| f.hit_partial + f.hit_full).sum();
    assert!(
        shared_u < partial,
        "unified must share less cross-adapter ({shared_u} vs {partial})"
    );
    fork.check_quiescent().unwrap();
    unified.check_quiescent().unwrap();
}

/// Full-reuse inherits everything cross-adapter (maximum sharing, lossy).
#[test]
fn full_reuse_shares_everything_on_the_real_path() {
    let Some(_) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let shared = Rng::seeded(11).tokens(160, 2048);
    let mut e = engine(CachePolicy::FullReuse, 64).unwrap();
    submit_stream(&mut e, &shared, 3, false);
    let fin = drive(&mut e, 3);
    // requests 2 and 3 fully hit request 1's merged cache
    let hits: Vec<usize> = fin.iter().map(|f| f.hit_full).collect();
    assert_eq!(hits[0], 0);
    assert!(hits[1] >= 144 && hits[2] >= 144, "{hits:?}");
}

/// The incremental decode-batch assembly must not change results:
/// running requests concurrently (stable batch, incremental path) vs
/// strictly sequentially (cold batches every time) yields the same tokens.
#[test]
fn incremental_batch_assembly_is_lossless() {
    let Some(_) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let shared = Rng::seeded(12).tokens(120, 2048);

    // concurrent: all arrive at once -> stable decode batch of 3
    let mut conc = engine(CachePolicy::Disaggregated, 64).unwrap();
    submit_stream(&mut conc, &shared, 3, false);
    let fin_c = drive(&mut conc, 3);

    // sequential: one at a time -> batch of 1, no incremental reuse
    let mut seq = engine(CachePolicy::Disaggregated, 64).unwrap();
    let mut fin_s = Vec::new();
    for i in 0..3u64 {
        let mut tokens = shared.clone();
        tokens.extend(Rng::seeded(50 + i).tokens(6, 2048));
        seq.submit(Request {
            id: i,
            tag: 0,
            adapter: 1 + (i % 3) as u32,
            tokens,
            max_new: 10,
            arrival_us: seq.now_us(),
            ignore_eos: true,
            fan: 0,
        });
        fin_s.extend(drive(&mut seq, 1));
    }
    fin_s.sort_by_key(|f| f.id);

    for (c, s) in fin_c.iter().zip(fin_s.iter()) {
        assert_eq!(c.id, s.id);
        // sequential mode sees more published cache (prior requests done),
        // so hits differ; generated tokens must still agree at the start,
        // where both attend over identical state
        assert_eq!(
            c.generated[0], s.generated[0],
            "first generated token must be batch-size independent"
        );
    }
}
