//! Fixture tests for the `forkkv analyze` invariant passes: each pass
//! must fire on its bad fixture, stay quiet on the clean one, and
//! honor the `analyze:allow` escape hatch — plus a self-test that the
//! real tree has zero active findings (the same gate CI enforces).

use forkkv::analysis::{self, passes};

const PANIC_BAD: &str = include_str!("fixtures/analyze/panic_bad.rs");
const PAIR_BAD: &str = include_str!("fixtures/analyze/pair_bad.rs");
const CMD_BAD: &str = include_str!("fixtures/analyze/cmd_bad.rs");
const LOCK_BAD: &str = include_str!("fixtures/analyze/lock_bad.rs");
const COUNTER_BAD: &str = include_str!("fixtures/analyze/counter_bad.rs");
const KNOB_BAD: &str = include_str!("fixtures/analyze/knob_bad.rs");
const DOC_BAD: &str = include_str!("fixtures/analyze/doc_bad.rs");
const CLEAN: &str = include_str!("fixtures/analyze/clean.rs");
const ALLOW: &str = include_str!("fixtures/analyze/allow.rs");

#[test]
fn panic_path_fires_on_bad_fixture() {
    let fs = passes::panic_path("panic_bad.rs", PANIC_BAD);
    let msgs: Vec<&str> = fs.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains(".unwrap()")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains(".expect(")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("panic!")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("unreachable!")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("indexing [i]")), "{msgs:?}");
    assert_eq!(fs.len(), 5, "look-alikes must not fire: {msgs:?}");
    assert!(fs.iter().all(|f| !f.allowed));
    assert!(fs.iter().all(|f| f.line > 0));
}

#[test]
fn pair_discipline_fires_on_unreleased_acquisitions() {
    let fs = passes::pair_discipline("pair_bad.rs", PAIR_BAD);
    assert!(
        fs.iter().any(|f| f.message.contains("pin_prefix")),
        "missing pin_prefix finding"
    );
    assert!(
        fs.iter().any(|f| f.message.contains("match_lease")),
        "missing match_lease finding"
    );
    assert_eq!(fs.len(), 2);
}

#[test]
fn cmd_coverage_flags_unhandled_variant() {
    let fs = passes::cmd_coverage("cmd_bad.rs", CMD_BAD);
    assert_eq!(fs.len(), 1);
    assert!(fs[0].message.contains("Cmd::Orphan"), "{}", fs[0].message);
}

#[test]
fn lock_order_flags_declaration_violation() {
    let fs = passes::lock_order("lock_bad.rs", LOCK_BAD);
    assert_eq!(fs.len(), 1, "{:?}", fs.iter().map(|f| &f.message).collect::<Vec<_>>());
    assert!(
        fs[0].message.contains("salvaged -> shard_tx"),
        "{}",
        fs[0].message
    );
}

#[test]
fn lock_order_requires_a_declaration() {
    let fs = passes::lock_order("no_decl.rs", "pub fn f() {}\n");
    assert!(fs.iter().any(|f| f.message.contains("no analyze:lock-order")));
}

#[test]
fn counter_drift_flags_missing_legs() {
    let docs = "| `completed` | total completed |\n";
    let fs = passes::counter_drift("counter_bad.rs", COUNTER_BAD, docs);
    let for_field = |name: &str| {
        fs.iter()
            .filter(|f| f.message.contains(&format!("`{name}`")))
            .count()
    };
    assert_eq!(for_field("completed"), 0, "fully-wired counter must pass");
    // ghost_counter: summed but not serialized, not documented
    assert_eq!(for_field("ghost_counter"), 2);
    // unsummed_counter: missing all three legs
    assert_eq!(for_field("unsummed_counter"), 3);
}

#[test]
fn knob_drift_flags_dead_knob() {
    let main_src = "--workers";
    let readme = "| `workers` | 4 | worker threads |";
    let fs = passes::knob_drift("knob_bad.rs", KNOB_BAD, main_src, readme);
    assert!(fs.iter().all(|f| f.message.contains("dead_knob_ms")), "workers is fully wired");
    assert_eq!(fs.len(), 3, "dead_knob_ms must miss all three surfaces");
}

#[test]
fn doc_gate_flags_missing_docs() {
    let fs = passes::doc_gate("doc_bad.rs", DOC_BAD);
    let msgs: Vec<&str> = fs.iter().map(|f| f.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("missing #![warn(missing_docs)]")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("undocumented pub fn undocumented")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("Holder::bare")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("Kind::Bare")), "{msgs:?}");
    assert_eq!(fs.len(), 4, "documented items must not fire: {msgs:?}");
}

#[test]
fn clean_fixture_has_no_findings() {
    assert!(passes::panic_path("clean.rs", CLEAN).is_empty());
    assert!(passes::pair_discipline("clean.rs", CLEAN).is_empty());
    assert!(passes::doc_gate("clean.rs", CLEAN).is_empty());
}

#[test]
fn allow_annotations_suppress_without_hiding() {
    let fs = passes::panic_path("allow.rs", ALLOW);
    assert_eq!(fs.len(), 3, "annotated findings are still reported");
    assert!(fs.iter().all(|f| f.allowed), "…but every one is allowed");
}

#[test]
fn report_json_is_parseable_and_counts_active() {
    let report = analysis::Report {
        findings: passes::panic_path("allow.rs", ALLOW)
            .into_iter()
            .chain(passes::panic_path("panic_bad.rs", PANIC_BAD))
            .collect(),
    };
    assert_eq!(report.active(), 5);
    let parsed = forkkv::util::json::parse(&report.to_json()).expect("valid JSON");
    assert_eq!(parsed.at(&["active"]).as_usize(), Some(5));
    assert_eq!(
        parsed.get("findings").and_then(|f| f.as_arr()).map(|a| a.len()),
        Some(8)
    );
}

#[test]
fn real_tree_has_zero_active_findings() {
    let root = analysis::find_root(std::path::Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("crate root");
    let report = analysis::run(&root, &[]);
    let active: Vec<String> = report
        .findings
        .iter()
        .filter(|f| !f.allowed)
        .map(|f| format!("{}:{}: {}", f.file, f.line, f.message))
        .collect();
    assert!(active.is_empty(), "active findings:\n{}", active.join("\n"));
}
