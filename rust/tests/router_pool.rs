//! Pool-level routing integration: the engine shard pool must turn
//! prefix-affinity placement into cache reuse. K workflows of M agents
//! each fork a large per-workflow shared context; under `affinity` every
//! agent lands on the shard already holding its workflow's bCache pages,
//! under `round_robin` the agents scatter and every shard recomputes the
//! context — so the pool's matched-page rate must be strictly higher with
//! affinity. Requests run sequentially per workflow (the ReAct shape), so
//! the comparison is fully deterministic.

use std::sync::Arc;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::SimExecutor;
use forkkv::router::RoutePolicy;
use forkkv::server::Server;
use forkkv::util::tokenizer::HashTokenizer;
use forkkv::workload::{multi_workflow_prompt, MultiWorkflowHttpSpec};

const SHARDS: usize = 4;

fn pool(route: RoutePolicy) -> (Arc<Server>, Vec<std::thread::JoinHandle<()>>) {
    // one logical budget split across the shards, exactly as `forkkv
    // serve --shards 4` builds the pool
    let base = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 128 << 20, capacity_bytes: 0 },
        ..EngineConfig::default()
    };
    let engines: Vec<Engine> = (0..SHARDS)
        .map(|i| {
            let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
            Engine::new(base.shard_slice(i, SHARDS), Box::new(sim)).unwrap()
        })
        .collect();
    let scfg = ServerConfig { route_policy: route, ..ServerConfig::default() };
    Server::start_sharded(engines, scfg)
}

/// Drive the multi-workflow scenario in-process (same prompts the HTTP
/// harness sends) and return (matched_rate, per-shard completed counts).
fn run_scenario(route: RoutePolicy) -> (f64, Vec<usize>) {
    let (srv, handles) = pool(route);
    let spec = MultiWorkflowHttpSpec {
        workflows: 6,
        agents_per_workflow: 4,
        shared_words: 160,
        unique_words: 4,
        max_new: 8,
        parallel: false,
    };
    let tok = HashTokenizer::new(2048); // sim model vocab
    for w in 0..spec.workflows {
        for a in 0..spec.agents_per_workflow {
            let tokens = tok.encode(&multi_workflow_prompt(&spec, w, a));
            let adapter = (w * spec.agents_per_workflow + a) as u32;
            // 1-based tags, matching the HTTP harness (tag 0 = untagged)
            srv.generate_tagged(tokens, adapter, spec.max_new, w as u64 + 1)
                .unwrap();
        }
    }
    let per_shard: Vec<usize> = srv
        .shard_stats()
        .unwrap()
        .iter()
        .map(|s| s.at(&["completed"]).as_usize().unwrap())
        .collect();
    let stats = srv.stats().unwrap();
    let matched = stats.at(&["matched_rate"]).as_f64().unwrap();
    assert_eq!(
        stats.at(&["completed"]).as_usize().unwrap(),
        spec.workflows * spec.agents_per_workflow,
        "{route:?}: every request must complete"
    );
    srv.shutdown();
    for h in handles {
        h.join().unwrap();
    }
    (matched, per_shard)
}

#[test]
fn affinity_beats_round_robin_on_matched_page_rate() {
    let (affinity, affinity_shards) = run_scenario(RoutePolicy::Affinity);
    let (round_robin, rr_shards) = run_scenario(RoutePolicy::RoundRobin);
    // round-robin spreads the load evenly but severs the workflows from
    // their cached contexts; affinity keeps each workflow whole
    assert!(
        affinity > round_robin + 0.3,
        "affinity matched rate {affinity:.3} not clearly above round-robin \
         {round_robin:.3} (shards: affinity {affinity_shards:?}, rr {rr_shards:?})"
    );
    // absolute sanity on both sides: agents 2..M share ~160 of ~164 prompt
    // tokens with their workflow under affinity; scattered agents share
    // (almost) nothing
    assert!(affinity > 0.5, "affinity matched rate too low: {affinity:.3}");
    assert!(round_robin < 0.2, "round-robin unexpectedly matched: {round_robin:.3}");
    // round-robin must have used every shard (it's the load-spread
    // baseline — if it didn't, the comparison above proves nothing)
    assert!(
        rr_shards.iter().all(|&c| c > 0),
        "round-robin left shards idle: {rr_shards:?}"
    );
}
