//! Cross-language numerics contract: replay the python-side golden run
//! (python/compile/aot.py::make_golden) through the Rust PJRT runtime and
//! require matching values. This is the proof that the AOT bridge — HLO
//! text, weight upload, argument order, cache layouts — is faithful.
//!
//! Requires `make artifacts` (skips cleanly when artifacts are absent so
//! `cargo test` stays green on a fresh checkout).

use std::path::{Path, PathBuf};

use forkkv::runtime::{DecodeArgs, PjrtRuntime, PrefillArgs};
use forkkv::util::json::{self, Json};

fn artifacts_dir() -> Option<PathBuf> {
    // without the `pjrt` feature the runtime cannot load artifacts even
    // when they exist on disk — skip rather than fail
    if !cfg!(feature = "pjrt") {
        return None;
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/llama3-8b-sim");
    dir.join("manifest.json").exists().then_some(dir)
}

fn approx(a: &[f32], b: &[f64], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            (x - y as f32).abs() <= tol + tol * (y as f32).abs(),
            "{what}[{i}]: rust {x} vs python {y}"
        );
    }
}

fn f64s(j: &Json) -> Vec<f64> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

#[test]
fn golden_prefill_and_decode_match_python() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("load runtime");
    let m = rt.meta().clone();
    let golden = json::parse(
        &std::fs::read_to_string(dir.join("golden.json")).expect("golden.json"),
    )
    .expect("parse golden");

    let tokens: Vec<u32> = golden
        .req_arr("tokens")
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(tokens.len(), m.chunk);
    let adapter = golden.req_usize("adapter_id").unwrap() as u32;
    let n_keep = golden.req_usize("n_keep").unwrap();

    // ---- prefill an empty cache ----
    let (l, s) = (m.n_layers, m.s_max);
    let (kvw, r) = (m.kv_width(), m.rank_max);
    let kb = vec![0.0f32; l * s * kvw];
    let vb = kb.clone();
    let kr = vec![0.0f32; l * s * r];
    let vr = kr.clone();
    let out = rt
        .prefill(&PrefillArgs {
            tokens: &tokens,
            cache_len: 0,
            adapter_id: adapter,
            adapter_on: true,
            kb: &kb,
            vb: &vb,
            kr: &kr,
            vr: &vr,
        })
        .expect("prefill");

    let last8 = &out.logits[(m.chunk - 1) * m.vocab..(m.chunk - 1) * m.vocab + 8];
    approx(last8, &f64s(golden.at(&["prefill_logits_last8"])), 2e-4, "logits");
    approx(&out.kb[..8], &f64s(golden.at(&["prefill_kb_l0"])), 2e-4, "kb");
    approx(&out.kr[..8], &f64s(golden.at(&["prefill_kr_l0"])), 2e-4, "kr");
    approx(&out.km[..8], &f64s(golden.at(&["prefill_km_l0"])), 2e-4, "km");

    // ---- write the first n_keep chunk tokens into the cache slabs ----
    let mut kb2 = kb.clone();
    let mut vb2 = vb.clone();
    let mut kr2 = kr.clone();
    let mut vr2 = vr.clone();
    for li in 0..l {
        for t in 0..n_keep {
            let src = (li * m.chunk + t) * kvw;
            let dst = (li * s + t) * kvw;
            kb2[dst..dst + kvw].copy_from_slice(&out.kb[src..src + kvw]);
            vb2[dst..dst + kvw].copy_from_slice(&out.vb[src..src + kvw]);
            let src_r = (li * m.chunk + t) * r;
            let dst_r = (li * s + t) * r;
            kr2[dst_r..dst_r + r].copy_from_slice(&out.kr[src_r..src_r + r]);
            vr2[dst_r..dst_r + r].copy_from_slice(&out.vr[src_r..src_r + r]);
        }
    }

    // ---- one decode step (batch bucket 2, row 0 live, row 1 inert) ----
    let bucket = 2usize;
    let tok = golden.req_usize("decode_token").unwrap() as u32;
    let mut bkb = vec![0.0f32; bucket * l * s * kvw];
    let mut bvb = bkb.clone();
    let mut bkr = vec![0.0f32; bucket * l * s * r];
    let mut bvr = bkr.clone();
    bkb[..l * s * kvw].copy_from_slice(&kb2);
    bvb[..l * s * kvw].copy_from_slice(&vb2);
    bkr[..l * s * r].copy_from_slice(&kr2);
    bvr[..l * s * r].copy_from_slice(&vr2);
    let dec = rt
        .decode(
            bucket,
            &DecodeArgs {
                tokens: &[tok, 0],
                cache_lens: &[n_keep, 0],
                adapter_ids: &[adapter, 0],
                adapter_on: &[true, false],
                kb: &bkb,
                vb: &bvb,
                kr: &bkr,
                vr: &bvr,
            },
        )
        .expect("decode");

    approx(
        &dec.logits[..8],
        &f64s(golden.at(&["decode_logits8"])),
        2e-4,
        "decode logits",
    );
    let am = forkkv::runtime::argmax(&dec.logits[..m.vocab]);
    assert_eq!(am as usize, golden.req_usize("decode_argmax").unwrap());
}

#[test]
fn decode_buckets_agree_with_each_other() {
    // the same row must produce identical logits regardless of bucket size
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = PjrtRuntime::load(&dir).expect("load runtime");
    let m = rt.meta().clone();
    let (l, s) = (m.n_layers, m.s_max);
    let (kvw, r) = (m.kv_width(), m.rank_max);

    // build a 5-token cache via prefill
    let tokens: Vec<u32> = (0..5).map(|i| 10 + i as u32).collect();
    let kb = vec![0.0f32; l * s * kvw];
    let kr = vec![0.0f32; l * s * r];
    let out = rt
        .prefill(&PrefillArgs {
            tokens: &tokens,
            cache_len: 0,
            adapter_id: 1,
            adapter_on: true,
            kb: &kb,
            vb: &kb.clone(),
            kr: &kr,
            vr: &kr.clone(),
        })
        .unwrap();
    let mut kb2 = kb.clone();
    let mut vb2 = kb.clone();
    let mut kr2 = kr.clone();
    let mut vr2 = kr.clone();
    for li in 0..l {
        for t in 0..5 {
            let (srcb, dstb) = ((li * m.chunk + t) * kvw, (li * s + t) * kvw);
            kb2[dstb..dstb + kvw].copy_from_slice(&out.kb[srcb..srcb + kvw]);
            vb2[dstb..dstb + kvw].copy_from_slice(&out.vb[srcb..srcb + kvw]);
            let (srcr, dstr) = ((li * m.chunk + t) * r, (li * s + t) * r);
            kr2[dstr..dstr + r].copy_from_slice(&out.kr[srcr..srcr + r]);
            vr2[dstr..dstr + r].copy_from_slice(&out.vr[srcr..srcr + r]);
        }
    }

    let mut per_bucket: Vec<Vec<f32>> = Vec::new();
    for &bucket in &[1usize, 4] {
        let mut bkb = vec![0.0f32; bucket * l * s * kvw];
        let mut bvb = bkb.clone();
        let mut bkr = vec![0.0f32; bucket * l * s * r];
        let mut bvr = bkr.clone();
        bkb[..l * s * kvw].copy_from_slice(&kb2);
        bvb[..l * s * kvw].copy_from_slice(&vb2);
        bkr[..l * s * r].copy_from_slice(&kr2);
        bvr[..l * s * r].copy_from_slice(&vr2);
        let mut toks = vec![0u32; bucket];
        toks[0] = 42;
        let mut lens = vec![0usize; bucket];
        lens[0] = 5;
        let mut ids = vec![0u32; bucket];
        ids[0] = 1;
        let mut on = vec![false; bucket];
        on[0] = true;
        let dec = rt
            .decode(
                bucket,
                &DecodeArgs {
                    tokens: &toks,
                    cache_lens: &lens,
                    adapter_ids: &ids,
                    adapter_on: &on,
                    kb: &bkb,
                    vb: &bvb,
                    kr: &bkr,
                    vr: &bvr,
                },
            )
            .unwrap();
        per_bucket.push(dec.logits[..m.vocab].to_vec());
    }
    for (a, b) in per_bucket[0].iter().zip(per_bucket[1].iter()) {
        assert!((a - b).abs() < 1e-4, "bucket-size dependence: {a} vs {b}");
    }
}
