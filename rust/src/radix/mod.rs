//! DualRadixTree: the paper's tree-structured cache with fork semantics
//! (§5.2).
//!
//! Each tree is a page-aligned radix trie: every node owns exactly one pool
//! page (`page_tokens` tokens) and the edge to its parent is that page's
//! token span. Page alignment keeps tree granularity identical to allocator
//! granularity (the same choice vLLM v1 makes for prefix caching); variable-
//! length edges à la SGLang would change only constants, not behaviour.
//!
//! Namespaces realize the paper's two key schemes without duplicating code:
//!   - the **base tree** keys purely by token ids (`ns = 0`): any agent with
//!     the same context hits the same bCache pages (zero-copy sharing);
//!   - the **residual tree** keys by `(adapter_id, token ids)` (`ns =
//!     adapter`), isolating each agent's CoW rCache footprint.
//! The unified baselines reuse the same structure: per-adapter prefix
//! caching keys its monolithic pages by `(adapter, tokens)`; full-reuse
//! keys by tokens only.
//!
//! Fork with CoW (paper Fig. 9): `match_lease` is Step 1 (prefix match +
//! inherit shared pages, pinned by a lease and pool-retained for the
//! sequence); the engine's fresh-page allocation for the residual tail is
//! Step 2. Eviction is **decoupled** (paper §5.2): each tree runs its own
//! LRU over unpinned leaves, so evicting a massive bCache node never
//! cascades into the surviving rCache (partial hits) and vice versa.
//!
//! Workflow-aware eviction (KVFlow-style steps-to-use): on top of the
//! hard `leases` (pages held by *running* sequences — never evictable),
//! nodes carry soft **pins** ([`RadixTree::pin_prefix`]) placed by the
//! scheduler for *queued* forks that will need the prefix shortly. The
//! LRU defers pinned nodes to a second pass (`stats.deferred_evictions`
//! counts the deferrals), so a workflow's parent pages survive until its
//! last queued fork has admitted — but under terminal pressure the
//! second pass may still take them, so pinning can never leak budget or
//! deadlock allocation.

#![warn(missing_docs)]

use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::kvcache::{BlockPool, PageId};

type NodeId = u32;
const NIL: NodeId = u32::MAX;

/// Compact the lazy LRU heap past this many entries per live node.
const LRU_COMPACT_FACTOR: usize = 4;
/// Never compact below this heap size (tiny trees churn cheaply).
const LRU_COMPACT_FLOOR: usize = 64;

/// Demotion sink handed to the eviction passes: called once per victim
/// page just before its memory is freed, with the victim's namespace,
/// its full root-to-node token path, and the page's floats. The engine
/// uses this to hand evicted bytes to the host-memory tier (tier module)
/// instead of destroying them. Victims are always unleased leaves whose
/// page only the tree references, so the snapshot can never observe a
/// running sequence's state.
pub type DemoteSink<'a> = &'a mut dyn FnMut(u32, &[u32], &[f32]);

/// A pinned prefix as returned by [`RadixTree::pin_prefix`]: one
/// `(node, epoch)` pair per pinned page, in path order. The epoch makes
/// a stale unpin safe: if a pinned node was force-evicted (second-pass
/// eviction) and its slot recycled, the epoch no longer matches and
/// [`RadixTree::unpin_path`] skips it instead of corrupting the fresh
/// node's pin count.
pub type PinPath = Vec<(u32, u32)>;

#[derive(Debug)]
struct Node {
    /// token span of the edge from the parent (page_tokens ids)
    key: Box<[u32]>,
    page: PageId,
    parent: NodeId,
    /// namespace this node lives under (root's namespace); the demote
    /// sink needs it to key the tier record
    ns: u32,
    children: HashMap<Box<[u32]>, NodeId>,
    last_access: u64,
    /// active sequences currently holding this node's page via match_lease
    leases: u32,
    /// queued forks that declared they still need this prefix (soft:
    /// defers eviction to the second pass, never forbids it)
    pins: u32,
    /// bumped every time this node slot is killed, so recycled slots
    /// invalidate stale [`PinPath`] entries
    epoch: u32,
    dead: bool,
}

/// Counters describing one tree's lifetime activity (insert/match/evict
/// traffic); snapshot via [`RadixTree::stats`].
#[derive(Debug, Default, Clone)]
pub struct TreeStats {
    /// live nodes (== pages currently owned by the tree)
    pub nodes: usize,
    /// pages newly adopted by `insert` over the tree's lifetime
    pub inserted_pages: u64,
    /// insert chunks that were already present (sharing wins)
    pub deduped_pages: u64,
    /// pages whose memory eviction actually freed
    pub evicted_pages: u64,
    /// `match_lease` calls served
    pub match_queries: u64,
    /// pages returned across all `match_lease` calls
    pub matched_pages: u64,
    /// eviction candidates skipped (to the second pass) because a queued
    /// workflow fork had pinned them — the workflow-aware eviction signal
    pub deferred_evictions: u64,
}

/// Result of the fork's Step-1 prefix match. Pages are pool-retained for
/// the caller; `path` must be given back via `release_path` when the
/// sequence stops using the prefix.
#[derive(Debug, Default)]
pub struct MatchResult {
    /// matched pages in path order, pool-retained for the caller
    pub pages: Vec<PageId>,
    /// matched coverage in tokens (always page aligned)
    pub tokens: usize,
    /// leased node path; give back via [`RadixTree::release_path`]
    pub path: Vec<NodeId>,
}

/// One page-aligned radix trie over token sequences (see module docs):
/// the storage half of the paper's bCache or rCache, with leases, soft
/// workflow pins, and a lazy-heap LRU eviction policy.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Node>,
    free_nodes: Vec<NodeId>,
    roots: HashMap<u32, NodeId>,
    page_tokens: usize,
    clock: u64,
    /// lazy min-heap of (last_access, node) eviction candidates
    lru: BinaryHeap<std::cmp::Reverse<(u64, NodeId)>>,
    stats: TreeStats,
}

impl RadixTree {
    /// Empty tree over pages of `page_tokens` tokens each.
    pub fn new(page_tokens: usize) -> Self {
        assert!(page_tokens > 0);
        RadixTree {
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: HashMap::new(),
            page_tokens,
            clock: 0,
            lru: BinaryHeap::new(),
            stats: TreeStats::default(),
        }
    }

    /// Page granularity of this tree (tokens per node edge).
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Lifetime activity counters (see [`TreeStats`]).
    pub fn stats(&self) -> &TreeStats {
        &self.stats
    }

    /// Total pages currently owned by the tree.
    pub fn total_pages(&self) -> usize {
        self.stats.nodes
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// All LRU heap pushes go through here so the lazy heap stays
    /// bounded: repeated access cycles leave duplicate and stale
    /// `(stamp, node)` entries that are otherwise only filtered at pop
    /// time, growing the heap without bound under an access-heavy loop.
    /// Past ~4x the node count the heap is rebuilt keeping one entry per
    /// still-evictable node.
    fn lru_push(&mut self, stamp: u64, id: NodeId) {
        self.lru.push(std::cmp::Reverse((stamp, id)));
        if self.lru.len() > (self.stats.nodes * LRU_COMPACT_FACTOR).max(LRU_COMPACT_FLOOR) {
            self.compact_lru();
        }
    }

    /// Rebuild the LRU heap with one entry per currently evictable node
    /// (alive, unleased, leaf), stamped with its *current* last_access.
    /// Refreshing the stamp matters: a deduping re-insert bumps
    /// last_access without pushing a new heap entry, so dropping the
    /// stale entry outright would strand the node unevictable forever.
    fn compact_lru(&mut self) {
        let old = std::mem::take(&mut self.lru);
        let mut seen = std::collections::HashSet::with_capacity(self.stats.nodes);
        for std::cmp::Reverse((_stamp, id)) in old {
            let node = &self.nodes[id as usize];
            if node.dead || node.leases > 0 || !node.children.is_empty() || !seen.insert(id) {
                continue;
            }
            self.lru.push(std::cmp::Reverse((node.last_access, id)));
        }
    }

    fn alloc_node(&mut self, mut node: Node) -> NodeId {
        if let Some(id) = self.free_nodes.pop() {
            // recycled slots keep their epoch so stale PinPath entries
            // from the previous occupant never match the new node
            node.epoch = self.nodes[id as usize].epoch;
            self.nodes[id as usize] = node;
            id
        } else {
            self.nodes.push(node);
            (self.nodes.len() - 1) as NodeId
        }
    }

    /// Step 1 of fork: longest-prefix match. Every matched node's page is
    /// `retain`ed on `pool` for the caller and leased in the tree.
    pub fn match_lease(
        &mut self,
        ns: u32,
        tokens: &[u32],
        pool: &mut BlockPool,
    ) -> MatchResult {
        self.stats.match_queries += 1;
        let mut res = MatchResult::default();
        let Some(&root) = self.roots.get(&ns) else {
            return res;
        };
        let now = self.tick();
        let mut cur = root;
        let mut consumed = 0usize;
        while consumed + self.page_tokens <= tokens.len() {
            let chunk = &tokens[consumed..consumed + self.page_tokens];
            let next = match self.nodes[cur as usize].children.get(chunk) {
                Some(&n) => n,
                None => break,
            };
            let node = &mut self.nodes[next as usize];
            node.last_access = now;
            node.leases += 1;
            pool.retain(node.page);
            res.pages.push(node.page);
            res.path.push(next);
            consumed += self.page_tokens;
            cur = next;
        }
        res.tokens = consumed;
        self.stats.matched_pages += res.pages.len() as u64;
        res
    }

    /// Drop the leases acquired by `match_lease` (pool refs are the
    /// caller's to release separately — sequence teardown does both).
    pub fn release_path(&mut self, path: &[NodeId]) {
        for &id in path {
            let node = &mut self.nodes[id as usize];
            debug_assert!(!node.dead, "lease release on dead node");
            assert!(node.leases > 0, "lease underflow on node {id}");
            node.leases -= 1;
            if node.leases == 0 && node.children.is_empty() {
                let stamp = node.last_access;
                self.lru_push(stamp, id);
            }
        }
    }

    /// Publish `pages` (one per full page of `tokens`) into the tree.
    /// Pages already present are deduped: the tree keeps its existing node
    /// and ignores the caller's page (the caller still owns its own ref).
    /// For adopted pages the tree takes its own `retain`. Returns the
    /// number of newly adopted pages.
    pub fn insert(
        &mut self,
        ns: u32,
        tokens: &[u32],
        pages: &[PageId],
        pool: &mut BlockPool,
    ) -> usize {
        let full_pages = tokens.len() / self.page_tokens;
        assert!(
            pages.len() >= full_pages,
            "insert: {} pages for {} full pages",
            pages.len(),
            full_pages
        );
        let now = self.tick();
        let root = *self.roots.entry(ns).or_insert_with(|| NIL);
        let mut cur = if root == NIL {
            let id = self.alloc_node(Node {
                key: Box::from(&[][..]),
                page: PageId::MAX,
                parent: NIL,
                ns,
                children: HashMap::new(),
                last_access: now,
                leases: 1, // roots are never evicted
                pins: 0,
                epoch: 0,
                dead: false,
            });
            self.roots.insert(ns, id);
            id
        } else {
            root
        };

        let mut adopted = 0usize;
        for (i, chunk) in tokens.chunks_exact(self.page_tokens).enumerate() {
            let key: Box<[u32]> = chunk.into();
            if let Some(&existing) = self.nodes[cur as usize].children.get(&key) {
                self.nodes[existing as usize].last_access = now;
                self.stats.deduped_pages += 1;
                cur = existing;
                continue;
            }
            let page = pages[i];
            pool.retain(page);
            let id = self.alloc_node(Node {
                key: key.clone(),
                page,
                parent: cur,
                ns,
                children: HashMap::new(),
                last_access: now,
                leases: 0,
                pins: 0,
                epoch: 0,
                dead: false,
            });
            self.nodes[cur as usize].children.insert(key, id);
            self.lru_push(now, id);
            self.stats.nodes += 1;
            self.stats.inserted_pages += 1;
            adopted += 1;
            cur = id;
        }
        adopted
    }

    /// Evict up to `want_pages` least-recently-used unleased leaves,
    /// releasing their pool refs. Returns the number of pages whose memory
    /// was actually freed (refcount reached zero) — nodes whose pages are
    /// still held by running sequences are *skipped*, because evicting them
    /// frees no memory and only destroys future sharing.
    ///
    /// Workflow-aware (KVFlow-style): the first pass also defers nodes a
    /// queued fork has pinned ([`RadixTree::pin_prefix`]), counting each
    /// deferral in `stats.deferred_evictions`; only if the unpinned
    /// candidates cannot satisfy the demand does a second pass take
    /// pinned pages too — pins shape eviction *order*, they never turn
    /// into a budget leak.
    /// Decoupled policy (paper §5.2): this touches only *this* tree/pool.
    pub fn evict(&mut self, want_pages: usize, pool: &mut BlockPool) -> usize {
        self.evict_with_sink(want_pages, pool, None)
    }

    /// [`RadixTree::evict`] with a demotion sink: each victim's bytes are
    /// offered to `sink` (see [`DemoteSink`]) just before the page is
    /// freed, turning "evict = destroy" into "evict = demote" when the
    /// engine's host-memory tier is on.
    pub fn evict_with_sink(
        &mut self,
        want_pages: usize,
        pool: &mut BlockPool,
        mut sink: Option<DemoteSink<'_>>,
    ) -> usize {
        let freed = self.evict_pass(want_pages, pool, false, true, sink.as_deref_mut());
        if freed < want_pages {
            freed + self.evict_pass(want_pages - freed, pool, true, true, sink.as_deref_mut())
        } else {
            freed
        }
    }

    /// First-pass-only eviction: up to `want_pages` LRU leaves that are
    /// neither leased nor workflow-pinned. Unlike [`RadixTree::evict`]
    /// this never escalates to pinned pages — budget-*shrink*
    /// enforcement (`Engine::enforce_budget`, run when the pool
    /// rebalancer reclaims lent budget) takes cold cache only, so a
    /// queued fork's pinned prefix survives a rebalance exactly like it
    /// survives first-pass LRU pressure. The un-freed remainder stays
    /// enforced lazily by the allocation-time budget check. Pins skipped
    /// here do NOT count as `deferred_evictions`: that counter means "a
    /// pinned page survived to eviction's second pass", and a budget
    /// shrink has no second pass — counting its skips would inflate the
    /// gang-eviction signal on every rebalance tick.
    pub fn evict_unpinned(&mut self, want_pages: usize, pool: &mut BlockPool) -> usize {
        self.evict_unpinned_with_sink(want_pages, pool, None)
    }

    /// [`RadixTree::evict_unpinned`] with a demotion sink (see
    /// [`RadixTree::evict_with_sink`]).
    pub fn evict_unpinned_with_sink(
        &mut self,
        want_pages: usize,
        pool: &mut BlockPool,
        sink: Option<DemoteSink<'_>>,
    ) -> usize {
        self.evict_pass(want_pages, pool, false, false, sink)
    }

    fn evict_pass(
        &mut self,
        want_pages: usize,
        pool: &mut BlockPool,
        evict_pinned: bool,
        count_deferrals: bool,
        mut sink: Option<DemoteSink<'_>>,
    ) -> usize {
        let mut evicted = 0;
        let mut deferred: Vec<std::cmp::Reverse<(u64, NodeId)>> = Vec::new();
        while evicted < want_pages {
            let Some(std::cmp::Reverse((stamp, id))) = self.lru.pop() else {
                break;
            };
            let node = &self.nodes[id as usize];
            // lazy-heap validation: skip stale entries
            if node.dead
                || node.leases > 0
                || !node.children.is_empty()
                || node.last_access != stamp
            {
                // re-queue nodes whose stamp moved but are still evictable
                if !node.dead
                    && node.leases == 0
                    && node.children.is_empty()
                    && node.last_access != stamp
                {
                    let moved = node.last_access;
                    self.lru_push(moved, id);
                }
                continue;
            }
            if !evict_pinned && node.pins > 0 {
                // a queued workflow fork still needs this prefix: evict
                // it last (second pass only)
                if count_deferrals {
                    self.stats.deferred_evictions += 1;
                }
                deferred.push(std::cmp::Reverse((stamp, id)));
                continue;
            }
            if pool.refcount(node.page) > 1 {
                deferred.push(std::cmp::Reverse((stamp, id)));
                continue;
            }
            if let Some(s) = sink.as_deref_mut() {
                // victim is an unleased leaf whose page only the tree
                // holds: hand its bytes to the tier before freeing
                let (ns, page) = (node.ns, node.page);
                let path = self.token_path(id);
                s(ns, &path, pool.page_data(page));
            }
            self.remove_leaf(id, pool);
            evicted += 1;
        }
        // candidates that freed no memory (or were pin-deferred) go back
        // for later rounds
        for std::cmp::Reverse((s, id)) in deferred {
            self.lru_push(s, id);
        }
        self.stats.evicted_pages += evicted as u64;
        evicted
    }

    fn remove_leaf(&mut self, id: NodeId, pool: &mut BlockPool) {
        let (parent, key, page) = {
            let node = &self.nodes[id as usize];
            debug_assert!(node.children.is_empty() && node.leases == 0);
            (node.parent, node.key.clone(), node.page)
        };
        pool.release(page);
        let node = &mut self.nodes[id as usize];
        node.dead = true;
        // invalidate any outstanding PinPath entries for this slot (a
        // soft-pinned node can be force-evicted by the second pass)
        node.epoch = node.epoch.wrapping_add(1);
        node.pins = 0;
        self.free_nodes.push(id);
        self.stats.nodes -= 1;
        if parent != NIL {
            self.nodes[parent as usize].children.remove(&key);
            let p = &self.nodes[parent as usize];
            if p.children.is_empty() && p.leases == 0 && p.parent != NIL {
                let stamp = p.last_access;
                self.lru_push(stamp, parent);
            }
        }
    }

    /// Full token path from the namespace root down through `id`, in
    /// sequence order — the stable identity of the node's page used to
    /// key its tier record.
    fn token_path(&self, id: NodeId) -> Vec<u32> {
        let mut spans: Vec<&[u32]> = Vec::new();
        let mut cur = id;
        while cur != NIL {
            let node = &self.nodes[cur as usize];
            spans.push(&node.key);
            cur = node.parent;
        }
        spans.reverse();
        spans.concat()
    }

    /// The full token path of every live **leaf**, with its namespace.
    /// A leaf path names its entire ancestor chain, so this is the
    /// tree's complete structural metadata in O(pages) space — the
    /// checkpoint half of warm shard restarts (paired with the tier
    /// store's own live-path scan). Root sentinels carry no page and are
    /// skipped; an idle namespace contributes nothing.
    pub fn live_paths(&self) -> Vec<(u32, Vec<u32>)> {
        let mut out = Vec::new();
        for (id, node) in self.nodes.iter().enumerate() {
            if node.dead || node.parent == NIL || !node.children.is_empty() {
                continue;
            }
            out.push((node.ns, self.token_path(id as NodeId)));
        }
        out
    }

    /// Read-only longest-prefix probe: pages that a `match_lease` would
    /// return, without taking leases (admission-control estimates).
    pub fn probe_pages(&self, ns: u32, tokens: &[u32]) -> usize {
        let Some(&root) = self.roots.get(&ns) else {
            return 0;
        };
        let mut cur = root;
        let mut pages = 0usize;
        let mut consumed = 0usize;
        while consumed + self.page_tokens <= tokens.len() {
            let chunk = &tokens[consumed..consumed + self.page_tokens];
            match self.nodes[cur as usize].children.get(chunk) {
                Some(&n) => {
                    cur = n;
                    pages += 1;
                    consumed += self.page_tokens;
                }
                None => break,
            }
        }
        pages
    }

    /// Workflow pin (KVFlow-style "a queued step still needs this
    /// prefix"): walk the longest cached prefix of `tokens` and mark
    /// every matched node pinned, deferring its eviction to the second
    /// pass until [`RadixTree::unpin_path`] is called. Unlike
    /// [`RadixTree::match_lease`] this takes **no pool refs** and does
    /// not touch the LRU clock — a pin is a scheduling hint, not
    /// ownership, so pinned pages still free their memory if the tree is
    /// forced to take them. Returns the pinned path; an empty path means
    /// nothing was cached (and needs no unpin).
    pub fn pin_prefix(&mut self, ns: u32, tokens: &[u32]) -> PinPath {
        let Some(&root) = self.roots.get(&ns) else {
            return Vec::new();
        };
        let mut cur = root;
        let mut consumed = 0usize;
        let mut path = Vec::new();
        while consumed + self.page_tokens <= tokens.len() {
            let chunk = &tokens[consumed..consumed + self.page_tokens];
            let next = match self.nodes[cur as usize].children.get(chunk) {
                Some(&n) => n,
                None => break,
            };
            let node = &mut self.nodes[next as usize];
            node.pins += 1;
            path.push((next, node.epoch));
            consumed += self.page_tokens;
            cur = next;
        }
        path
    }

    /// Drop the pins taken by [`RadixTree::pin_prefix`]. Entries whose
    /// node was force-evicted in the meantime (dead, or recycled under a
    /// newer epoch) are skipped — their pins died with the node.
    pub fn unpin_path(&mut self, path: &[(u32, u32)]) {
        for &(id, epoch) in path {
            let node = &mut self.nodes[id as usize];
            if node.dead || node.epoch != epoch {
                continue;
            }
            debug_assert!(node.pins > 0, "unpin underflow on node {id}");
            node.pins = node.pins.saturating_sub(1);
        }
    }

    /// Live nodes currently carrying at least one workflow pin (test /
    /// leak-check observability).
    pub fn pinned_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| !n.dead && n.pins > 0).count()
    }

    /// Pages whose memory is reclaimable by (possibly cascaded) eviction:
    /// unleased nodes whose page is referenced only by the tree.
    pub fn reclaimable_pages(&self, pool: &BlockPool) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                !n.dead
                    && n.page != PageId::MAX
                    && n.leases == 0
                    && pool.refcount(n.page) == 1
            })
            .count()
    }

    /// Drop the whole tree, releasing every page (used by tests/benches).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for node in &self.nodes {
            if !node.dead && node.page != PageId::MAX {
                pool.release(node.page);
            }
        }
        self.nodes.clear();
        self.free_nodes.clear();
        self.roots.clear();
        self.lru.clear();
        self.stats.nodes = 0;
    }

    /// Structural invariants (tests): every live non-root node is reachable
    /// from its ns root, child links are bidirectional, page refcounts > 0.
    pub fn check_invariants(&self, pool: &BlockPool) -> Result<(), String> {
        let mut live = 0usize;
        for (id, node) in self.nodes.iter().enumerate() {
            if node.dead {
                continue;
            }
            if node.page != PageId::MAX {
                live += 1;
                if pool.refcount(node.page) == 0 {
                    return Err(format!("node {id} holds freed page {}", node.page));
                }
                let parent = &self.nodes[node.parent as usize];
                match parent.children.get(&node.key) {
                    Some(&c) if c == id as NodeId => {}
                    _ => return Err(format!("node {id} not linked from parent")),
                }
            }
            for (&ref key, &child) in &node.children {
                let c = &self.nodes[child as usize];
                if c.dead {
                    return Err(format!("dead child {child} reachable"));
                }
                if c.parent != id as NodeId || &c.key != key {
                    return Err(format!("child {child} parent/key mismatch"));
                }
            }
        }
        if live != self.stats.nodes {
            return Err(format!("stats.nodes {} != live {live}", self.stats.nodes));
        }
        Ok(())
    }
}

/// The paper's coordinated dual-tree storage (§5.2): one tree for the
/// globally shared bCache, one for the per-adapter rCache, with
/// independently managed LRU lifecycles.
#[derive(Debug)]
pub struct DualRadixTree {
    /// token-keyed bCache tree (namespace 0 is globally shared)
    pub base: RadixTree,
    /// (adapter, token)-keyed rCache tree (namespace = adapter id)
    pub residual: RadixTree,
}

/// Outcome of forking a new agent onto existing cache state.
#[derive(Debug, Default)]
pub struct ForkMatch {
    /// bCache half of the fork's Step-1 match
    pub base: MatchResult,
    /// rCache half of the fork's Step-1 match
    pub residual: MatchResult,
}

impl ForkMatch {
    /// Tokens that can be skipped entirely (both components cached).
    pub fn full_hit_tokens(&self) -> usize {
        self.base.tokens.min(self.residual.tokens)
    }
    /// Tokens with a *partial* hit (exactly one component survives) —
    /// the paper's decoupled-eviction win: the surviving half is reused.
    pub fn partial_hit_tokens(&self) -> usize {
        self.base.tokens.max(self.residual.tokens) - self.full_hit_tokens()
    }
}

impl DualRadixTree {
    /// Two empty trees sharing one page granularity.
    pub fn new(page_tokens: usize) -> Self {
        DualRadixTree {
            base: RadixTree::new(page_tokens),
            residual: RadixTree::new(page_tokens),
        }
    }

    /// Fork Step 1 for a new agent: longest-prefix match in both trees.
    /// The base match is adapter-agnostic (ns 0); the residual match is
    /// namespaced by the adapter.
    pub fn fork_match(
        &mut self,
        adapter: u32,
        tokens: &[u32],
        base_pool: &mut BlockPool,
        res_pool: &mut BlockPool,
    ) -> ForkMatch {
        ForkMatch {
            base: self.base.match_lease(0, tokens, base_pool),
            residual: self.residual.match_lease(adapter, tokens, res_pool),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PoolSpec;
    use crate::prop_assert;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn pool(pages: usize) -> BlockPool {
        BlockPool::new(PoolSpec {
            n_pages: pages,
            page_tokens: 4,
            n_layers: 1,
            width: 2,
        })
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::seeded(seed);
        rng.tokens(n, 1000)
    }

    /// allocate + publish a sequence, returning (tokens, seq refs released)
    fn publish(tree: &mut RadixTree, ns: u32, tokens: &[u32], pool: &mut BlockPool) {
        let n_pages = tokens.len() / tree.page_tokens();
        let pages: Vec<PageId> = (0..n_pages).map(|_| pool.alloc().unwrap()).collect();
        tree.insert(ns, tokens, &pages, pool);
        for p in pages {
            pool.release(p); // tree keeps its own refs
        }
    }

    #[test]
    fn match_returns_longest_cached_prefix() {
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(16, 1);
        publish(&mut tree, 0, &t, &mut pool);

        // full match
        let m = tree.match_lease(0, &t, &mut pool);
        assert_eq!(m.tokens, 16);
        assert_eq!(m.pages.len(), 4);
        tree.release_path(&m.path);
        for p in &m.pages {
            pool.release(*p);
        }

        // diverging suffix matches only the shared prefix
        let mut t2 = t.clone();
        t2[9] = t2[9].wrapping_add(7); // diverge in page 2 (tokens 8..12)
        let m2 = tree.match_lease(0, &t2, &mut pool);
        assert_eq!(m2.tokens, 8);
        tree.release_path(&m2.path);
        for p in &m2.pages {
            pool.release(*p);
        }
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn namespaces_isolate_adapters() {
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(8, 2);
        publish(&mut tree, 1, &t, &mut pool);
        let m_other = tree.match_lease(2, &t, &mut pool);
        assert_eq!(m_other.tokens, 0, "adapter 2 must not see adapter 1's cache");
        let m_same = tree.match_lease(1, &t, &mut pool);
        assert_eq!(m_same.tokens, 8);
        tree.release_path(&m_same.path);
        for p in &m_same.pages {
            pool.release(*p);
        }
    }

    #[test]
    fn insert_dedups_shared_prefix() {
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(8, 3);
        publish(&mut tree, 0, &t, &mut pool);
        let used_before = pool.used_pages();

        // same tokens published again by a second sequence: all deduped
        publish(&mut tree, 0, &t, &mut pool);
        assert_eq!(pool.used_pages(), used_before);
        assert_eq!(tree.stats().deduped_pages, 2);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn eviction_respects_leases_and_lru_order() {
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let a = toks(8, 4);
        let b = toks(8, 5);
        publish(&mut tree, 0, &a, &mut pool);
        publish(&mut tree, 0, &b, &mut pool);
        assert_eq!(tree.total_pages(), 4);

        // lease `a`: its nodes must survive eviction
        let m = tree.match_lease(0, &a, &mut pool);
        assert_eq!(m.tokens, 8);
        let evicted = tree.evict(10, &mut pool);
        assert_eq!(evicted, 2, "only b's two pages are evictable");
        let m2 = tree.match_lease(0, &b, &mut pool);
        assert_eq!(m2.tokens, 0, "b evicted");
        let m3 = tree.match_lease(0, &a, &mut pool);
        assert_eq!(m3.tokens, 8, "a survived");
        tree.release_path(&m.path);
        tree.release_path(&m3.path);
        for p in m.pages.iter().chain(&m3.pages) {
            pool.release(*p);
        }
        // now everything is evictable
        let evicted = tree.evict(10, &mut pool);
        assert_eq!(evicted, 2);
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn dual_tree_partial_hits() {
        let mut bpool = pool(32);
        let mut rpool = pool(32);
        let mut dual = DualRadixTree::new(4);
        let t = toks(16, 6);

        // agent 1 published both components over 16 tokens
        publish(&mut dual.base, 0, &t, &mut bpool);
        publish(&mut dual.residual, 1, &t, &mut rpool);

        // fork agent 1 again: full hit on 16
        let f = dual.fork_match(1, &t, &mut bpool, &mut rpool);
        assert_eq!(f.full_hit_tokens(), 16);
        assert_eq!(f.partial_hit_tokens(), 0);
        dual.base.release_path(&f.base.path);
        dual.residual.release_path(&f.residual.path);
        for p in &f.base.pages {
            bpool.release(*p);
        }
        for p in &f.residual.pages {
            rpool.release(*p);
        }

        // fork agent 2: base inherited (shared!), residual cold => CoW tail
        let f2 = dual.fork_match(2, &t, &mut bpool, &mut rpool);
        assert_eq!(f2.base.tokens, 16);
        assert_eq!(f2.residual.tokens, 0);
        assert_eq!(f2.full_hit_tokens(), 0);
        assert_eq!(f2.partial_hit_tokens(), 16);
        dual.base.release_path(&f2.base.path);
        for p in &f2.base.pages {
            bpool.release(*p);
        }

        // decoupled eviction: dropping all residual pages leaves base intact
        let evicted = dual.residual.evict(100, &mut rpool);
        assert_eq!(evicted, 4);
        let f3 = dual.fork_match(1, &t, &mut bpool, &mut rpool);
        assert_eq!(f3.base.tokens, 16, "bCache survives rCache eviction");
        assert_eq!(f3.residual.tokens, 0);
        dual.base.release_path(&f3.base.path);
        for p in &f3.base.pages {
            bpool.release(*p);
        }
    }

    #[test]
    fn fork_match_stops_at_page_boundary_mid_page() {
        // a query whose length (or divergence point) falls mid-page must
        // match only whole pages — fork coverage is page-aligned
        let mut bpool = pool(32);
        let mut rpool = pool(32);
        let mut dual = DualRadixTree::new(4);
        let t = toks(16, 40);
        publish(&mut dual.base, 0, &t, &mut bpool);
        publish(&mut dual.residual, 1, &t, &mut rpool);

        // query ends mid-page: 10 tokens -> 2 full pages = 8 tokens
        let f = dual.fork_match(1, &t[..10], &mut bpool, &mut rpool);
        assert_eq!(f.base.tokens, 8);
        assert_eq!(f.residual.tokens, 8);
        assert_eq!(f.base.pages.len(), 2);
        assert_eq!(f.full_hit_tokens(), 8);
        assert_eq!(f.partial_hit_tokens(), 0);
        dual.base.release_path(&f.base.path);
        dual.residual.release_path(&f.residual.path);
        for p in &f.base.pages {
            bpool.release(*p);
        }
        for p in &f.residual.pages {
            rpool.release(*p);
        }

        // divergence mid-page 3 (token 10): match stops after page 2
        let mut t2 = t.clone();
        t2[10] = t2[10].wrapping_add(13);
        let f2 = dual.fork_match(1, &t2, &mut bpool, &mut rpool);
        assert_eq!(f2.base.tokens, 8);
        assert_eq!(f2.residual.tokens, 8);
        dual.base.release_path(&f2.base.path);
        dual.residual.release_path(&f2.residual.path);
        for p in &f2.base.pages {
            bpool.release(*p);
        }
        for p in &f2.residual.pages {
            rpool.release(*p);
        }
        dual.base.check_invariants(&bpool).unwrap();
        dual.residual.check_invariants(&rpool).unwrap();
    }

    #[test]
    fn fork_match_zero_length_residual_is_pure_partial() {
        // base cached, residual namespace completely cold: the fork is
        // all partial hit, zero full hit — and the residual MatchResult
        // must be truly empty (no pages, no path to release)
        let mut bpool = pool(32);
        let mut rpool = pool(32);
        let mut dual = DualRadixTree::new(4);
        let t = toks(12, 41);
        publish(&mut dual.base, 0, &t, &mut bpool);

        let f = dual.fork_match(5, &t, &mut bpool, &mut rpool);
        assert_eq!(f.base.tokens, 12);
        assert_eq!(f.residual.tokens, 0);
        assert!(f.residual.pages.is_empty());
        assert!(f.residual.path.is_empty());
        assert_eq!(f.full_hit_tokens(), 0);
        assert_eq!(f.partial_hit_tokens(), 12);
        assert_eq!(rpool.used_pages(), 0, "cold residual must not allocate");
        dual.base.release_path(&f.base.path);
        for p in &f.base.pages {
            bpool.release(*p);
        }
    }

    #[test]
    fn fork_match_residual_only_hit_after_base_eviction() {
        // decoupled eviction can leave the rCache alive with the bCache
        // gone: the surviving residual half must still be matched
        let mut bpool = pool(32);
        let mut rpool = pool(32);
        let mut dual = DualRadixTree::new(4);
        let t = toks(12, 42);
        publish(&mut dual.base, 0, &t, &mut bpool);
        publish(&mut dual.residual, 7, &t, &mut rpool);
        assert_eq!(dual.base.evict(100, &mut bpool), 3, "drop the whole base");

        let f = dual.fork_match(7, &t, &mut bpool, &mut rpool);
        assert_eq!(f.base.tokens, 0, "base gone");
        assert!(f.base.pages.is_empty() && f.base.path.is_empty());
        assert_eq!(f.residual.tokens, 12, "residual survives alone");
        assert_eq!(f.full_hit_tokens(), 0);
        assert_eq!(f.partial_hit_tokens(), 12);
        dual.residual.release_path(&f.residual.path);
        for p in &f.residual.pages {
            rpool.release(*p);
        }
    }

    #[test]
    fn evict_refuses_pages_leased_by_inflight_export() {
        // a migration export leases its matched pages for the duration of
        // the byte copy (migrate::export_component); an LRU pass landing
        // between the lease and the release must not free them
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(12, 43);
        publish(&mut tree, 0, &t, &mut pool);
        assert_eq!(tree.total_pages(), 3);

        // "in-flight export": lease held, bytes being copied
        let export_lease = tree.match_lease(0, &t, &mut pool);
        assert_eq!(export_lease.tokens, 12);
        assert_eq!(tree.evict(100, &mut pool), 0, "leased pages must survive");
        let still = tree.match_lease(0, &t, &mut pool);
        assert_eq!(still.tokens, 12, "export source intact under pressure");
        tree.release_path(&still.path);
        for p in &still.pages {
            pool.release(*p);
        }

        // export done: leases released, pages evictable again
        tree.release_path(&export_lease.path);
        for p in &export_lease.pages {
            pool.release(*p);
        }
        assert_eq!(tree.evict(100, &mut pool), 3);
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn export_component_snapshots_without_leaking() {
        // the real export path end to end: bytes captured while leased,
        // then every lease and pool ref dropped — the tree/pool state is
        // exactly as before the export
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(12, 44);
        // make page contents distinguishable
        let pages: Vec<PageId> = (0..3).map(|_| pool.alloc().unwrap()).collect();
        for (i, &p) in pages.iter().enumerate() {
            pool.page_data_mut(p).fill(i as f32 + 1.0);
        }
        tree.insert(0, &t, &pages, &mut pool);
        for p in pages {
            pool.release(p);
        }
        let used_before = pool.used_pages();

        let export = crate::migrate::export_component(&mut tree, &mut pool, 0, &t[..10]);
        assert_eq!(export.tokens, t[..8], "page-aligned prefix of the query");
        assert_eq!(export.pages.len(), 2);
        assert!(export.pages[0].iter().all(|&x| x == 1.0));
        assert!(export.pages[1].iter().all(|&x| x == 2.0));
        assert_eq!(pool.used_pages(), used_before, "no refs leaked");
        tree.check_invariants(&pool).unwrap();
        // everything is evictable again (no lingering leases)
        assert_eq!(tree.evict(100, &mut pool), 3);
    }

    #[test]
    fn pinned_pages_evicted_last_but_reclaimable() {
        // workflow-aware eviction contract: pages pinned for a queued
        // fork survive LRU pressure while unpinned candidates exist, are
        // still takeable under terminal pressure (no budget leak), and
        // become plainly evictable once the tag's pending count (the
        // engine's pin) is gone
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let a = toks(8, 60);
        let b = toks(8, 61);
        publish(&mut tree, 0, &a, &mut pool); // older: LRU victim first
        publish(&mut tree, 0, &b, &mut pool);
        assert_eq!(tree.total_pages(), 4);

        let pins = tree.pin_prefix(0, &a);
        assert_eq!(pins.len(), 2);
        assert_eq!(tree.pinned_nodes(), 2);
        assert_eq!(pool.used_pages(), 4, "pins take no pool refs");

        // partial pressure: although a is least recently used, only b's
        // unpinned pages go — and the deferrals are counted
        assert_eq!(tree.evict(2, &mut pool), 2);
        assert!(tree.stats().deferred_evictions >= 2, "{:?}", tree.stats());
        let m = tree.match_lease(0, &a, &mut pool);
        assert_eq!(m.tokens, 8, "pinned prefix evicted while unpinned existed");
        let mb = tree.match_lease(0, &b, &mut pool);
        assert_eq!(mb.tokens, 0, "unpinned pages should have been the victims");
        tree.release_path(&m.path);
        for p in &m.pages {
            pool.release(*p);
        }

        // terminal pressure: pins are soft — the second pass takes them
        // rather than leaving the budget stuck
        assert_eq!(tree.evict(10, &mut pool), 2);
        assert_eq!(pool.used_pages(), 0, "pinned pages leaked budget");
        tree.check_invariants(&pool).unwrap();

        // the stale unpin (its nodes were force-evicted) must be a no-op
        tree.unpin_path(&pins);
        assert_eq!(tree.pinned_nodes(), 0);

        // recycled node slots: a re-publish reuses the freed node ids
        // under a bumped epoch, so the old PinPath can never strip the
        // fresh pins
        publish(&mut tree, 0, &a, &mut pool);
        let fresh = tree.pin_prefix(0, &a);
        assert_eq!(fresh.len(), 2);
        tree.unpin_path(&pins); // stale epochs: skipped
        assert_eq!(tree.pinned_nodes(), 2, "stale unpin stripped fresh pins");

        // the normal lifecycle: pending count hits zero -> unpin -> the
        // pages are ordinary first-pass LRU candidates again
        tree.unpin_path(&fresh);
        assert_eq!(tree.pinned_nodes(), 0);
        let deferred_before = tree.stats().deferred_evictions;
        assert_eq!(tree.evict(10, &mut pool), 2);
        assert_eq!(
            tree.stats().deferred_evictions,
            deferred_before,
            "unpinned eviction must not defer"
        );
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn evict_sink_receives_full_path_and_page_bytes() {
        // the demote hook: every victim is reported with its namespace,
        // its full root-to-node token path, and its exact page bytes,
        // leaves first (children evict before their parents)
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(8, 73);
        let pages: Vec<PageId> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        for (i, &p) in pages.iter().enumerate() {
            pool.page_data_mut(p).fill(i as f32 + 1.0);
        }
        tree.insert(3, &t, &pages, &mut pool);
        for p in pages {
            pool.release(p);
        }
        let mut got: Vec<(u32, Vec<u32>, Vec<f32>)> = Vec::new();
        let mut sink = |ns: u32, path: &[u32], data: &[f32]| {
            got.push((ns, path.to_vec(), data.to_vec()));
        };
        let freed = tree.evict_with_sink(10, &mut pool, Some(&mut sink));
        assert_eq!(freed, 2);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 3);
        assert_eq!(got[0].1, t[..8], "leaf demotes first, under its full path");
        assert!(got[0].2.iter().all(|&x| x == 2.0), "leaf page bytes");
        assert_eq!(got[1].1, t[..4], "then its parent");
        assert!(got[1].2.iter().all(|&x| x == 1.0), "parent page bytes");
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn lru_heap_stays_bounded_under_access_heavy_loop() {
        // every match/release cycle pushes a fresh heap entry; without
        // compaction the lazy heap grows without bound
        let mut pool = pool(32);
        let mut tree = RadixTree::new(4);
        let t = toks(16, 70);
        publish(&mut tree, 0, &t, &mut pool);
        for _ in 0..10_000 {
            let m = tree.match_lease(0, &t, &mut pool);
            assert_eq!(m.tokens, 16);
            tree.release_path(&m.path);
            for p in &m.pages {
                pool.release(*p);
            }
        }
        let bound = (tree.total_pages() * LRU_COMPACT_FACTOR).max(LRU_COMPACT_FLOOR);
        assert!(
            tree.lru.len() <= bound,
            "lru heap grew to {} (bound {bound})",
            tree.lru.len()
        );
        // stale entries are skipped, not re-evicted: exactly the tree's
        // four pages free, and the pool returns to empty
        assert_eq!(tree.evict(100, &mut pool), 4);
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn lru_compaction_keeps_stamp_moved_leaves_evictable() {
        // a deduping re-insert bumps last_access without pushing a new
        // heap entry; compaction must refresh such nodes' stamps instead
        // of dropping them, or they become unevictable forever
        let mut pool = pool(64);
        let mut tree = RadixTree::new(4);
        let t = toks(8, 71);
        let u = toks(8, 72);
        publish(&mut tree, 0, &t, &mut pool);
        publish(&mut tree, 0, &u, &mut pool);
        for _ in 0..200 {
            publish(&mut tree, 0, &t, &mut pool); // all deduped: stamps move
            let m = tree.match_lease(0, &u, &mut pool); // heap churn
            tree.release_path(&m.path);
            for p in &m.pages {
                pool.release(*p);
            }
        }
        let bound = (tree.total_pages() * LRU_COMPACT_FACTOR).max(LRU_COMPACT_FLOOR);
        assert!(tree.lru.len() <= bound, "heap unbounded: {}", tree.lru.len());
        assert_eq!(tree.evict(100, &mut pool), 4, "every page still evictable");
        assert_eq!(pool.used_pages(), 0);
        tree.check_invariants(&pool).unwrap();
    }

    #[test]
    fn prop_radix_consistency_under_random_traffic() {
        prop::check("radix-fuzz", 48, |rng| {
            let mut pool = BlockPool::new(PoolSpec {
                n_pages: 64,
                page_tokens: 4,
                n_layers: 1,
                width: 2,
            });
            let mut tree = RadixTree::new(4);
            // a small universe of base sequences with shared prefixes
            let base = {
                let mut r = rng.fork(99);
                r.tokens(24, 50)
            };
            let mut outstanding: Vec<(Vec<u32>, MatchResult)> = Vec::new();
            for _ in 0..120 {
                match rng.below(4) {
                    0 => {
                        // publish a random-length prefix w/ random suffix
                        let keep = rng.below(5) * 4;
                        let extra = rng.below(3) * 4;
                        let mut t = base[..keep.min(base.len())].to_vec();
                        let mut r2 = rng.fork(7);
                        t.extend(r2.tokens(extra, 50));
                        let n_pages = t.len() / 4;
                        let mut pages = Vec::new();
                        let mut ok = true;
                        for _ in 0..n_pages {
                            match pool.alloc() {
                                Some(p) => pages.push(p),
                                None => {
                                    ok = false;
                                    break;
                                }
                            }
                        }
                        if ok {
                            tree.insert(0, &t, &pages, &mut pool);
                        }
                        for p in pages {
                            pool.release(p);
                        }
                    }
                    1 => {
                        let keep = rng.below(7) * 4;
                        let t = base[..keep.min(base.len())].to_vec();
                        let m = tree.match_lease(0, &t, &mut pool);
                        prop_assert!(
                            m.tokens <= t.len(),
                            "matched more than queried"
                        );
                        prop_assert!(
                            m.tokens % 4 == 0,
                            "match not page aligned"
                        );
                        outstanding.push((t, m));
                    }
                    2 if !outstanding.is_empty() => {
                        let i = rng.below(outstanding.len());
                        let (_t, m) = outstanding.swap_remove(i);
                        tree.release_path(&m.path);
                        for p in &m.pages {
                            pool.release(*p);
                        }
                    }
                    _ => {
                        tree.evict(rng.below(4) + 1, &mut pool);
                    }
                }
                tree.check_invariants(&pool).map_err(|e| e)?;
                pool.check_invariants().map_err(|e| e)?;
            }
            // leased prefixes must still be fully matchable
            for (t, m) in &outstanding {
                if m.tokens > 0 {
                    let m2 = tree.match_lease(0, &t[..m.tokens], &mut pool);
                    prop_assert!(
                        m2.tokens == m.tokens,
                        "leased prefix shrank: {} -> {}",
                        m.tokens,
                        m2.tokens
                    );
                    tree.release_path(&m2.path);
                    for p in &m2.pages {
                        pool.release(*p);
                    }
                }
            }
            Ok(())
        });
    }
}
