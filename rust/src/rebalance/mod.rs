//! Elastic shard budgets: the pool-level budget rebalancer.
//!
//! `EngineConfig::shard_slice` splits one "GPU memory" budget statically
//! 1/N across the engine shards. A skewed workflow (one hot MapReduce fan
//! pinned to its home shard by affinity routing) saturates that shard's
//! slice and starts OOM-dropping while its neighbors sit on free pages —
//! the imbalance the paper's *dynamic* base/residual split (ForkKV §5.1)
//! exists to avoid inside a single device. This module is the
//! disaggregated-pool analogue: a server-level supervisor periodically
//! reads every shard's [`BudgetPressure`] and **lends free budget from
//! cold shards to hot ones**, bounded so no shard is ever starved.
//!
//! Design rules (all enforced by [`Rebalancer::tick`], property-tested):
//!   - **conservation** — the per-shard budgets always sum to the
//!     configured pool total; every byte removed from a donor lands on
//!     exactly one borrower in the same tick;
//!   - **lend floor** — a donor never drops below
//!     `base_slice * (1 - lend_max_frac)` (clamped to at least 1/8 of the
//!     slice), so a cold shard that turns hot later still owns a working
//!     budget immediately;
//!   - **free bytes only** — a donor lends only budget it is not using
//!     (plus a slack margin), so granting a loan never forces the donor to
//!     evict its own cache;
//!   - **physical cap** — a borrower's budget never exceeds its pools'
//!     physical capacity (`CacheConfig::capacity_bytes` headroom), so lent
//!     budget is always actually usable;
//!   - **hysteresis** — a shard must stay non-hot for
//!     [`DONOR_COOLDOWN_TICKS`] ticks before it lends, moves are bounded
//!     by a per-donor per-tick step, and surplus drifts back toward the
//!     static split only while *no* shard is hot — budget cannot thrash
//!     back and forth between two bursty shards;
//!   - **replica weighting** — a shard holding hot-context replicas (the
//!     server threads its replica-map holder count through
//!     [`BudgetPressure::hot_replicas`]) lends at half the usual
//!     per-tick step, and among equally starved borrowers the replica
//!     holder is served first: its pages are the warm copies the router
//!     steers spilled forks onto, so squeezing its budget would evict
//!     exactly the bytes replication just paid to ship.
//!
//! The planner is deliberately pure (budgets in, budgets out, no
//! channels): the server supervisor feeds it `Cmd::Pressure` snapshots and
//! applies its moves with `Cmd::Budget`, and the property tests drive it
//! directly with synthetic pressure sequences.

#![warn(missing_docs)]

/// One shard's budget-pressure snapshot, served by `Cmd::Pressure`
/// (`Engine::budget_pressure`). Counters are cumulative; the planner
/// differences them against the previous tick itself.
#[derive(Debug, Clone, Copy, Default)]
pub struct BudgetPressure {
    /// bytes currently held by used pages across both pools
    pub used_bytes: usize,
    /// the shard's currently enforced byte budget
    pub budget_bytes: usize,
    /// physical pool capacity (page tables × page bytes) — the hard
    /// ceiling on how much budget this shard can actually spend
    pub capacity_bytes: usize,
    /// cumulative allocations/admissions denied by the byte budget
    pub budget_denials: u64,
    /// cumulative allocations that found a pool physically exhausted
    pub alloc_failures: u64,
    /// cumulative requests dropped by the memory-deadlock breaker
    pub oom_drops: u64,
    /// hot-context replicas this shard currently holds, per the server's
    /// replica map (engines report 0 — the server fills this in before
    /// ticking the planner; see the module docs' replica-weighting rule)
    pub hot_replicas: usize,
}

/// Ticks a shard must stay non-hot before it is allowed to lend budget
/// (the donor half of the thrash hysteresis).
pub const DONOR_COOLDOWN_TICKS: u8 = 2;

/// A shard whose used bytes reach 15/16 of its budget counts as hot even
/// before any allocation is denied (numerator/denominator of the ratio).
const HOT_USED_NUM: usize = 15;
const HOT_USED_DEN: usize = 16;

/// The pool-level budget planner. Owns the authoritative per-shard budget
/// vector; [`Rebalancer::tick`] consumes one pressure snapshot per shard
/// and returns the budget moves to apply.
#[derive(Debug)]
pub struct Rebalancer {
    /// the static `shard_slice` budgets the pool was constructed with
    base: Vec<usize>,
    /// lend floor per shard (see module docs)
    floor: Vec<usize>,
    /// current budgets (always sums to `sum(base)`)
    budgets: Vec<usize>,
    /// last observed `budget_denials + alloc_failures` per shard
    last_fail: Vec<u64>,
    /// last observed `oom_drops` per shard
    last_oom: Vec<u64>,
    /// ticks remaining before this shard may lend again
    cool: Vec<u8>,
}

impl Rebalancer {
    /// Planner over a pool whose shards were constructed with
    /// `base_slices` byte budgets. `lend_max_frac` ∈ [0, 1] bounds how
    /// much of its base slice a shard may lend out (the floor is clamped
    /// so at least 1/8 of every slice is unlendable — a shard can never
    /// be starved into an allocation deadlock).
    pub fn new(base_slices: Vec<usize>, lend_max_frac: f64) -> Self {
        assert!(!base_slices.is_empty(), "rebalancer needs at least one shard");
        let frac = lend_max_frac.clamp(0.0, 1.0);
        let floor = base_slices
            .iter()
            .map(|&b| {
                let kept = (b as f64 * (1.0 - frac)) as usize;
                kept.max(b / 8).max(1)
            })
            .collect();
        let n = base_slices.len();
        Rebalancer {
            budgets: base_slices.clone(),
            floor,
            base: base_slices,
            last_fail: vec![0; n],
            last_oom: vec![0; n],
            cool: vec![0; n],
        }
    }

    /// The configured pool total (what the budgets always sum to).
    pub fn total(&self) -> usize {
        self.base.iter().sum()
    }

    /// Current per-shard budgets (the planner's authoritative view).
    pub fn budgets(&self) -> &[usize] {
        &self.budgets
    }

    /// The lend floor of shard `i`.
    pub fn floor(&self, i: usize) -> usize {
        self.floor[i]
    }

    /// One rebalance step. `obs[i]` is shard i's pressure snapshot, or
    /// `None` for a dead/unreachable shard (its budget is frozen —
    /// neither lent out nor granted to). Returns the changed budgets as
    /// `(shard, new_budget_bytes)` plus the total bytes moved this tick.
    pub fn tick(&mut self, obs: &[Option<BudgetPressure>]) -> (Vec<(usize, usize)>, usize) {
        let n = self.base.len();
        assert_eq!(obs.len(), n, "one pressure slot per shard");
        let before = self.budgets.clone();

        // classify: a shard is hot when its failure counters moved since
        // the last tick or it is running nearly full against its budget
        let mut hot = vec![false; n];
        let mut oom_d = vec![0u64; n];
        let mut fail_d = vec![0u64; n];
        for (i, o) in obs.iter().enumerate() {
            let Some(p) = o else { continue };
            let fails = p.budget_denials + p.alloc_failures;
            fail_d[i] = fails.saturating_sub(self.last_fail[i]);
            self.last_fail[i] = self.last_fail[i].max(fails);
            oom_d[i] = p.oom_drops.saturating_sub(self.last_oom[i]);
            self.last_oom[i] = self.last_oom[i].max(p.oom_drops);
            hot[i] = fail_d[i] > 0
                || oom_d[i] > 0
                || (p.used_bytes > 0
                    && p.used_bytes * HOT_USED_DEN >= self.budgets[i] * HOT_USED_NUM);
        }
        for i in 0..n {
            self.cool[i] = if hot[i] {
                DONOR_COOLDOWN_TICKS
            } else {
                self.cool[i].saturating_sub(1)
            };
        }

        // donor offers: cold-for-a-while shards lend free budget above
        // their floor, at most one step per tick
        let mut offer = vec![0usize; n];
        for (i, o) in obs.iter().enumerate() {
            let Some(p) = o else { continue };
            if hot[i] || self.cool[i] > 0 || self.budgets[i] <= self.floor[i] {
                continue;
            }
            let slack = self.base[i] / 16;
            let free = self.budgets[i].saturating_sub(p.used_bytes + slack);
            let above_floor = self.budgets[i] - self.floor[i];
            // replica holders lend at half the step: their free bytes
            // back the warm pages spilled forks are being routed onto
            let step = if p.hot_replicas > 0 {
                (self.base[i] / 8).max(1)
            } else {
                (self.base[i] / 4).max(1)
            };
            offer[i] = free.min(above_floor).min(step);
        }

        // borrowers, most-starved first (drops outrank denials outrank
        // replica weight; index breaks ties deterministically)
        let reps: Vec<usize> = obs
            .iter()
            .map(|o| o.as_ref().map_or(0, |p| p.hot_replicas))
            .collect();
        let mut borrowers: Vec<usize> = (0..n)
            .filter(|&i| {
                obs[i].is_some()
                    && hot[i]
                    && self.budgets[i] < obs[i].as_ref().map_or(0, |p| p.capacity_bytes)
            })
            .collect();
        borrowers.sort_by_key(|&i| {
            (
                std::cmp::Reverse(oom_d[i]),
                std::cmp::Reverse(fail_d[i]),
                std::cmp::Reverse(reps[i]),
                i,
            )
        });

        let mut moved = 0usize;
        if borrowers.is_empty() {
            // quiet pool: drift surplus back toward the static split so a
            // past burst doesn't skew budgets forever. Same free-bytes
            // rule as lending — decay never forces the holder to evict.
            // per-holder decay allowance, derived once so a surplus
            // holder returns at most base/8 per *tick* no matter how
            // many shards are in deficit (the same per-tick step bound
            // the borrow path enforces via `offer`)
            let mut give = vec![0usize; n];
            for (i, o) in obs.iter().enumerate() {
                let Some(p) = o else { continue };
                if self.cool[i] > 0 {
                    continue;
                }
                let surplus = self.budgets[i].saturating_sub(self.base[i]);
                let slack = self.base[i] / 16;
                let free = self.budgets[i].saturating_sub(p.used_bytes + slack);
                let step = (self.base[i] / 8).max(1);
                give[i] = surplus.min(free).min(step);
            }
            let mut deficits: Vec<usize> = (0..n)
                .filter(|&i| obs[i].is_some() && self.budgets[i] < self.base[i])
                .collect();
            deficits.sort_by_key(|&i| (std::cmp::Reverse(self.base[i] - self.budgets[i]), i));
            for d in deficits {
                let mut want = self.base[d] - self.budgets[d];
                for i in 0..n {
                    if want == 0 {
                        break;
                    }
                    if i == d || give[i] == 0 {
                        continue;
                    }
                    let take = give[i].min(want);
                    give[i] -= take;
                    self.budgets[i] -= take;
                    self.budgets[d] += take;
                    want -= take;
                    moved += take;
                }
            }
        } else {
            for &b in &borrowers {
                let cap = obs[b].as_ref().unwrap().capacity_bytes;
                let mut want = cap.saturating_sub(self.budgets[b]);
                for d in 0..n {
                    if want == 0 {
                        break;
                    }
                    if d == b || offer[d] == 0 {
                        continue;
                    }
                    let take = offer[d].min(want);
                    offer[d] -= take;
                    self.budgets[d] -= take;
                    self.budgets[b] += take;
                    want -= take;
                    moved += take;
                }
            }
        }

        let moves: Vec<(usize, usize)> = (0..n)
            .filter(|&i| self.budgets[i] != before[i])
            .map(|i| (i, self.budgets[i]))
            .collect();
        debug_assert_eq!(
            self.budgets.iter().sum::<usize>(),
            self.total(),
            "budget conservation violated"
        );
        (moves, moved)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    const MB: usize = 1 << 20;

    fn pressure(used: usize, budget: usize) -> BudgetPressure {
        BudgetPressure {
            used_bytes: used,
            budget_bytes: budget,
            capacity_bytes: 2 * MB,
            budget_denials: 0,
            alloc_failures: 0,
            oom_drops: 0,
            hot_replicas: 0,
        }
    }

    fn quiet(n: usize, reb: &Rebalancer) -> Vec<Option<BudgetPressure>> {
        (0..n)
            .map(|i| Some(pressure(0, reb.budgets()[i])))
            .collect()
    }

    #[test]
    fn hot_shard_borrows_from_cold_ones_and_sum_is_conserved() {
        let mut reb = Rebalancer::new(vec![MB; 4], 0.5);
        assert_eq!(reb.total(), 4 * MB);
        // shard 0 dropped a request; the rest are idle
        let mut obs = quiet(4, &reb);
        obs[0] = Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) });
        let (moves, moved) = reb.tick(&obs);
        assert!(moved > 0, "hot shard got nothing");
        assert!(!moves.is_empty());
        assert!(reb.budgets()[0] > MB, "{:?}", reb.budgets());
        for i in 1..4 {
            assert!(reb.budgets()[i] >= reb.floor(i));
            assert!(reb.budgets()[i] < MB);
        }
        assert_eq!(reb.budgets().iter().sum::<usize>(), 4 * MB);
        // per-donor step is base/4: three donors → at most 3/4 MB per tick
        assert!(moved <= 3 * MB / 4, "step bound violated: {moved}");
    }

    #[test]
    fn borrower_is_capped_at_physical_capacity() {
        let mut reb = Rebalancer::new(vec![MB; 4], 1.0);
        for _ in 0..32 {
            let mut obs = quiet(4, &reb);
            // running at 100% of the current budget: hot every tick
            obs[0] = Some(pressure(reb.budgets()[0], reb.budgets()[0]));
            reb.tick(&obs);
        }
        assert_eq!(reb.budgets()[0], 2 * MB, "{:?}", reb.budgets());
        assert_eq!(reb.budgets().iter().sum::<usize>(), 4 * MB);
    }

    #[test]
    fn recently_hot_shard_does_not_lend() {
        let mut reb = Rebalancer::new(vec![MB; 2], 0.5);
        // shard 1 is hot this tick (denial delta); shard 0 too — nobody
        // lends, budgets hold
        let obs = vec![
            Some(BudgetPressure { budget_denials: 1, ..pressure(MB, MB) }),
            Some(BudgetPressure { budget_denials: 1, ..pressure(MB, MB) }),
        ];
        let (moves, moved) = reb.tick(&obs);
        assert!(moves.is_empty() && moved == 0);
        // next tick shard 1 is quiet but still cooling: it must not lend
        // to a still-hot shard 0 yet
        let obs = vec![
            Some(BudgetPressure { budget_denials: 2, ..pressure(MB, MB) }),
            Some(pressure(0, MB)),
        ];
        let (_, moved) = reb.tick(&obs);
        assert_eq!(moved, 0, "donor lent while cooling down");
        // after the cooldown elapses it lends
        let mut lent = 0;
        for k in 0..3u64 {
            let obs = vec![
                Some(BudgetPressure { budget_denials: 3 + k, ..pressure(MB, MB) }),
                Some(pressure(0, reb.budgets()[1])),
            ];
            lent += reb.tick(&obs).1;
        }
        assert!(lent > 0, "cooldown never released the donor");
    }

    #[test]
    fn quiet_pool_decays_budgets_back_toward_base() {
        let mut reb = Rebalancer::new(vec![MB; 2], 0.5);
        let obs = vec![
            Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) }),
            Some(pressure(0, MB)),
        ];
        reb.tick(&obs);
        let borrowed = reb.budgets()[0];
        assert!(borrowed > MB);
        // burst over: everyone quiet (and shard 0's surplus unused) —
        // budgets drift back to the static split
        for _ in 0..16 {
            let obs = quiet(2, &reb);
            reb.tick(&obs);
        }
        assert_eq!(reb.budgets(), &[MB, MB], "decay did not restore the split");
    }

    #[test]
    fn decay_is_bounded_per_holder_per_tick() {
        let mut reb = Rebalancer::new(vec![MB; 4], 1.0);
        // shard 0 borrows from all three peers, then the pool goes quiet
        let mut obs = quiet(4, &reb);
        obs[0] = Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) });
        reb.tick(&obs);
        assert!(reb.budgets()[0] > MB);
        // two quiet ticks drain shard 0's hot cooldown without moving
        // budget; the third is the first decay tick — one surplus holder
        // facing three deficit shards must still return at most base/8
        // total (per holder per tick, not per deficit pair)
        for _ in 0..2 {
            reb.tick(&quiet(4, &reb));
        }
        let before = reb.budgets()[0];
        reb.tick(&quiet(4, &reb));
        let returned = before - reb.budgets()[0];
        assert!(returned > 0, "quiet surplus holder never decayed");
        assert!(returned <= MB / 8, "decay exceeded the per-tick step: {returned}");
    }

    #[test]
    fn dead_shards_freeze_their_budget() {
        let mut reb = Rebalancer::new(vec![MB; 3], 0.5);
        let mut obs = quiet(3, &reb);
        obs[2] = None; // dead
        obs[0] = Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) });
        reb.tick(&obs);
        assert_eq!(reb.budgets()[2], MB, "dead shard's budget moved");
        assert!(reb.budgets()[0] > MB);
        assert_eq!(reb.budgets().iter().sum::<usize>(), 3 * MB);
    }

    #[test]
    fn donor_never_lends_bytes_it_is_using() {
        let mut reb = Rebalancer::new(vec![MB; 2], 1.0);
        // donor's cache is nearly full: free (minus slack) is tiny
        let used = MB - MB / 32;
        let obs = vec![
            Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) }),
            Some(pressure(used, MB)),
        ];
        let (_, moved) = reb.tick(&obs);
        assert!(
            moved <= MB - used,
            "lent {} bytes but only {} were free",
            moved,
            MB - used
        );
    }

    #[test]
    fn replica_holders_lend_less_and_borrow_first() {
        // donor side: a replica-holding donor lends at half the step
        let mut reb = Rebalancer::new(vec![MB; 2], 1.0);
        let obs = vec![
            Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) }),
            Some(BudgetPressure { hot_replicas: 3, ..pressure(0, MB) }),
        ];
        let (_, halved) = reb.tick(&obs);
        assert!(halved > 0, "replica holder refused to lend at all");
        assert!(halved <= MB / 8, "replica holder lent a full step: {halved}");
        let mut reb = Rebalancer::new(vec![MB; 2], 1.0);
        let obs = vec![
            Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) }),
            Some(pressure(0, MB)),
        ];
        let (_, full) = reb.tick(&obs);
        assert!(full > halved, "replica weighting changed nothing: {full} vs {halved}");

        // borrower side: two equally starved hot shards, one donor — the
        // replica holder is served first and takes the whole offer
        let mut reb = Rebalancer::new(vec![MB; 3], 1.0);
        let obs = vec![
            Some(pressure(0, MB)),
            Some(BudgetPressure { oom_drops: 1, ..pressure(MB, MB) }),
            Some(BudgetPressure { oom_drops: 1, hot_replicas: 2, ..pressure(MB, MB) }),
        ];
        reb.tick(&obs);
        assert!(
            reb.budgets()[2] > reb.budgets()[1],
            "replica-holding borrower was not preferred: {:?}",
            reb.budgets()
        );
        assert_eq!(reb.budgets().iter().sum::<usize>(), 3 * MB);
    }

    #[test]
    fn prop_random_lend_reclaim_keeps_invariants() {
        // ISSUE 5 satellite: random lend/reclaim sequences on a 4-shard
        // pool — the budgets never drift from the configured total, no
        // shard falls below its lend floor or above its physical
        // capacity, and every shard always keeps an allocatable budget
        // (no starvation deadlock).
        prop::check("rebalance-lend-reclaim", 48, |rng| {
            let n = 4;
            let base = MB;
            let frac = [0.25, 0.5, 0.75, 1.0][rng.below(4)];
            let cap = base * 2;
            let mut reb = Rebalancer::new(vec![base; n], frac);
            let total = reb.total();
            let mut fails = vec![0u64; n];
            let mut ooms = vec![0u64; n];
            for _ in 0..200 {
                let mut obs: Vec<Option<BudgetPressure>> = Vec::with_capacity(n);
                for i in 0..n {
                    if rng.below(16) == 0 {
                        obs.push(None); // transiently unreachable
                        continue;
                    }
                    let budget = reb.budgets()[i];
                    // used anywhere from empty to the full budget
                    let used = rng.below(budget + 1);
                    // hot roughly a third of the time
                    match rng.below(6) {
                        0 => fails[i] += 1 + rng.below(4) as u64,
                        1 => ooms[i] += 1,
                        _ => {}
                    }
                    obs.push(Some(BudgetPressure {
                        used_bytes: used,
                        budget_bytes: budget,
                        capacity_bytes: cap,
                        budget_denials: fails[i],
                        alloc_failures: 0,
                        oom_drops: ooms[i],
                        // replica weighting must not be able to break
                        // conservation/floor/capacity either
                        hot_replicas: rng.below(4),
                    }));
                }
                let (moves, moved) = reb.tick(&obs);
                let sum: usize = reb.budgets().iter().sum();
                prop_assert!(sum == total, "sum drifted: {sum} != {total}");
                for i in 0..n {
                    let b = reb.budgets()[i];
                    prop_assert!(
                        b >= reb.floor(i),
                        "shard {i} below floor: {b} < {}",
                        reb.floor(i)
                    );
                    prop_assert!(b <= cap, "shard {i} above capacity: {b}");
                    prop_assert!(b > 0, "shard {i} starved to zero budget");
                }
                // reported moves must match the authoritative vector
                for (i, b) in moves {
                    prop_assert!(
                        reb.budgets()[i] == b,
                        "move ({i}, {b}) disagrees with budgets"
                    );
                }
                let _ = moved;
            }
            Ok(())
        });
    }
}
