//! forkkv CLI: serve, run workloads, calibrate the sim cost model.
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set).

use std::path::{Path, PathBuf};

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::{CostModel, Executor, PjrtExecutor, SimExecutor};
use forkkv::router::RoutePolicy;
use forkkv::runtime::PrefillArgs;
use forkkv::server::Server;
use forkkv::util::json::Json;
use forkkv::workload::{
    presets, run_dag_load, run_http_load, run_multi_workflow_load,
    run_returning_sessions_load, run_skewed_workflow_load, spawn_http_shard_killer,
    DagTopology, DagWorkflowHttpSpec, HttpLoadSpec, MultiWorkflowHttpSpec,
    ReturningSessionsHttpSpec, SkewedWorkflowHttpSpec, WorkflowDriver, WorkflowKind,
    WorkloadSpec,
};

fn usage() -> ! {
    eprintln!(
        "forkkv — multi-LoRA agent serving with a CoW disaggregated KV cache

USAGE:
  forkkv serve      [--artifacts DIR] [--addr HOST:PORT] [--policy P] [--budget-mb N]
                    [--workers N] [--max-body-kb N] [--accept-backlog N]
                    [--idle-wait-ms T] [--io-timeout-ms T] [--shards N] [--route R]
                    [--imbalance F] [--migrate on|off] [--migrate-gbps F]
                    [--migrate-max-inflight N] [--gang on|off] [--gang-hold-ms T]
                    [--replicate on|off] [--replicate-miss N]
                    [--replicate-window N] [--replicate-min-forks N]
                    [--rebalance on|off] [--rebalance-ms T] [--lend-max F]
                    [--tier on|off] [--tier-mb N] [--tier-compact-ms T]
                    [--prefetch on|off] [--prefetch-horizon N]
                    [--prefetch-abandon-ms T] [--prefetch-tick-ms T]
                    [--journal on|off] [--journal-dir DIR] [--journal-sync-ms T]
                    [--journal-sync-kb N] [--journal-seg-kb N] [--checkpoint-ms T]
  forkkv run        [--policy P] [--model M] [--dataset D] [--workflow react|mapreduce]
                    [--workflows N] [--requests N] [--rate R] [--budget-mb N] [--seed S]
                    [--gang on|off] [--real --artifacts DIR]
  forkkv bench-http [--clients N] [--requests-per-client N] [--policy P] [--model M]
                    [--budget-mb N] [--max-new N] [--workers N] [--pace-us U]
                    [--shards N] [--route R] [--imbalance F]
                    [--workflows K --agents-per-workflow M] [--fan-parallel]
                    [--hot-agents N --stagger-ms T] [--waves W]
                    [--unique-words U] [--hot-pad-words P]
                    [--migrate on|off] [--migrate-gbps F]
                    [--gang on|off] [--gang-hold-ms T]
                    [--replicate on|off] [--replicate-miss N]
                    [--replicate-window N] [--replicate-min-forks N]
                    [--rebalance on|off] [--rebalance-ms T] [--lend-max F]
                    [--tier on|off] [--tier-mb N] [--tier-compact-ms T]
                    [--sessions N --visits V] [--session-words W]
                    [--dag mapreduce|react|pipeline]
                    [--prefetch on|off] [--prefetch-horizon N]
                    [--prefetch-abandon-ms T] [--prefetch-tick-ms T]
                    [--journal on|off] [--journal-dir DIR] [--journal-sync-ms T]
                    [--journal-sync-kb N] [--journal-seg-kb N] [--checkpoint-ms T]
                    [--fault-kill-shard-after-ms T] [--fault-kill-shard I]
                    # closed-loop concurrent HTTP load against a sim-backed server;
                    # with --workflows, K workflows of M agents fork shared contexts
                    # (the multi-shard placement scenario; add --fan-parallel to
                    # burst agents 1..M as a declared fan and exercise gang
                    # admission); with --hot-agents, one hot workflow bursts N
                    # parallel agents so spills are forced and cross-shard page
                    # migration (--migrate) is exercised; --waves W replays the
                    # hot burst W times (the elastic-budget --rebalance A/B);
                    # with --replicate on, repeated spill-misses of a hot
                    # read-mostly prefix plant durable replicas instead of
                    # per-spill copies (the hot-context --replicate A/B);
                    # with --sessions, N sessions of --session-words context
                    # words each make V round-robin visits, so a session's
                    # pages are evicted between visits (the host-tier --tier
                    # A/B: tier on promotes demoted pages back on return
                    # instead of recomputing the prompt); with --dag, K
                    # workflows declare their steps-to-execute DAG up front
                    # and the server pre-warms each successor step's known
                    # prefix on its home shard while the predecessors decode
                    # (the cross-step --prefetch A/B; K and the step width
                    # come from --workflows / --agents-per-workflow); with
                                        # --fault-kill-shard-after-ms, a fault injector crashes
                                        # --fault-kill-shard (default 0) mid-bench once it holds an
                                        # in-flight request — with --journal on, its journaled
                                        # submits replay on the surviving shards and the report's
                                        # journal block proves zero requests were lost
  forkkv calibrate  [--artifacts DIR]   # measure real PJRT costs + inter-shard copy
                                        # bandwidth -> calibration.json
  forkkv analyze    [--json] [PATH ...] # cross-layer invariant linter: panic-path,
                                        # pair-discipline, lock-order, counter-drift,
                                        # knob-drift, doc-gate (see docs/ANALYSIS.md);
                                        # PATH prefixes filter the report; exits 1 on
                                        # any finding not covered by an analyze:allow

  P: forkkv | prefix | full-reuse      M: llama3-8b-sim | qwen2.5-7b-sim | qwen2.5-14b-sim
  D: loogle | narrativeqa | apigen     R: affinity | round_robin"
    );
    std::process::exit(2);
}

/// Parse an `on|off` CLI flag value (also accepts true/false/1/0).
fn parse_on_off(flag: &str, v: &str) -> anyhow::Result<bool> {
    match v {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => anyhow::bail!("{flag} takes on|off, got {other:?}"),
    }
}

struct Args(Vec<String>);

impl Args {
    fn flag(&self, name: &str) -> Option<String> {
        self.0
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.0.get(i + 1).cloned())
    }
    fn has(&self, name: &str) -> bool {
        self.0.iter().any(|a| a == name)
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else { usage() };
    let args = Args(argv[1..].to_vec());
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "bench-http" => cmd_bench_http(&args),
        "calibrate" => cmd_calibrate(&args),
        "analyze" => cmd_analyze(&args),
        _ => usage(),
    }
}

/// `forkkv analyze [--json] [PATH ...]` — run the invariant passes and
/// exit non-zero when any non-allowed finding remains.
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let as_json = args.has("--json");
    let paths: Vec<String> = args
        .0
        .iter()
        .filter(|a| !a.starts_with("--"))
        .cloned()
        .collect();
    let cwd = std::env::current_dir()?;
    let root = forkkv::analysis::find_root(&cwd).ok_or_else(|| {
        anyhow::anyhow!(
            "cannot locate the crate root (src/server/mod.rs) from {}",
            cwd.display()
        )
    })?;
    let report = forkkv::analysis::run(&root, &paths);
    if as_json {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if report.active() > 0 {
        std::process::exit(1);
    }
    Ok(())
}

fn server_config(args: &Args) -> anyhow::Result<ServerConfig> {
    let mut cfg = ServerConfig::default();
    if let Some(v) = args.flag("--workers") {
        cfg.workers = v.parse()?;
        anyhow::ensure!(cfg.workers > 0, "--workers must be > 0");
    }
    if let Some(v) = args.flag("--accept-backlog") {
        cfg.accept_backlog = v.parse()?;
        anyhow::ensure!(cfg.accept_backlog > 0, "--accept-backlog must be > 0");
    }
    if let Some(v) = args.flag("--idle-wait-ms") {
        cfg.idle_wait_ms = v.parse()?;
    }
    if let Some(v) = args.flag("--io-timeout-ms") {
        cfg.io_timeout_ms = v.parse()?;
    }
    if let Some(v) = args.flag("--max-body-kb") {
        let kb: usize = v.parse()?;
        cfg.max_body_bytes = kb
            .checked_mul(1024)
            .ok_or_else(|| anyhow::anyhow!("--max-body-kb {kb} too large"))?;
    }
    if let Some(v) = args.flag("--shards") {
        cfg.shards = v.parse()?;
        anyhow::ensure!(cfg.shards > 0, "--shards must be > 0");
    }
    if let Some(v) = args.flag("--route") {
        cfg.route_policy = RoutePolicy::parse(&v)?;
    }
    if let Some(v) = args.flag("--imbalance") {
        cfg.imbalance_factor = v.parse()?;
        anyhow::ensure!(cfg.imbalance_factor >= 1.0, "--imbalance must be >= 1.0");
    }
    if let Some(v) = args.flag("--migrate") {
        cfg.migrate = parse_on_off("--migrate", &v)?;
    }
    if let Some(v) = args.flag("--migrate-gbps") {
        let gbps: f64 = v.parse()?;
        anyhow::ensure!(gbps > 0.0, "--migrate-gbps must be > 0");
        cfg.migration_bandwidth_bytes_per_s = gbps * 1e9;
    }
    if let Some(v) = args.flag("--migrate-max-inflight") {
        cfg.migration_max_inflight = v.parse()?;
        anyhow::ensure!(
            cfg.migration_max_inflight > 0,
            "--migrate-max-inflight must be > 0"
        );
    }
    if let Some(v) = args.flag("--replicate") {
        cfg.replicate = parse_on_off("--replicate", &v)?;
    }
    if let Some(v) = args.flag("--replicate-miss") {
        cfg.replicate_miss_threshold = v.parse()?;
        anyhow::ensure!(
            cfg.replicate_miss_threshold > 0,
            "--replicate-miss must be > 0"
        );
    }
    if let Some(v) = args.flag("--replicate-window") {
        cfg.replicate_window = v.parse()?;
        anyhow::ensure!(cfg.replicate_window > 0, "--replicate-window must be > 0");
    }
    if let Some(v) = args.flag("--replicate-min-forks") {
        cfg.replicate_min_forks = v.parse()?;
        anyhow::ensure!(
            cfg.replicate_min_forks > 0,
            "--replicate-min-forks must be > 0"
        );
    }
    if let Some(v) = args.flag("--rebalance") {
        cfg.rebalance = parse_on_off("--rebalance", &v)?;
    }
    if let Some(v) = args.flag("--rebalance-ms") {
        cfg.rebalance_interval_ms = v.parse()?;
        anyhow::ensure!(cfg.rebalance_interval_ms > 0, "--rebalance-ms must be > 0");
    }
    if let Some(v) = args.flag("--lend-max") {
        cfg.lend_max_frac = v.parse()?;
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.lend_max_frac),
            "--lend-max must be in [0, 1]"
        );
    }
    if let Some(v) = args.flag("--tier") {
        cfg.tier = parse_on_off("--tier", &v)?;
    }
    if let Some(v) = args.flag("--tier-compact-ms") {
        cfg.tier_compact_ms = v.parse()?;
    }
    if let Some(v) = args.flag("--prefetch") {
        cfg.prefetch = parse_on_off("--prefetch", &v)?;
    }
    if let Some(v) = args.flag("--prefetch-horizon") {
        cfg.prefetch_horizon = v.parse()?;
        anyhow::ensure!(cfg.prefetch_horizon > 0, "--prefetch-horizon must be > 0");
    }
    if let Some(v) = args.flag("--prefetch-abandon-ms") {
        cfg.prefetch_abandon_ms = v.parse()?;
        anyhow::ensure!(
            cfg.prefetch_abandon_ms > 0,
            "--prefetch-abandon-ms must be > 0"
        );
    }
    if let Some(v) = args.flag("--prefetch-tick-ms") {
        cfg.prefetch_tick_ms = v.parse()?;
    }
    if let Some(v) = args.flag("--journal") {
        cfg.journal = parse_on_off("--journal", &v)?;
    }
    if let Some(v) = args.flag("--journal-dir") {
        anyhow::ensure!(!v.is_empty(), "--journal-dir must not be empty");
        cfg.journal_dir = v;
    }
    if let Some(v) = args.flag("--journal-sync-ms") {
        cfg.journal_sync_ms = v.parse()?;
    }
    if let Some(v) = args.flag("--journal-sync-kb") {
        let kb: usize = v.parse()?;
        anyhow::ensure!(kb > 0, "--journal-sync-kb must be > 0");
        cfg.journal_sync_bytes = kb << 10;
    }
    if let Some(v) = args.flag("--journal-seg-kb") {
        let kb: usize = v.parse()?;
        anyhow::ensure!(kb > 0, "--journal-seg-kb must be > 0");
        cfg.journal_segment_bytes = kb << 10;
    }
    if let Some(v) = args.flag("--checkpoint-ms") {
        cfg.checkpoint_ms = v.parse()?;
    }
    Ok(cfg)
}

fn engine_config(args: &Args) -> anyhow::Result<EngineConfig> {
    let policy =
        CachePolicy::parse(&args.flag("--policy").unwrap_or_else(|| "forkkv".into()))?;
    let budget_mb: usize = args
        .flag("--budget-mb")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(160);
    let seed: u64 = args.flag("--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);
    let mut cfg = EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20, capacity_bytes: 0 },
        seed,
        ..EngineConfig::default()
    };
    if let Some(v) = args.flag("--gang") {
        cfg.sched.gang = parse_on_off("--gang", &v)?;
    }
    if let Some(v) = args.flag("--gang-hold-ms") {
        cfg.sched.gang_hold_ms = v.parse()?;
    }
    // the host-memory tier: armed by --tier on, sized by --tier-mb
    // (pool-wide; shard_slice splits it exactly like the byte budget)
    let tier_on = args
        .flag("--tier")
        .map(|v| parse_on_off("--tier", &v))
        .transpose()?
        .unwrap_or(false);
    let tier_mb: usize = args
        .flag("--tier-mb")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(64);
    cfg.tier.tier_bytes = if tier_on { tier_mb << 20 } else { 0 };
    Ok(cfg)
}

/// Feed `forkkv calibrate`'s measured cost model (real FLOP terms + the
/// memcpy bandwidth probes) into the server's migrate-vs-recompute
/// decision and the engines' promote-vs-recompute decision (the
/// host-tier pricing — which is why this must run before the shards are
/// built). No calibration file, no entry for this model, or a parse
/// failure all silently keep the derived defaults; an explicit
/// `--migrate-gbps` flag still overrides the calibrated bandwidth.
fn apply_calibration(
    scfg: &mut ServerConfig,
    ecfg: &mut EngineConfig,
    args: &Args,
    cal_dir: &Path,
    model: &str,
) {
    let path = cal_dir.join("calibration.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        return;
    };
    let Ok(j) = forkkv::util::json::parse(&text) else {
        return;
    };
    let Some(per_model) = j.get(model) else {
        return;
    };
    let Ok(mut cost) = CostModel::from_json(per_model) else {
        return;
    };
    if args.flag("--migrate-gbps").is_some() {
        cost.migration_bandwidth_bytes_per_s = scfg.migration_bandwidth_bytes_per_s;
    } else {
        scfg.migration_bandwidth_bytes_per_s = cost.migration_bandwidth_bytes_per_s;
    }
    eprintln!(
        "cost model for {model} calibrated from {} ({:.2e} FLOP/s, migrate {:.2e} B/s, tier {:.2e} B/s)",
        path.display(),
        cost.sustained_flops,
        cost.migration_bandwidth_bytes_per_s,
        cost.tier_bandwidth_bytes_per_s
    );
    ecfg.tier.cost = Some(cost.clone());
    scfg.migration_cost = Some(cost);
}

/// Build the engine shard pool: `shards` peer engines, each owning a
/// 1/N slice of the byte budget (and its own executor built by `mk`).
fn build_shards(
    cfg: &EngineConfig,
    shards: usize,
    mut mk: impl FnMut() -> anyhow::Result<Box<dyn Executor>>,
) -> anyhow::Result<Vec<Engine>> {
    (0..shards)
        .map(|i| Engine::new(cfg.shard_slice(i, shards), mk()?))
        .collect()
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(
        args.flag("--artifacts")
            .unwrap_or_else(|| "artifacts/llama3-8b-sim".into()),
    );
    let addr = args
        .flag("--addr")
        .unwrap_or_else(|| "127.0.0.1:8080".into());
    let mut cfg = engine_config(args)?;
    let mut scfg = server_config(args)?;
    eprintln!("loading artifacts from {} ...", dir.display());
    // load the executors before constructing engines: the model name
    // they carry selects the calibration entry, and the calibrated cost
    // model must reach the engine config (tier pricing) pre-construction
    let mut execs = Vec::with_capacity(scfg.shards.max(1));
    for _ in 0..scfg.shards.max(1) {
        execs.push(Box::new(PjrtExecutor::load(&dir)?) as Box<dyn Executor>);
    }
    // calibrate writes calibration.json next to the per-model artifact
    // dirs (the parent of --artifacts here)
    let model = execs[0].meta().name.clone();
    if let Some(parent) = dir.parent() {
        apply_calibration(&mut scfg, &mut cfg, args, parent, &model);
    }
    let shards = execs.len();
    let engines = execs
        .into_iter()
        .enumerate()
        .map(|(i, exec)| Engine::new(cfg.shard_slice(i, shards), exec))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let (server, handles) = Server::start_sharded(engines, scfg);
    server.serve_http(&addr, None)?;
    server.shutdown();
    for h in handles {
        h.join().ok();
    }
    Ok(())
}

/// Closed-loop concurrent HTTP benchmark over the sim backend: stands up a
/// wall-paced sim shard pool on an ephemeral port, drives it with either N
/// plain closed-loop clients or (with `--workflows`) K workflows of M
/// agents forking shared contexts, and reports client-side latency plus
/// each shard's decode-batch occupancy — the direct measurement of
/// front-end concurrency and router placement quality.
fn cmd_bench_http(args: &Args) -> anyhow::Result<()> {
    let mut cfg = engine_config(args)?;
    let mut scfg = server_config(args)?;
    let model = args
        .flag("--model")
        .unwrap_or_else(|| "llama3-8b-sim".into());
    apply_calibration(&mut scfg, &mut cfg, args, Path::new("artifacts"), &model);
    let clients: usize = args.flag("--clients").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let per_client: usize = args
        .flag("--requests-per-client")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let max_new: usize = args.flag("--max-new").map(|v| v.parse()).transpose()?.unwrap_or(32);
    let pace_us: u64 = args.flag("--pace-us").map(|v| v.parse()).transpose()?.unwrap_or(500);
    let workflows: Option<usize> = args.flag("--workflows").map(|v| v.parse()).transpose()?;
    let agents: usize = args
        .flag("--agents-per-workflow")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(3);
    let hot_agents: Option<usize> = args.flag("--hot-agents").map(|v| v.parse()).transpose()?;
    let stagger_ms: u64 = args
        .flag("--stagger-ms")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(4);
    let waves: usize = args.flag("--waves").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let unique_words: Option<usize> =
        args.flag("--unique-words").map(|v| v.parse()).transpose()?;
    let hot_pad_words: usize = args
        .flag("--hot-pad-words")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(0);
    let fan_parallel = args.has("--fan-parallel");
    let dag: Option<DagTopology> = args
        .flag("--dag")
        .map(|v| DagTopology::parse(&v))
        .transpose()?;
    let sessions: Option<usize> = args.flag("--sessions").map(|v| v.parse()).transpose()?;
    let visits: usize = args.flag("--visits").map(|v| v.parse()).transpose()?.unwrap_or(3);
    let session_words: usize = args
        .flag("--session-words")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(160);
    let fault_after_ms: Option<u64> = args
        .flag("--fault-kill-shard-after-ms")
        .map(|v| v.parse())
        .transpose()?;
    let fault_shard: usize = args
        .flag("--fault-kill-shard")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(0);

    let policy = cfg.policy;
    let gang = cfg.sched.gang;
    let page_tokens = cfg.cache.page_tokens;
    let engines = build_shards(&cfg, scfg.shards, || {
        let sim = SimExecutor::new(&model, presets::SIM_BUCKETS.to_vec())?
            .with_wall_pace_us(pace_us);
        Ok(Box::new(sim) as Box<dyn Executor>)
    })?;
    // the DAG harness mirrors the router's placement function so it can
    // pin successor steps onto different shards than their predecessors
    let vocab = engines[0].meta().vocab;
    let (server, shard_handles) = Server::start_sharded(engines, scfg);

    let listener = std::net::TcpListener::bind(
        args.flag("--addr")
            .unwrap_or_else(|| "127.0.0.1:0".into()),
    )?;
    let addr = listener.local_addr()?.to_string();
    match (dag, sessions, hot_agents, workflows) {
        (Some(t), _, _, _) => eprintln!(
            "bench-http: {} DAG workflows ({} wide, topology {}) over {} shard(s), \
             prefetch={} -> http://{addr}",
            workflows.unwrap_or(6),
            agents,
            t.name(),
            server.config().shards,
            server.config().prefetch,
        ),
        (None, Some(n), _, _) => eprintln!(
            "bench-http: {n} returning sessions x {visits} visits ({session_words} context \
             words), tier={} -> http://{addr}",
            server.config().tier,
        ),
        (None, None, Some(n), _) => eprintln!(
            "bench-http: skewed load, {n} hot agents (+{} cold) over {} shard(s), \
             migrate={} -> http://{addr}",
            workflows.unwrap_or(3),
            server.config().shards,
            server.config().migrate,
        ),
        (None, None, None, Some(k)) => eprintln!(
            "bench-http: {k} workflows x {agents} agents over {} shard(s) -> http://{addr}",
            server.config().shards
        ),
        (None, None, None, None) => eprintln!(
            "bench-http: {clients} clients x {per_client} requests over {} shard(s) -> http://{addr}",
            server.config().shards
        ),
    }
    // serve unbounded on a detached thread: the load below completes only
    // once every client got its response, and capping the accept count
    // would hang the bench if any connect attempt failed (those are
    // counted as errors in the report instead)
    let _serve = {
        let server = server.clone();
        std::thread::spawn(move || server.serve_listener(listener, None))
    };

    // the fault injector: after the grace period, crash the victim shard
    // over the same HTTP surface the bench drives (waiting for it to hold
    // an in-flight request so the journal replay path demonstrably runs)
    let killer = fault_after_ms.map(|after_ms| {
        anyhow::ensure!(
            fault_shard < server.config().shards,
            "--fault-kill-shard {fault_shard} out of range ({} shards)",
            server.config().shards
        );
        eprintln!(
            "bench-http: fault injector armed — killing shard {fault_shard} after {after_ms}ms"
        );
        Ok(spawn_http_shard_killer(&addr, fault_shard, after_ms, 1, 2_000))
    });
    let killer = killer.transpose()?;

    let mut report = match (dag, sessions, hot_agents, workflows) {
        (Some(topology), _, _, _) => {
            let spec = DagWorkflowHttpSpec {
                topology,
                workflows: workflows.unwrap_or(6),
                width: agents,
                max_new,
                shards: server.config().shards,
                page_tokens,
                vocab,
                ..DagWorkflowHttpSpec::default()
            };
            run_dag_load(&addr, &spec)?
        }
        (None, Some(n), _, _) => {
            let spec = ReturningSessionsHttpSpec {
                sessions: n,
                visits,
                session_words,
                max_new,
                ..ReturningSessionsHttpSpec::default()
            };
            run_returning_sessions_load(&addr, &spec)?
        }
        (None, None, Some(n), _) => {
            let mut spec = SkewedWorkflowHttpSpec {
                hot_agents: n,
                stagger_ms,
                cold_workflows: workflows.unwrap_or(3),
                max_new,
                waves,
                hot_pad_words,
                ..SkewedWorkflowHttpSpec::default()
            };
            if let Some(u) = unique_words {
                spec.unique_words = u;
            }
            run_skewed_workflow_load(&addr, &spec)?
        }
        (None, None, None, Some(k)) => {
            let spec = MultiWorkflowHttpSpec {
                workflows: k,
                agents_per_workflow: agents,
                max_new,
                parallel: fan_parallel,
                ..MultiWorkflowHttpSpec::default()
            };
            run_multi_workflow_load(&addr, &spec)?
        }
        (None, None, None, None) => {
            let spec = HttpLoadSpec {
                clients,
                requests_per_client: per_client,
                max_new,
                ..HttpLoadSpec::default()
            };
            run_http_load(&addr, &spec)?
        }
    };
    if let Json::Obj(m) = &mut report {
        // one snapshot for both views, so the aggregate always equals the
        // sum of the per-shard entries even if stragglers are still active
        let per_shard = server.shard_stats()?;
        m.insert("engine".into(), forkkv::metrics::aggregate_stats(&per_shard));
        m.insert("per_shard".into(), Json::Arr(per_shard));
        m.insert(
            "route".into(),
            Json::str(server.config().route_policy.name()),
        );
        m.insert("router".into(), server.router_stats());
        m.insert("rebalancer".into(), server.rebalancer_stats());
        m.insert("tier".into(), server.tier_stats());
        m.insert("prefetch".into(), server.prefetch_stats());
        m.insert("replication".into(), server.replication_stats());
        m.insert("journal".into(), server.journal_stats());
        m.insert("locks".into(), server.lock_stats());
        m.insert("policy".into(), Json::str(policy.name()));
        m.insert("gang".into(), Json::Bool(gang));
        m.insert("workers".into(), Json::num(server.config().workers as f64));
        m.insert("pace_us".into(), Json::num(pace_us as f64));
    }
    if let Some(k) = killer {
        if let Some(kill) = k.join().ok().flatten() {
            if let Json::Obj(m) = &mut report {
                m.insert("fault".into(), kill);
            }
        }
    }
    server.shutdown();
    for h in shard_handles {
        h.join().ok();
    }
    println!("{report}");
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = engine_config(args)?;
    let model = args
        .flag("--model")
        .unwrap_or_else(|| "llama3-8b-sim".into());
    let dataset = args.flag("--dataset").unwrap_or_else(|| "loogle".into());
    let workflows: usize = args
        .flag("--workflows")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(8);
    let requests: usize = args
        .flag("--requests")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(32);
    let rate: f64 = args.flag("--rate").map(|v| v.parse()).transpose()?.unwrap_or(2.0);
    let kind = match args.flag("--workflow").as_deref() {
        Some("mapreduce") => WorkflowKind::MapReduce { n_mappers: 6 },
        _ => WorkflowKind::ReAct { n_agents: 4 },
    };

    let budget_mb = cfg.cache.budget_bytes >> 20;
    let (mut engine, mut spec) = if args.has("--real") {
        let dir = PathBuf::from(
            args.flag("--artifacts")
                .unwrap_or_else(|| format!("artifacts/{model}")),
        );
        let exec = PjrtExecutor::load(&dir)?;
        let spec = WorkloadSpec::standard(&dataset, kind, workflows);
        (Engine::new(cfg, Box::new(exec))?, spec)
    } else {
        let engine = presets::paper_sim_engine(&model, cfg.policy, budget_mb, 16, cfg.seed)?;
        let spec = WorkloadSpec::paper(&dataset, kind, workflows, requests);
        (engine, spec)
    };
    spec.n_requests = requests;
    spec.arrival_rate = rate;
    let mut driver = WorkflowDriver::new(spec);
    engine.run_driver(&mut driver)?;
    let mut report = driver.report();
    if let Json::Obj(m) = &mut report {
        m.insert("engine".into(), engine.stats_json());
        m.insert("policy".into(), Json::str(engine.cfg.policy.name()));
        m.insert("gang".into(), Json::Bool(engine.cfg.sched.gang));
    }
    println!("{report}");
    Ok(())
}

/// Measured host copy bandwidth (bytes/s): the rate at which one shard's
/// page bytes move into a peer pool on this machine — the denominator of
/// the migrate-vs-recompute decision (`CostModel::migrate_cost_us`).
fn measure_copy_bandwidth() -> f64 {
    let src = vec![1.0f32; 4 << 20]; // 16 MiB
    let mut dst = vec![0.0f32; 4 << 20];
    let reps = 8;
    let t = std::time::Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&mut dst);
    }
    let secs = t.elapsed().as_secs_f64().max(1e-9);
    (src.len() * 4 * reps) as f64 / secs
}

/// Measured host-tier copy bandwidth (bytes/s): the rate at which page
/// bytes move between a pool and the host-memory `TierStore` — the
/// denominator of the promote-vs-recompute decision
/// (`CostModel::tier_cost_us`). A larger working set than the migration
/// probe (64 MiB vs 16 MiB) so the figure reflects DRAM streaming, not
/// last-level cache reuse: demoted pages are cold by definition.
fn measure_tier_bandwidth() -> f64 {
    let src = vec![1.0f32; 16 << 20]; // 64 MiB
    let mut dst = vec![0.0f32; 16 << 20];
    let reps = 4;
    let t = std::time::Instant::now();
    for _ in 0..reps {
        dst.copy_from_slice(&src);
        std::hint::black_box(&mut dst);
    }
    let secs = t.elapsed().as_secs_f64().max(1e-9);
    (src.len() * 4 * reps) as f64 / secs
}

/// Measure real per-op costs and write artifacts/calibration.json so the
/// sim cost model reflects this machine (EXPERIMENTS.md §Calibration).
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let base =
        PathBuf::from(args.flag("--artifacts").unwrap_or_else(|| "artifacts".into()));
    let mut out = std::collections::BTreeMap::new();
    for entry in std::fs::read_dir(&base)? {
        let dir = entry?.path();
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let mut exec = PjrtExecutor::load(&dir)?;
        let meta = exec.meta().clone();
        eprintln!("calibrating {} ...", meta.name);
        let (l, s) = (meta.n_layers, meta.s_max);
        let (kvw, r) = (meta.kv_width(), meta.rank_max);
        let kb = vec![0.0f32; l * s * kvw];
        let kr = vec![0.0f32; l * s * r];
        let tokens: Vec<u32> = (0..meta.chunk as u32).map(|i| 2 + i % 100).collect();

        // prefill cost (warm): median of 5 (first call includes warmup)
        let mut prefill_us = Vec::new();
        for _ in 0..6 {
            let a = PrefillArgs {
                tokens: &tokens,
                cache_len: 0,
                adapter_id: 1,
                adapter_on: true,
                kb: &kb,
                vb: &kb,
                kr: &kr,
                vr: &kr,
            };
            prefill_us.push(exec.prefill(&a)?.elapsed_us);
        }
        prefill_us.sort_unstable();
        let prefill_med = prefill_us[prefill_us.len() / 2];

        // derive sustained FLOP/s from the measured chunk
        let mut cost = CostModel::derived(&meta);
        let model_flops = cost.flops_per_token * meta.chunk as f64
            + cost.attn_flops_per_qk * (meta.chunk * meta.s_max) as f64;
        cost.sustained_flops = model_flops / (prefill_med as f64 / 1e6);
        cost.dispatch_us = (prefill_med / 10).max(200);
        // inter-shard page-copy bandwidth: shards live in one process on
        // this substrate, so migration moves at host memcpy speed
        cost.migration_bandwidth_bytes_per_s = measure_copy_bandwidth();
        // host-tier demote/promote bandwidth (the promote-vs-recompute
        // denominator); calibration files predating the tier load with
        // the derived default, so this field is additive
        cost.tier_bandwidth_bytes_per_s = measure_tier_bandwidth();
        out.insert(meta.name.clone(), cost.to_json());
        eprintln!(
            "  chunk={}us sustained={:.2e} FLOP/s migrate={:.2e} B/s tier={:.2e} B/s",
            prefill_med,
            cost.sustained_flops,
            cost.migration_bandwidth_bytes_per_s,
            cost.tier_bandwidth_bytes_per_s
        );
    }
    let j = Json::Obj(out.into_iter().collect());
    let path = base.join("calibration.json");
    std::fs::write(&path, j.to_string())?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
