//! forkkv CLI: serve, run workloads, calibrate the sim cost model.
//!
//! Hand-rolled argument parsing (no clap in the offline vendor set).

use std::path::PathBuf;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::Engine;
use forkkv::exec::{CostModel, Executor, PjrtExecutor};
use forkkv::runtime::PrefillArgs;
use forkkv::server::Server;
use forkkv::util::json::Json;
use forkkv::workload::{presets, WorkflowDriver, WorkflowKind, WorkloadSpec};

fn usage() -> ! {
    eprintln!(
        "forkkv — multi-LoRA agent serving with a CoW disaggregated KV cache

USAGE:
  forkkv serve     [--artifacts DIR] [--addr HOST:PORT] [--policy P] [--budget-mb N]
  forkkv run       [--policy P] [--model M] [--dataset D] [--workflow react|mapreduce]
                   [--workflows N] [--requests N] [--rate R] [--budget-mb N] [--seed S]
                   [--real --artifacts DIR]
  forkkv calibrate [--artifacts DIR]   # measure real PJRT costs -> calibration.json

  P: forkkv | prefix | full-reuse      M: llama3-8b-sim | qwen2.5-7b-sim | qwen2.5-14b-sim
  D: loogle | narrativeqa | apigen"
    );
    std::process::exit(2);
}

struct Args(Vec<String>);

impl Args {
    fn flag(&self, name: &str) -> Option<String> {
        self.0
            .iter()
            .position(|a| a == name)
            .and_then(|i| self.0.get(i + 1).cloned())
    }
    fn has(&self, name: &str) -> bool {
        self.0.iter().any(|a| a == name)
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else { usage() };
    let args = Args(argv[1..].to_vec());
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "calibrate" => cmd_calibrate(&args),
        _ => usage(),
    }
}

fn engine_config(args: &Args) -> anyhow::Result<EngineConfig> {
    let policy = CachePolicy::parse(&args.flag("--policy").unwrap_or("forkkv".into()))?;
    let budget_mb: usize = args
        .flag("--budget-mb")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(160);
    let seed: u64 = args.flag("--seed").map(|v| v.parse()).transpose()?.unwrap_or(42);
    Ok(EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20 },
        seed,
        ..EngineConfig::default()
    })
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(
        args.flag("--artifacts")
            .unwrap_or("artifacts/llama3-8b-sim".into()),
    );
    let addr = args.flag("--addr").unwrap_or("127.0.0.1:8080".into());
    let cfg = engine_config(args)?;
    eprintln!("loading artifacts from {} ...", dir.display());
    let exec = PjrtExecutor::load(&dir)?;
    let engine = Engine::new(cfg, Box::new(exec))?;
    let (server, handle) = Server::start(engine);
    server.serve_http(&addr, None)?;
    server.shutdown();
    handle.join().ok();
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = engine_config(args)?;
    let model = args.flag("--model").unwrap_or("llama3-8b-sim".into());
    let dataset = args.flag("--dataset").unwrap_or("loogle".into());
    let workflows: usize = args
        .flag("--workflows")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(8);
    let requests: usize = args
        .flag("--requests")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(32);
    let rate: f64 = args.flag("--rate").map(|v| v.parse()).transpose()?.unwrap_or(2.0);
    let kind = match args.flag("--workflow").as_deref() {
        Some("mapreduce") => WorkflowKind::MapReduce { n_mappers: 6 },
        _ => WorkflowKind::ReAct { n_agents: 4 },
    };

    let budget_mb = cfg.cache.budget_bytes >> 20;
    let (mut engine, mut spec) = if args.has("--real") {
        let dir = PathBuf::from(
            args.flag("--artifacts")
                .unwrap_or(format!("artifacts/{model}")),
        );
        let exec = PjrtExecutor::load(&dir)?;
        let spec = WorkloadSpec::standard(&dataset, kind, workflows);
        (Engine::new(cfg, Box::new(exec))?, spec)
    } else {
        let engine = presets::paper_sim_engine(&model, cfg.policy, budget_mb, 16, cfg.seed)?;
        let spec = WorkloadSpec::paper(&dataset, kind, workflows, requests);
        (engine, spec)
    };
    spec.n_requests = requests;
    spec.arrival_rate = rate;
    let mut driver = WorkflowDriver::new(spec);
    engine.run_driver(&mut driver)?;
    let mut report = driver.report();
    if let Json::Obj(m) = &mut report {
        m.insert("engine".into(), engine.metrics.to_json());
        m.insert("policy".into(), Json::str(engine.cfg.policy.name()));
    }
    println!("{}", report.to_string());
    Ok(())
}

/// Measure real per-op costs and write artifacts/calibration.json so the
/// sim cost model reflects this machine (EXPERIMENTS.md §Calibration).
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let base = PathBuf::from(args.flag("--artifacts").unwrap_or("artifacts".into()));
    let mut out = std::collections::BTreeMap::new();
    for entry in std::fs::read_dir(&base)? {
        let dir = entry?.path();
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let mut exec = PjrtExecutor::load(&dir)?;
        let meta = exec.meta().clone();
        eprintln!("calibrating {} ...", meta.name);
        let (l, s) = (meta.n_layers, meta.s_max);
        let (kvw, r) = (meta.kv_width(), meta.rank_max);
        let kb = vec![0.0f32; l * s * kvw];
        let kr = vec![0.0f32; l * s * r];
        let tokens: Vec<u32> = (0..meta.chunk as u32).map(|i| 2 + i % 100).collect();

        // prefill cost (warm): median of 5 (first call includes warmup)
        let mut prefill_us = Vec::new();
        for _ in 0..6 {
            let a = PrefillArgs {
                tokens: &tokens,
                cache_len: 0,
                adapter_id: 1,
                adapter_on: true,
                kb: &kb,
                vb: &kb,
                kr: &kr,
                vr: &kr,
            };
            prefill_us.push(exec.prefill(&a)?.elapsed_us);
        }
        prefill_us.sort_unstable();
        let prefill_med = prefill_us[prefill_us.len() / 2];

        // derive sustained FLOP/s from the measured chunk
        let mut cost = CostModel::derived(&meta);
        let model_flops = cost.flops_per_token * meta.chunk as f64
            + cost.attn_flops_per_qk * (meta.chunk * meta.s_max) as f64;
        cost.sustained_flops = model_flops / (prefill_med as f64 / 1e6);
        cost.dispatch_us = (prefill_med / 10).max(200);
        out.insert(meta.name.clone(), cost.to_json());
        eprintln!(
            "  chunk={}us sustained={:.2e} FLOP/s",
            prefill_med, cost.sustained_flops
        );
    }
    let j = Json::Obj(out.into_iter().collect());
    let path = base.join("calibration.json");
    std::fs::write(&path, j.to_string())?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
