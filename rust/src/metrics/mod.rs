//! Engine metrics: the measurement layer behind every figure in the
//! evaluation (throughput, TTFT, cache hit rate, per-agent memory, decode
//! batch occupancy — paper Figs. 11–15).

use crate::util::json::Json;
use crate::util::stats::Series;

#[derive(Debug, Default)]
pub struct EngineMetrics {
    // step counters
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub decode_rows: u64,
    pub prefill_busy_us: u64,
    pub decode_busy_us: u64,

    // cache effectiveness (token-granular)
    pub prompt_tokens: u64,
    pub hit_full_tokens: u64,
    pub hit_partial_tokens: u64,
    pub computed_prompt_tokens: u64,

    // request outcome counters: every submitted request terminates as
    // exactly one of these (the engine<->server reply protocol relies on
    // this accounting — no silent terminal state)
    pub completed: u64,
    pub preemptions: u64,
    pub oom_drops: u64,

    /// admissions/allocations denied by the *byte budget* (counted per
    /// blocked scheduler tick, not per request): distinct from the
    /// pools' physical `alloc_failures`, this is the shard's "I am
    /// bumping my budget ceiling" signal — the pressure input the
    /// elastic-budget rebalancer reads to decide who borrows
    pub budget_denials: u64,

    /// forks first-admitted while another member of their workflow tag
    /// was already resident — the gang scheduler's co-admissions
    pub gang_admitted: u64,
    /// per-workflow-tag cache effectiveness: tag -> (prompt tokens,
    /// matched tokens). Cardinality-bounded; see
    /// [`EngineMetrics::record_tag_hit`].
    pub tag_hits: std::collections::HashMap<u64, (u64, u64)>,

    // cross-shard page migration (spill-path bandwidth-for-FLOPs trade):
    // import side — pages/bytes adopted into this shard's pool + trees,
    // and the prompt tokens those pages spare this shard from prefilling
    pub migrated_pages: u64,
    pub migrated_bytes: u64,
    pub recompute_tokens_saved: u64,
    /// export side — pages snapshotted out of this shard for a peer
    pub exported_pages: u64,

    // host-memory tier (demote-on-evict / promote-on-match):
    /// evicted pages whose bytes the tier accepted instead of destroying
    pub demoted_pages: u64,
    /// tier pages copied back into the pool ahead of a returning
    /// session's fork match
    pub promoted_pages: u64,
    /// admissions that found at least one of their pages resident in the
    /// tier (whether or not the cost model then chose to promote)
    pub tier_hits: u64,
    /// prompt tokens spared recompute because their pages were promoted
    /// from the tier (the tier's own bytes-for-FLOPs ledger, parallel to
    /// migration's `recompute_tokens_saved`)
    pub recompute_tokens_saved_tier: u64,
    /// pages rebuilt from the tier by a warm restart's checkpoint replay
    /// (`Engine::restore_checkpoint`) — the recovery path's own
    /// bytes-for-FLOPs ledger, disjoint from `promoted_pages` (admission
    /// promotion) so the two mechanisms stay separately auditable
    pub restored_pages: u64,

    // cross-step workflow prefetch (the KVFlow horizon):
    /// pages covered by prefetch leases at issue time — resident pages a
    /// lease pinned, including pages the prefetch itself promoted from
    /// the tier or imported via pre-migration
    pub prefetched_pages: u64,
    /// prefetch leases that covered at least one page and were released
    /// by the arrival of the step they were warmed for
    pub prefetch_hits: u64,
    /// pages whose prefetch lease was abandoned (the successor step
    /// never arrived before the timeout) — warmed bytes nobody used
    pub prefetch_wasted: u64,

    // decode-batch occupancy (rows per decode step) and its observed peak
    pub decode_batch: Series,
    pub max_decode_batch: u64,

    // sampled time series (one sample per engine step)
    pub base_pool_bytes: Series,
    pub res_pool_bytes: Series,
    pub active_seqs: Series,
    pub bytes_per_agent: Series,
    /// requests admitted or pending but not yet running (scheduler backlog)
    pub queue_depth: Series,
}

impl EngineMetrics {
    pub fn avg_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_rows as f64 / self.decode_steps as f64
        }
    }

    /// Fraction of prompt tokens served from cache without recompute
    /// (full hits only — the paper's "cache hit rate").
    pub fn hit_rate(&self) -> f64 {
        if self.prompt_tokens == 0 {
            0.0
        } else {
            self.hit_full_tokens as f64 / self.prompt_tokens as f64
        }
    }

    pub fn sample_memory(&mut self, base_bytes: usize, res_bytes: usize, active: usize) {
        self.base_pool_bytes.push(base_bytes as f64);
        self.res_pool_bytes.push(res_bytes as f64);
        self.active_seqs.push(active as f64);
        if active > 0 {
            self.bytes_per_agent
                .push((base_bytes + res_bytes) as f64 / active as f64);
        }
    }

    pub fn sample_queue_depth(&mut self, depth: usize) {
        self.queue_depth.push(depth as f64);
    }

    /// Record one decode step's occupancy (live rows, not the padded bucket).
    pub fn record_decode_batch(&mut self, rows: usize) {
        self.decode_batch.push(rows as f64);
        self.max_decode_batch = self.max_decode_batch.max(rows as u64);
    }

    /// Distinct workflow tags tracked individually by
    /// [`EngineMetrics::record_tag_hit`]; tags past this fold into one
    /// `other` bucket so an adversarial tag stream cannot grow the map
    /// unboundedly.
    pub const MAX_TAG_SLOTS: usize = 128;

    /// Record one first-admission's per-tag cache outcome (`prompt`
    /// tokens, of which `matched` were served from cached pages) — the
    /// per-workflow matched rate in `/metrics`. A (theoretical) real tag
    /// of `u64::MAX` shares the overflow bucket.
    pub fn record_tag_hit(&mut self, tag: u64, prompt: u64, matched: u64) {
        let slot = if self.tag_hits.contains_key(&tag)
            || self.tag_hits.len() < Self::MAX_TAG_SLOTS
        {
            tag
        } else {
            u64::MAX // overflow bucket, rendered as "other"
        };
        let e = self.tag_hits.entry(slot).or_insert((0, 0));
        e.0 += prompt;
        e.1 += matched;
    }

    /// JSON snapshot. Takes `&mut self` (unlike the `to_*` convention)
    /// because the percentile summaries sort their series in place.
    #[allow(clippy::wrong_self_convention)]
    pub fn to_json(&mut self) -> Json {
        Json::obj(vec![
            ("prefill_steps", Json::num(self.prefill_steps as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("decode_rows", Json::num(self.decode_rows as f64)),
            ("avg_decode_batch", Json::num(self.avg_decode_batch())),
            ("prefill_busy_us", Json::num(self.prefill_busy_us as f64)),
            ("decode_busy_us", Json::num(self.decode_busy_us as f64)),
            ("prompt_tokens", Json::num(self.prompt_tokens as f64)),
            ("hit_full_tokens", Json::num(self.hit_full_tokens as f64)),
            ("hit_partial_tokens", Json::num(self.hit_partial_tokens as f64)),
            ("computed_prompt_tokens", Json::num(self.computed_prompt_tokens as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
            ("completed", Json::num(self.completed as f64)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("oom_drops", Json::num(self.oom_drops as f64)),
            ("budget_denials", Json::num(self.budget_denials as f64)),
            ("gang_admitted", Json::num(self.gang_admitted as f64)),
            ("per_tag", self.per_tag_json()),
            ("migrated_pages", Json::num(self.migrated_pages as f64)),
            ("migrated_bytes", Json::num(self.migrated_bytes as f64)),
            (
                "recompute_tokens_saved",
                Json::num(self.recompute_tokens_saved as f64),
            ),
            ("exported_pages", Json::num(self.exported_pages as f64)),
            ("demoted_pages", Json::num(self.demoted_pages as f64)),
            ("promoted_pages", Json::num(self.promoted_pages as f64)),
            ("tier_hits", Json::num(self.tier_hits as f64)),
            (
                "recompute_tokens_saved_tier",
                Json::num(self.recompute_tokens_saved_tier as f64),
            ),
            ("restored_pages", Json::num(self.restored_pages as f64)),
            ("prefetched_pages", Json::num(self.prefetched_pages as f64)),
            ("prefetch_hits", Json::num(self.prefetch_hits as f64)),
            ("prefetch_wasted", Json::num(self.prefetch_wasted as f64)),
            ("decode_batch", self.decode_batch.summary().to_json()),
            ("max_decode_batch", Json::num(self.max_decode_batch as f64)),
            ("base_pool_bytes", self.base_pool_bytes.summary().to_json()),
            ("res_pool_bytes", self.res_pool_bytes.summary().to_json()),
            ("bytes_per_agent", self.bytes_per_agent.summary().to_json()),
            ("active_seqs", self.active_seqs.summary().to_json()),
            ("queue_depth", self.queue_depth.summary().to_json()),
        ])
    }

    /// The per-workflow-tag matched-rate object served inside each shard
    /// snapshot (`per_tag` key). Percentages don't compose across shards,
    /// so like the series summaries this stays per-shard only.
    fn per_tag_json(&self) -> Json {
        let mut tags = std::collections::BTreeMap::new();
        for (&tag, &(prompt, matched)) in &self.tag_hits {
            let label = if tag == u64::MAX {
                "other".to_string()
            } else {
                tag.to_string()
            };
            tags.insert(
                label,
                Json::obj(vec![
                    ("prompt_tokens", Json::num(prompt as f64)),
                    ("matched_tokens", Json::num(matched as f64)),
                    (
                        "matched_rate",
                        Json::num(if prompt > 0 {
                            matched as f64 / prompt as f64
                        } else {
                            0.0
                        }),
                    ),
                ]),
            );
        }
        Json::Obj(tags)
    }
}

/// Keys summed across shards by [`aggregate_stats`]. Series summaries are
/// deliberately absent: percentiles don't compose across shards, so those
/// stay in the per-shard snapshots.
const SUMMED_KEYS: [&str; 30] = [
    "prefill_steps",
    "decode_steps",
    "decode_rows",
    "prefill_busy_us",
    "decode_busy_us",
    "prompt_tokens",
    "hit_full_tokens",
    "hit_partial_tokens",
    "computed_prompt_tokens",
    "completed",
    "preemptions",
    "oom_drops",
    "budget_denials",
    // per-shard elastic budgets: the aggregate is the pool total, which
    // the rebalancer conserves (always equals the configured budget)
    "budget_bytes",
    "gang_admitted",
    "evictions_deferred",
    "migrated_pages",
    "migrated_bytes",
    "recompute_tokens_saved",
    "exported_pages",
    "demoted_pages",
    "promoted_pages",
    "tier_hits",
    "recompute_tokens_saved_tier",
    "restored_pages",
    "prefetched_pages",
    "prefetch_hits",
    "prefetch_wasted",
    // per-shard tier gauges (stats_json inserts them next to
    // budget_bytes): the aggregate is the pool-wide tier footprint
    "tier_bytes",
    "tier_budget_bytes",
];

/// Combine per-shard stats snapshots (as produced by
/// [`EngineMetrics::to_json`]) into pool-level totals: counters sum,
/// `max_decode_batch` takes the max, and the ratio metrics
/// (`avg_decode_batch`, `hit_rate`, `matched_rate`) are re-derived from the
/// summed numerators/denominators — averaging per-shard ratios would weight
/// an idle shard the same as a saturated one.
pub fn aggregate_stats(shards: &[Json]) -> Json {
    fn sum(shards: &[Json], key: &str) -> f64 {
        shards
            .iter()
            .filter_map(|s| s.get(key).and_then(Json::as_f64))
            .sum()
    }
    let mut pairs: Vec<(&str, Json)> =
        vec![("shards", Json::num(shards.len() as f64))];
    for key in SUMMED_KEYS {
        pairs.push((key, Json::num(sum(shards, key))));
    }
    let decode_steps = sum(shards, "decode_steps");
    let decode_rows = sum(shards, "decode_rows");
    pairs.push((
        "avg_decode_batch",
        Json::num(if decode_steps > 0.0 { decode_rows / decode_steps } else { 0.0 }),
    ));
    pairs.push((
        "max_decode_batch",
        Json::num(
            shards
                .iter()
                .filter_map(|s| s.get("max_decode_batch").and_then(Json::as_f64))
                .fold(0.0, f64::max),
        ),
    ));
    let prompt = sum(shards, "prompt_tokens");
    let hit_full = sum(shards, "hit_full_tokens");
    let hit_partial = sum(shards, "hit_partial_tokens");
    pairs.push((
        "hit_rate",
        Json::num(if prompt > 0.0 { hit_full / prompt } else { 0.0 }),
    ));
    // fraction of prompt tokens served from *any* cached pages (base or
    // residual) — the router's figure of merit: affinity placement raises
    // this, round-robin scatters it
    pairs.push((
        "matched_rate",
        Json::num(if prompt > 0.0 { (hit_full + hit_partial) / prompt } else { 0.0 }),
    ));
    Json::obj(pairs)
}

/// Per-request outcome, the unit the workload drivers aggregate.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub tag: u64,
    pub adapter: u32,
    pub prompt_len: usize,
    pub generated: Vec<u32>,
    pub arrival_us: u64,
    pub first_token_us: u64,
    pub finish_us: u64,
    pub hit_full: usize,
    pub hit_partial: usize,
    pub computed_prompt: usize,
    pub preemptions: u32,
    /// logits of the first generated token (quality experiments)
    pub first_logits: Option<Vec<f32>>,
}

impl FinishedRequest {
    pub fn ttft_us(&self) -> u64 {
        self.first_token_us.saturating_sub(self.arrival_us)
    }
    pub fn latency_us(&self) -> u64 {
        self.finish_us.saturating_sub(self.arrival_us)
    }
}

/// Why the engine evicted a request without completing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// memory deadlock breaker: every schedulable unit was blocked on pages
    /// held by blocked sequences, and this request was the chosen victim
    OutOfMemory,
    /// the shard serving the request died and no live peer could replay
    /// it (journal off, or the whole pool is dead) — the terminal state
    /// that replaces an infinite client wait
    ShardLost,
}

impl DropReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            DropReason::OutOfMemory => "out of memory",
            DropReason::ShardLost => "shard lost",
        }
    }
}

/// A request the engine gave up on. Carries enough identity for the serving
/// layer to route the failure back to the right waiter.
#[derive(Debug, Clone)]
pub struct DroppedRequest {
    pub id: u64,
    pub tag: u64,
    pub adapter: u32,
    pub prompt_len: usize,
    pub arrival_us: u64,
    pub drop_us: u64,
    pub reason: DropReason,
}

/// Every terminal engine state for a request — completion (max_new or EOS)
/// or an engine-initiated drop. The server replies to its waiter with
/// exactly one of these, so no client ever blocks forever.
#[derive(Debug, Clone)]
pub enum RequestOutcome {
    Finished(FinishedRequest),
    Dropped(DroppedRequest),
}

impl RequestOutcome {
    pub fn id(&self) -> u64 {
        match self {
            RequestOutcome::Finished(f) => f.id,
            RequestOutcome::Dropped(d) => d.id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let m = EngineMetrics {
            decode_steps: 4,
            decode_rows: 14,
            prompt_tokens: 100,
            hit_full_tokens: 40,
            ..EngineMetrics::default()
        };
        assert!((m.avg_decode_batch() - 3.5).abs() < 1e-9);
        assert!((m.hit_rate() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn memory_sampling_per_agent() {
        let mut m = EngineMetrics::default();
        m.sample_memory(1000, 200, 4);
        m.sample_memory(2000, 200, 2);
        assert_eq!(m.bytes_per_agent.len(), 2);
        assert!((m.bytes_per_agent.mean() - (300.0 + 1100.0) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn decode_batch_and_queue_depth_tracking() {
        let mut m = EngineMetrics::default();
        m.record_decode_batch(1);
        m.record_decode_batch(6);
        m.record_decode_batch(3);
        assert_eq!(m.max_decode_batch, 6);
        assert_eq!(m.decode_batch.len(), 3);
        m.sample_queue_depth(5);
        m.sample_queue_depth(0);
        assert_eq!(m.queue_depth.len(), 2);
        let j = m.to_json();
        assert_eq!(j.at(&["max_decode_batch"]).as_usize().unwrap(), 6);
        assert_eq!(j.at(&["queue_depth", "n"]).as_usize().unwrap(), 2);
    }

    #[test]
    fn aggregate_sums_counters_and_rederives_ratios() {
        let mut a = EngineMetrics {
            decode_steps: 10,
            decode_rows: 40, // avg 4.0
            max_decode_batch: 6,
            prompt_tokens: 100,
            hit_full_tokens: 80,
            hit_partial_tokens: 10,
            completed: 3,
            gang_admitted: 2,
            migrated_pages: 5,
            migrated_bytes: 5 * 65536,
            recompute_tokens_saved: 80,
            demoted_pages: 12,
            promoted_pages: 4,
            tier_hits: 3,
            recompute_tokens_saved_tier: 64,
            prefetched_pages: 6,
            prefetch_hits: 2,
            prefetch_wasted: 1,
            ..EngineMetrics::default()
        };
        let mut b = EngineMetrics {
            decode_steps: 90,
            decode_rows: 90, // avg 1.0
            max_decode_batch: 2,
            prompt_tokens: 900,
            oom_drops: 2,
            budget_denials: 7,
            gang_admitted: 1,
            migrated_pages: 2,
            recompute_tokens_saved: 32,
            exported_pages: 5,
            demoted_pages: 1,
            tier_hits: 1,
            prefetched_pages: 3,
            prefetch_hits: 1,
            ..EngineMetrics::default()
        };
        let agg = aggregate_stats(&[a.to_json(), b.to_json()]);
        assert_eq!(agg.at(&["shards"]).as_usize().unwrap(), 2);
        assert_eq!(agg.at(&["decode_steps"]).as_usize().unwrap(), 100);
        assert_eq!(agg.at(&["completed"]).as_usize().unwrap(), 3);
        assert_eq!(agg.at(&["oom_drops"]).as_usize().unwrap(), 2);
        assert_eq!(agg.at(&["budget_denials"]).as_usize().unwrap(), 7);
        assert_eq!(agg.at(&["max_decode_batch"]).as_usize().unwrap(), 6);
        assert_eq!(agg.at(&["gang_admitted"]).as_usize().unwrap(), 3);
        assert_eq!(agg.at(&["migrated_pages"]).as_usize().unwrap(), 7);
        assert_eq!(agg.at(&["migrated_bytes"]).as_usize().unwrap(), 5 * 65536);
        assert_eq!(agg.at(&["recompute_tokens_saved"]).as_usize().unwrap(), 112);
        assert_eq!(agg.at(&["exported_pages"]).as_usize().unwrap(), 5);
        assert_eq!(agg.at(&["demoted_pages"]).as_usize().unwrap(), 13);
        assert_eq!(agg.at(&["promoted_pages"]).as_usize().unwrap(), 4);
        assert_eq!(agg.at(&["tier_hits"]).as_usize().unwrap(), 4);
        assert_eq!(
            agg.at(&["recompute_tokens_saved_tier"]).as_usize().unwrap(),
            64
        );
        assert_eq!(agg.at(&["prefetched_pages"]).as_usize().unwrap(), 9);
        assert_eq!(agg.at(&["prefetch_hits"]).as_usize().unwrap(), 3);
        assert_eq!(agg.at(&["prefetch_wasted"]).as_usize().unwrap(), 1);
        // weighted by steps, not the mean of per-shard averages (2.5)
        assert!((agg.at(&["avg_decode_batch"]).as_f64().unwrap() - 1.3).abs() < 1e-9);
        // weighted by prompt tokens, not the mean of per-shard rates (0.4)
        assert!((agg.at(&["hit_rate"]).as_f64().unwrap() - 0.08).abs() < 1e-9);
        assert!((agg.at(&["matched_rate"]).as_f64().unwrap() - 0.09).abs() < 1e-9);
        // empty pool degrades to zeros, not NaN
        let empty = aggregate_stats(&[]);
        assert_eq!(empty.at(&["avg_decode_batch"]).as_f64().unwrap(), 0.0);
        assert_eq!(empty.at(&["hit_rate"]).as_f64().unwrap(), 0.0);
    }

    #[test]
    fn per_tag_hits_bound_cardinality_and_render() {
        let mut m = EngineMetrics::default();
        m.record_tag_hit(3, 100, 80);
        m.record_tag_hit(3, 100, 60);
        m.record_tag_hit(9, 50, 0);
        assert_eq!(m.tag_hits[&3], (200, 140));
        let j = m.to_json();
        assert!((j.at(&["per_tag", "3", "matched_rate"]).as_f64().unwrap() - 0.7).abs() < 1e-9);
        assert_eq!(j.at(&["per_tag", "9", "matched_rate"]).as_f64().unwrap(), 0.0);

        // past the slot cap, new tags fold into "other"; known tags
        // still accumulate under their own key
        let mut m = EngineMetrics::default();
        for t in 0..(EngineMetrics::MAX_TAG_SLOTS as u64) {
            m.record_tag_hit(t, 10, 5);
        }
        m.record_tag_hit(1_000_000, 10, 10);
        m.record_tag_hit(2_000_000, 10, 0);
        m.record_tag_hit(0, 10, 5);
        assert_eq!(m.tag_hits.len(), EngineMetrics::MAX_TAG_SLOTS + 1);
        assert_eq!(m.tag_hits[&u64::MAX], (20, 10));
        assert_eq!(m.tag_hits[&0], (20, 10));
        let j = m.to_json();
        assert!((j.at(&["per_tag", "other", "matched_rate"]).as_f64().unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn outcome_identity() {
        let d = DroppedRequest {
            id: 9,
            tag: 1,
            adapter: 2,
            prompt_len: 10,
            arrival_us: 0,
            drop_us: 5,
            reason: DropReason::OutOfMemory,
        };
        assert_eq!(RequestOutcome::Dropped(d).id(), 9);
        assert_eq!(DropReason::OutOfMemory.as_str(), "out of memory");
    }
}
