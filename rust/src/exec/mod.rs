//! Execution backends behind one trait: the engine's scheduler, pools and
//! trees run identically against
//!   - `PjrtExecutor` — real XLA execution of the AOT artifacts, and
//!   - `SimExecutor` — a calibrated cost model + virtual time, used for the
//!     paper-scale sweeps where raw FLOP execution would dominate wallclock
//!     without changing the memory-system behaviour under test
//!     (DESIGN.md §3, "calibrated simulation").

use std::path::Path;

use crate::runtime::{DecodeArgs, DecodeOut, ModelMeta, PjrtRuntime, PrefillArgs, PrefillOut};
use crate::util::json::Json;

pub struct ExecPrefill {
    pub elapsed_us: u64,
    /// present in real mode; None in sim (engine synthesizes state)
    pub out: Option<PrefillOut>,
}

pub struct ExecDecode {
    pub elapsed_us: u64,
    pub out: Option<DecodeOut>,
}

pub trait Executor: Send {
    fn meta(&self) -> &ModelMeta;
    /// whether prefill/decode need real gathered cache slabs
    fn needs_data(&self) -> bool;
    fn decode_buckets(&self) -> Vec<usize>;
    fn prefill(&mut self, args: &PrefillArgs) -> anyhow::Result<ExecPrefill>;
    fn decode(&mut self, bucket: usize, args: &DecodeArgs) -> anyhow::Result<ExecDecode>;
}

// ---------------------------------------------------------------------------
// real backend
// ---------------------------------------------------------------------------

pub struct PjrtExecutor {
    rt: PjrtRuntime,
}

impl PjrtExecutor {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        Ok(PjrtExecutor { rt: PjrtRuntime::load(dir)? })
    }
    pub fn runtime(&self) -> &PjrtRuntime {
        &self.rt
    }
}

// SAFETY: the `xla` crate uses `Rc` for the client handle, so the type is
// not auto-Send; but every Rc clone (client, weight buffers, executables)
// lives inside this single `PjrtRuntime` value and is moved as one unit.
// The server moves the whole Engine (and thus this executor) into exactly
// one engine thread and never aliases it across threads, so there is no
// cross-thread shared Rc. PJRT itself is thread-compatible.
unsafe impl Send for PjrtExecutor {}

impl Executor for PjrtExecutor {
    fn meta(&self) -> &ModelMeta {
        self.rt.meta()
    }
    fn needs_data(&self) -> bool {
        true
    }
    fn decode_buckets(&self) -> Vec<usize> {
        self.rt.decode_buckets()
    }
    fn prefill(&mut self, args: &PrefillArgs) -> anyhow::Result<ExecPrefill> {
        let t = std::time::Instant::now();
        let out = self.rt.prefill(args)?;
        Ok(ExecPrefill {
            elapsed_us: t.elapsed().as_micros() as u64,
            out: Some(out),
        })
    }
    fn decode(&mut self, bucket: usize, args: &DecodeArgs) -> anyhow::Result<ExecDecode> {
        let t = std::time::Instant::now();
        let out = self.rt.decode(bucket, args)?;
        Ok(ExecDecode {
            elapsed_us: t.elapsed().as_micros() as u64,
            out: Some(out),
        })
    }
}

// ---------------------------------------------------------------------------
// calibrated simulation backend
// ---------------------------------------------------------------------------

/// Per-op virtual-time costs, parameterized by actual sequence lengths
/// (attention cost grows with the live context, dense cost with tokens
/// processed). The sustained-FLOPs constant is calibrated against real
/// PJRT runs on this image (`forkkv calibrate` writes
/// artifacts/calibration.json; EXPERIMENTS.md §Calibration).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// dense (projection + MLP + lm head) FLOPs per processed token
    pub flops_per_token: f64,
    /// attention FLOPs per (query token x context slot)
    pub attn_flops_per_qk: f64,
    /// sustained FLOP/s of the substrate
    pub sustained_flops: f64,
    /// fixed dispatch cost per executable invocation
    pub dispatch_us: u64,
    /// fixed per-step scheduling/gather overhead
    pub step_overhead_us: u64,
    /// sustained shard-to-shard copy bandwidth for bCache page migration
    /// (bytes/s); calibrated by `forkkv calibrate` alongside the FLOP
    /// terms, and the denominator of the migrate-vs-recompute decision
    pub migration_bandwidth_bytes_per_s: f64,
    /// sustained pool<->host-tier copy bandwidth (bytes/s); calibrated by
    /// the `forkkv calibrate` tier probe, and the denominator of the
    /// promote-vs-recompute decision (tier module)
    pub tier_bandwidth_bytes_per_s: f64,
}

/// Default inter-shard copy bandwidth when no calibration is present:
/// conservative host-memory memcpy territory (same-box shards).
pub const DEFAULT_MIGRATION_BANDWIDTH: f64 = 8.0e9;

/// Default pool<->tier copy bandwidth when no calibration is present.
/// The tier is plain host memory on the same box — no socket framing or
/// peer round trip — so the default sits above the migration link.
pub const DEFAULT_TIER_BANDWIDTH: f64 = 16.0e9;

impl CostModel {
    pub fn derived(meta: &ModelMeta) -> Self {
        CostModel {
            flops_per_token: per_token_flops(meta),
            attn_flops_per_qk: attn_flops(meta, 1, 1),
            sustained_flops: 6.0e9,
            dispatch_us: 600,
            step_overhead_us: 150,
            migration_bandwidth_bytes_per_s: DEFAULT_MIGRATION_BANDWIDTH,
            tier_bandwidth_bytes_per_s: DEFAULT_TIER_BANDWIDTH,
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(CostModel {
            flops_per_token: j.req_f64("flops_per_token")?,
            attn_flops_per_qk: j.req_f64("attn_flops_per_qk")?,
            sustained_flops: j.req_f64("sustained_flops")?,
            dispatch_us: j.req_usize("dispatch_us")? as u64,
            step_overhead_us: j.req_usize("step_overhead_us")? as u64,
            // optional so calibration files written before the migration
            // subsystem keep loading
            migration_bandwidth_bytes_per_s: j
                .get("migration_bandwidth_bytes_per_s")
                .and_then(Json::as_f64)
                .unwrap_or(DEFAULT_MIGRATION_BANDWIDTH),
            // optional for the same reason: calibration files written
            // before the tier subsystem keep loading
            tier_bandwidth_bytes_per_s: j
                .get("tier_bandwidth_bytes_per_s")
                .and_then(Json::as_f64)
                .unwrap_or(DEFAULT_TIER_BANDWIDTH),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flops_per_token", Json::num(self.flops_per_token)),
            ("attn_flops_per_qk", Json::num(self.attn_flops_per_qk)),
            ("sustained_flops", Json::num(self.sustained_flops)),
            ("dispatch_us", Json::num(self.dispatch_us as f64)),
            ("step_overhead_us", Json::num(self.step_overhead_us as f64)),
            (
                "migration_bandwidth_bytes_per_s",
                Json::num(self.migration_bandwidth_bytes_per_s),
            ),
            (
                "tier_bandwidth_bytes_per_s",
                Json::num(self.tier_bandwidth_bytes_per_s),
            ),
        ])
    }

    /// Virtual time to copy `bytes` of KV pages between two shards (one
    /// fixed dispatch for the transfer, then pure bandwidth).
    pub fn migrate_cost_us(&self, bytes: usize) -> u64 {
        (bytes as f64 / self.migration_bandwidth_bytes_per_s.max(1.0) * 1e6) as u64
            + self.dispatch_us
    }

    /// Virtual time to copy `bytes` of pages between the pool and the
    /// host-memory tier — the migrate shape one tier down: one dispatch,
    /// then pure bandwidth. Promotion runs when this beats
    /// [`CostModel::prefill_cost_us`] for the tokens the pages hold.
    pub fn tier_cost_us(&self, bytes: usize) -> u64 {
        (bytes as f64 / self.tier_bandwidth_bytes_per_s.max(1.0) * 1e6) as u64
            + self.dispatch_us
    }

    /// One prefill chunk of `n` tokens attending over `cache_len + n` slots.
    pub fn prefill_cost_us(&self, n: usize, cache_len: usize) -> u64 {
        let f = self.flops_per_token * n as f64
            + self.attn_flops_per_qk * n as f64 * (cache_len + n) as f64;
        (f / self.sustained_flops * 1e6) as u64 + self.dispatch_us
    }

    /// One decode step over rows with the given live context lengths.
    pub fn decode_cost_us(&self, cache_lens: &[usize]) -> u64 {
        let rows = cache_lens.len().max(1) as f64;
        let ctx: f64 = cache_lens.iter().map(|&c| (c + 1) as f64).sum();
        let f = self.flops_per_token * rows + self.attn_flops_per_qk * ctx;
        (f / self.sustained_flops * 1e6) as u64 + self.dispatch_us
    }
}

/// Dense-projection FLOPs per token (fwd only), all layers.
fn per_token_flops(m: &ModelMeta) -> f64 {
    let d = m.d_model as f64;
    let qw = (m.n_heads * m.head_dim) as f64;
    let kvw = m.kv_width() as f64;
    let ff = m.d_ff as f64;
    let per_layer = 2.0 * d * (qw + 2.0 * kvw) // qkv
        + 2.0 * qw * d                          // out proj
        + 3.0 * 2.0 * d * ff;                   // swiglu
    m.n_layers as f64 * per_layer + 2.0 * d * m.vocab as f64
}

/// Attention FLOPs for `q` query tokens over a padded cache of `s` slots.
fn attn_flops(m: &ModelMeta, q: usize, s: usize) -> f64 {
    let hd = m.head_dim as f64;
    let heads = m.n_heads as f64;
    m.n_layers as f64 * heads * (q as f64) * (s as f64) * (2.0 * hd * 2.0)
}

/// Synthetic model metadata mirroring python/compile/configs.py (kept in
/// sync by tests/sim_meta.rs against the generated manifest).
pub fn synthetic_meta(name: &str) -> anyhow::Result<ModelMeta> {
    let (n_layers, d_model, n_heads, n_kv_heads, d_ff, qkv_bias) = match name {
        "llama3-8b-sim" => (4, 256, 8, 4, 704, false),
        "qwen2.5-7b-sim" => (4, 256, 8, 2, 704, true),
        "qwen2.5-14b-sim" => (6, 384, 12, 6, 1024, true),
        other => anyhow::bail!("unknown sim model {other:?}"),
    };
    Ok(ModelMeta {
        name: name.to_string(),
        n_layers,
        d_model,
        n_heads,
        n_kv_heads,
        head_dim: 32,
        d_ff,
        vocab: 2048,
        rope_theta: 1e4,
        qkv_bias,
        s_max: 768,
        chunk: 64,
        rank_max: 32,
        n_adapters: 16,
        decode_batches: vec![1, 2, 4, 8],
        rank_effective: 16,
    })
}

pub struct SimExecutor {
    meta: ModelMeta,
    cost: CostModel,
    buckets: Vec<usize>,
    /// wall-clock sleep per executed op (0 = pure virtual time). Lets the
    /// sim backend stand in for real hardware behind the HTTP server:
    /// concurrent clients then overlap in wall time and co-batch exactly
    /// as they would against PJRT, instead of the first request racing to
    /// completion in microseconds.
    wall_pace_us: u64,
}

impl SimExecutor {
    /// Sim over one of the three paper models; buckets may exceed the AOT
    /// set (sim needs no artifacts).
    pub fn new(name: &str, buckets: Vec<usize>) -> anyhow::Result<Self> {
        let meta = synthetic_meta(name)?;
        let cost = CostModel::derived(&meta);
        Ok(SimExecutor { meta, cost, buckets, wall_pace_us: 0 })
    }

    pub fn with_meta(meta: ModelMeta, buckets: Vec<usize>) -> Self {
        let cost = CostModel::derived(&meta);
        SimExecutor { meta, cost, buckets, wall_pace_us: 0 }
    }

    /// Sleep this many wall-clock microseconds inside every prefill/decode
    /// call (serving-mode realism; virtual time is unaffected).
    pub fn with_wall_pace_us(mut self, us: u64) -> Self {
        self.wall_pace_us = us;
        self
    }

    fn pace(&self) {
        if self.wall_pace_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.wall_pace_us));
        }
    }

    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Override the effective LoRA rank used for rCache memory accounting.
    /// The sim models are ~8x narrower than the paper's (kv_width 128 vs
    /// 1024), so reproducing the paper's r/n *ratio* (Eq. 3 — the quantity
    /// that governs every memory experiment) requires scaling r down by
    /// the same factor: paper r=16,n=1024 -> sim r=2,n=128 (DESIGN.md §3).
    pub fn with_rank(mut self, rank_effective: usize) -> Self {
        self.meta.rank_effective = rank_effective;
        self
    }

    /// Paper-faithful sim rank for paper rank in {8, 16, 32} (Fig. 15a).
    pub fn paper_ratio_rank(paper_rank: usize) -> usize {
        // paper n = 1024; sim kv_width = 128 => scale by 1/8, min 1
        (paper_rank / 8).max(1)
    }

    /// Override the substrate's sustained FLOP/s (virtual-capacity knob;
    /// `forkkv calibrate` measures the real value for this image).
    pub fn with_sustained(mut self, flops: f64) -> Self {
        self.cost.sustained_flops = flops;
        self
    }

    /// Widen the context window (sim needs no recompiled artifacts); used
    /// by the paper-scale sweeps (static contexts are 1/10 the paper's).
    pub fn with_ctx(mut self, s_max: usize) -> Self {
        self.meta.s_max = s_max;
        self
    }

    /// Load calibration written by `forkkv calibrate` if present.
    pub fn try_load_calibration(mut self, dir: &Path) -> Self {
        let path = dir.join("calibration.json");
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(j) = crate::util::json::parse(&text) {
                if let Some(per_model) = j.get(&self.meta.name) {
                    if let Ok(c) = CostModel::from_json(per_model) {
                        self.cost = c;
                    }
                }
            }
        }
        self
    }

    pub fn cost(&self) -> &CostModel {
        &self.cost
    }
}

impl Executor for SimExecutor {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }
    fn needs_data(&self) -> bool {
        false
    }
    fn decode_buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }
    fn prefill(&mut self, args: &PrefillArgs) -> anyhow::Result<ExecPrefill> {
        self.pace();
        Ok(ExecPrefill {
            elapsed_us: self.cost.prefill_cost_us(args.tokens.len(), args.cache_len)
                + self.cost.step_overhead_us,
            out: None,
        })
    }
    fn decode(&mut self, _bucket: usize, args: &DecodeArgs) -> anyhow::Result<ExecDecode> {
        self.pace();
        // only live rows cost FLOPs (padding rows are masked out)
        let live: Vec<usize> = args
            .adapter_on
            .iter()
            .zip(args.cache_lens.iter())
            .filter(|(&on, _)| on)
            .map(|(_, &c)| c)
            .collect();
        Ok(ExecDecode {
            elapsed_us: self.cost.decode_cost_us(&live) + self.cost.step_overhead_us,
            out: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_costs_scale_with_model_batch_and_context() {
        let small = synthetic_meta("llama3-8b-sim").unwrap();
        let big = synthetic_meta("qwen2.5-14b-sim").unwrap();
        let cs = CostModel::derived(&small);
        let cb = CostModel::derived(&big);
        assert!(cb.prefill_cost_us(64, 0) > cs.prefill_cost_us(64, 0));
        assert!(cs.decode_cost_us(&[100; 8]) > cs.decode_cost_us(&[100; 1]));
        // batching amortizes the dispatch cost
        assert!(cs.decode_cost_us(&[100; 8]) < 8 * cs.decode_cost_us(&[100; 1]));
        // attention cost grows with live context
        assert!(cs.decode_cost_us(&[4000]) > cs.decode_cost_us(&[100]));
        assert!(cs.prefill_cost_us(64, 4000) > cs.prefill_cost_us(64, 0));
    }

    #[test]
    fn cost_model_json_round_trip() {
        let m = synthetic_meta("llama3-8b-sim").unwrap();
        let c = CostModel::derived(&m);
        let j = c.to_json();
        let c2 = CostModel::from_json(&j).unwrap();
        assert_eq!(c.dispatch_us, c2.dispatch_us);
        assert!((c.flops_per_token - c2.flops_per_token).abs() < 1.0);
        assert!(
            (c.migration_bandwidth_bytes_per_s - c2.migration_bandwidth_bytes_per_s).abs()
                < 1.0
        );
        assert!((c.tier_bandwidth_bytes_per_s - c2.tier_bandwidth_bytes_per_s).abs() < 1.0);
        // calibration files that predate the migration and tier
        // subsystems load with the default bandwidths
        let mut legacy = j.clone();
        if let Json::Obj(m) = &mut legacy {
            m.remove("migration_bandwidth_bytes_per_s");
            m.remove("tier_bandwidth_bytes_per_s");
        }
        let c3 = CostModel::from_json(&legacy).unwrap();
        assert_eq!(c3.migration_bandwidth_bytes_per_s, DEFAULT_MIGRATION_BANDWIDTH);
        assert_eq!(c3.tier_bandwidth_bytes_per_s, DEFAULT_TIER_BANDWIDTH);
    }

    #[test]
    fn tier_cost_scales_and_beats_recompute_for_long_prefixes() {
        let m = synthetic_meta("llama3-8b-sim").unwrap();
        let mut c = CostModel::derived(&m);
        let small = c.tier_cost_us(64 << 10);
        let big = c.tier_cost_us(64 << 20);
        assert!(big > small);
        c.tier_bandwidth_bytes_per_s /= 100.0;
        assert!(c.tier_cost_us(64 << 20) > big, "slower tier costs more");
        // host-memory copies beat re-prefilling the tokens the pages hold
        let c = CostModel::derived(&m);
        assert!(c.tier_cost_us(100 << 10) < c.prefill_cost_us(144, 0));
        // and sit below the socket-framed migration link at equal bytes
        assert!(c.tier_cost_us(64 << 20) < c.migrate_cost_us(64 << 20));
    }

    #[test]
    fn migrate_cost_scales_with_bytes_and_bandwidth() {
        let m = synthetic_meta("llama3-8b-sim").unwrap();
        let mut c = CostModel::derived(&m);
        let small = c.migrate_cost_us(64 << 10);
        let big = c.migrate_cost_us(64 << 20);
        assert!(big > small);
        c.migration_bandwidth_bytes_per_s /= 100.0;
        assert!(c.migrate_cost_us(64 << 20) > big, "slower link costs more");
        // on a same-box link, moving a few pages is far cheaper than
        // re-prefilling the tokens they hold
        let c = CostModel::derived(&m);
        assert!(c.migrate_cost_us(100 << 10) < c.prefill_cost_us(144, 0));
    }

    #[test]
    fn rank_and_ctx_overrides() {
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 8])
            .unwrap()
            .with_rank(2)
            .with_ctx(10240);
        assert_eq!(sim.meta().rank_effective, 2);
        assert_eq!(sim.meta().s_max, 10240);
        assert_eq!(SimExecutor::paper_ratio_rank(16), 2);
        assert_eq!(SimExecutor::paper_ratio_rank(8), 1);
        assert_eq!(SimExecutor::paper_ratio_rank(32), 4);
    }

    #[test]
    fn sim_executor_advances_virtual_time_only() {
        let mut sim = SimExecutor::new("llama3-8b-sim", vec![1, 8]).unwrap();
        let args = PrefillArgs {
            tokens: &[1, 2, 3],
            cache_len: 0,
            adapter_id: 0,
            adapter_on: true,
            kb: &[],
            vb: &[],
            kr: &[],
            vr: &[],
        };
        let r = sim.prefill(&args).unwrap();
        assert!(r.out.is_none());
        assert!(r.elapsed_us > 0);
    }

    #[test]
    fn padded_decode_rows_cost_nothing() {
        let mut sim = SimExecutor::new("llama3-8b-sim", vec![8]).unwrap();
        let full = DecodeArgs {
            tokens: &[1; 8],
            cache_lens: &[500; 8],
            adapter_ids: &[0; 8],
            adapter_on: &[true; 8],
            kb: &[], vb: &[], kr: &[], vr: &[],
        };
        let half_on = [true, true, true, true, false, false, false, false];
        let half = DecodeArgs {
            tokens: &[1; 8],
            cache_lens: &[500; 8],
            adapter_ids: &[0; 8],
            adapter_on: &half_on,
            kb: &[], vb: &[], kr: &[], vr: &[],
        };
        let c_full = sim.decode(8, &full).unwrap().elapsed_us;
        let c_half = sim.decode(8, &half).unwrap().elapsed_us;
        assert!(c_half < c_full);
    }
}
