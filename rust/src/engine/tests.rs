//! Engine unit tests over the sim executor: scheduling, fork semantics,
//! policy-specific sharing behaviour, memory pressure, and quiescence.

use super::*;
use crate::config::{CacheConfig, CachePolicy, EngineConfig, SchedulerConfig, TierConfig};
use crate::exec::SimExecutor;
use crate::util::rng::Rng;

fn engine(policy: CachePolicy, budget_mb: usize) -> Engine {
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: budget_mb << 20,
            capacity_bytes: 0,
        },
        sched: SchedulerConfig::default(),
        tier: TierConfig::default(),
        seed: 7,
        greedy: true,
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8, 16]).unwrap();
    Engine::new(cfg, Box::new(sim)).unwrap()
}

/// `engine` with the host-memory tier armed (`tier_bytes` of budget);
/// `tier_bytes == 0` is the tier-off control with otherwise identical
/// construction.
fn engine_tiered(budget_bytes: usize, tier_bytes: usize) -> Engine {
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes,
            capacity_bytes: 0,
        },
        tier: TierConfig { tier_bytes, cost: None },
        seed: 7,
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8, 16]).unwrap();
    Engine::new(cfg, Box::new(sim)).unwrap()
}

/// `engine` with explicit gang-scheduler knobs (A/B tests).
fn engine_with(policy: CachePolicy, budget_mb: usize, gang: bool, hold_ms: u64) -> Engine {
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: budget_mb << 20,
            capacity_bytes: 0,
        },
        sched: SchedulerConfig {
            gang,
            gang_hold_ms: hold_ms,
            ..SchedulerConfig::default()
        },
        tier: TierConfig::default(),
        seed: 7,
        greedy: true,
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8, 16]).unwrap();
    Engine::new(cfg, Box::new(sim)).unwrap()
}

fn req(id: u64, adapter: u32, tokens: Vec<u32>, max_new: usize, arrival_us: u64) -> Request {
    Request {
        id,
        tag: 0,
        adapter,
        tokens,
        max_new,
        arrival_us,
        ignore_eos: true,
        fan: 0,
    }
}

/// `req` with an explicit workflow tag + declared fan width (gang tests).
fn tagged_req(
    id: u64,
    tag: u64,
    fan: usize,
    adapter: u32,
    tokens: Vec<u32>,
    max_new: usize,
    arrival_us: u64,
) -> Request {
    Request {
        id,
        tag,
        adapter,
        tokens,
        max_new,
        arrival_us,
        ignore_eos: true,
        fan,
    }
}

fn run_to_completion(e: &mut Engine) -> Vec<crate::metrics::FinishedRequest> {
    let mut out = Vec::new();
    for _ in 0..200_000 {
        match e.tick().unwrap() {
            Tick::Progress => out.extend(e.drain_finished()),
            Tick::Idle => {
                if let Some(t) = e.next_pending_arrival() {
                    let now = e.now_us().max(t);
                    e.now_us = now;
                } else {
                    break;
                }
            }
        }
    }
    out
}

fn toks(n: usize, seed: u64) -> Vec<u32> {
    Rng::seeded(seed).tokens(n, 2000)
}

#[test]
fn single_request_completes() {
    let mut e = engine(CachePolicy::Disaggregated, 32);
    e.submit(req(1, 0, toks(100, 1), 20, 0));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].generated.len(), 20);
    assert!(fin[0].finish_us > 0);
    assert!(fin[0].first_token_us >= fin[0].arrival_us);
    e.check_quiescent().unwrap();
}

#[test]
fn same_agent_reuses_prefix_all_policies() {
    for policy in [
        CachePolicy::Disaggregated,
        CachePolicy::UnifiedPerAdapter,
        CachePolicy::FullReuse,
    ] {
        let mut e = engine(policy, 32);
        let prompt = toks(200, 2);
        e.submit(req(1, 3, prompt.clone(), 8, 0));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin[0].hit_full, 0, "{policy:?}: cold start");

        // same adapter, same prompt, later arrival
        e.submit(req(2, 3, prompt.clone(), 8, e.now_us() + 1));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 1);
        // everything except the (never-cached) tail is a full hit
        assert!(
            fin[0].hit_full >= 176,
            "{policy:?}: hit_full {} too small",
            fin[0].hit_full
        );
        e.check_quiescent().unwrap();
    }
}

#[test]
fn cross_adapter_fork_is_the_policy_differentiator() {
    let prompt = toks(320, 3);

    // ForkKV: agent 2 inherits agent 1's bCache => large partial hit
    let mut e = engine(CachePolicy::Disaggregated, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt.clone(), 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin[0].hit_full, 0, "different adapter: no full hit");
    assert!(
        fin[0].hit_partial >= 304,
        "bCache must be inherited cross-adapter: {}",
        fin[0].hit_partial
    );

    // prefix caching baseline: nothing shared cross-adapter
    let mut e = engine(CachePolicy::UnifiedPerAdapter, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt.clone(), 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin[0].hit_full, 0);
    assert_eq!(fin[0].hit_partial, 0);

    // full reuse: everything shared cross-adapter (lossy)
    let mut e = engine(CachePolicy::FullReuse, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt, 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert!(fin[0].hit_full >= 304, "full reuse shares everything");
}

#[test]
fn react_chain_hits_grow_with_published_outputs() {
    // agent k+1's prompt extends agent k's prompt+output: each fork should
    // match the previously published span (ForkKV base tree, ns 0)
    let mut e = engine(CachePolicy::Disaggregated, 32);
    let shared = toks(256, 4);
    let mut transcript = shared.clone();
    let mut id = 0;
    for step in 0..4u32 {
        id += 1;
        e.submit(req(id, step, transcript.clone(), 16, e.now_us() + 1));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 1);
        if step > 0 {
            // bCache from previous agents (different adapters) inherited
            assert!(
                fin[0].hit_partial + fin[0].hit_full >= (transcript.len() / 2),
                "step {step}: inherited {} of {}",
                fin[0].hit_partial + fin[0].hit_full,
                transcript.len()
            );
        }
        transcript.extend(fin[0].generated.iter().copied());
        transcript.extend(Rng::seeded(step as u64).tokens(8, 2000)); // tool output
    }
    e.check_quiescent().unwrap();
}

#[test]
fn decode_batches_fill_under_concurrency() {
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let shared = toks(128, 5);
    for i in 0..8 {
        let mut p = shared.clone();
        p.extend(toks(8, 100 + i));
        e.submit(req(i, i as u32, p, 32, 0));
    }
    run_to_completion(&mut e);
    assert!(
        e.metrics.avg_decode_batch() > 3.0,
        "decode batching too small: {}",
        e.metrics.avg_decode_batch()
    );
    e.check_quiescent().unwrap();
}

#[test]
fn memory_pressure_evicts_and_preempts_but_completes() {
    // deliberately tiny budget: 2 MB for 16 concurrent agents
    let mut e = engine(CachePolicy::UnifiedPerAdapter, 2);
    let shared = toks(300, 6);
    for i in 0..16 {
        let mut p = shared.clone();
        p.extend(toks(10, 200 + i));
        e.submit(req(i, i as u32, p, 24, (i * 1000) as u64));
    }
    let fin = run_to_completion(&mut e);
    assert_eq!(
        fin.len() as u64 + e.metrics.oom_drops,
        16,
        "all requests finish or are accounted as drops"
    );
    assert!(fin.len() >= 12, "most requests must still complete");
    e.check_quiescent().unwrap();
}

#[test]
fn forkkv_serves_more_agents_in_same_budget() {
    // the core paper claim at allocator level: with a fixed budget and N
    // agents on the same context, ForkKV sustains a much higher hit rate
    let shared = toks(320, 7);
    let run = |policy| {
        let mut e = engine(policy, 4);
        for i in 0..12 {
            let mut p = shared.clone();
            p.extend(toks(6, 300 + i));
            e.submit(req(i, i as u32, p, 8, (i * 500) as u64));
        }
        run_to_completion(&mut e);
        (
            e.metrics.hit_rate()
                + e.metrics.hit_partial_tokens as f64 / e.metrics.prompt_tokens as f64,
            e.metrics.preemptions,
        )
    };
    let (fork_shared_frac, _) = run(CachePolicy::Disaggregated);
    let (unified_shared_frac, _) = run(CachePolicy::UnifiedPerAdapter);
    assert!(
        fork_shared_frac > unified_shared_frac + 0.3,
        "forkkv shared fraction {fork_shared_frac:.2} vs unified {unified_shared_frac:.2}"
    );
}

#[test]
fn driver_loop_with_poisson_arrivals() {
    struct D {
        released: usize,
        finished: usize,
        next_t: u64,
        rng: Rng,
        shared: Vec<u32>,
    }
    impl Driver for D {
        fn poll(&mut self, now: u64, fin: &[crate::metrics::FinishedRequest]) -> Vec<Request> {
            self.finished += fin.len();
            let mut out = Vec::new();
            let _ = now;
            while self.released < 10 {
                self.released += 1;
                let mut p = self.shared.clone();
                p.extend(self.rng.tokens(4, 2000));
                out.push(Request {
                    id: self.released as u64,
                    tag: 1,
                    adapter: (self.released % 4) as u32,
                    tokens: p,
                    max_new: 8,
                    arrival_us: self.next_t,
                    ignore_eos: true,
                    fan: 0,
                });
                self.next_t += (self.rng.exponential(2.0) * 1e6) as u64;
            }
            out
        }
        fn done(&self) -> bool {
            self.released == 10 && self.finished == 10
        }
    }
    let mut e = engine(CachePolicy::Disaggregated, 32);
    let mut d = D {
        released: 0,
        finished: 0,
        next_t: 0,
        rng: Rng::seeded(9),
        shared: toks(200, 8),
    };
    let fin = e.run_driver(&mut d).unwrap();
    assert_eq!(fin.len(), 10);
    assert!(d.done());
    e.check_quiescent().unwrap();
}

#[test]
fn deterministic_under_fixed_seed() {
    let run = || {
        let mut e = engine(CachePolicy::Disaggregated, 8);
        for i in 0..6 {
            e.submit(req(i, i as u32, toks(150, 10 + i), 12, i * 2000));
        }
        let fin = run_to_completion(&mut e);
        (
            e.now_us(),
            fin.iter().map(|f| f.finish_us).collect::<Vec<_>>(),
            fin.iter()
                .flat_map(|f| f.generated.clone())
                .collect::<Vec<_>>(),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn migration_export_import_moves_cache_hits_across_engines() {
    // two peer shards: prime one, migrate its pages to the other, and
    // the "spilled" request must hit there as if it had stayed home
    let mut home = engine(CachePolicy::Disaggregated, 32);
    let mut target = engine(CachePolicy::Disaggregated, 32);
    let prompt = toks(200, 30);
    home.submit(req(1, 3, prompt.clone(), 8, 0));
    run_to_completion(&mut home);

    // probe over the admit_fork match window (prompt minus final token)
    let window = &prompt[..prompt.len() - 1];
    let est = home.migration_probe(3, window);
    assert!(est.base_pages >= 12, "home not primed: {est:?}");
    assert_eq!(est.res_pages, est.base_pages, "both components published");
    assert_eq!(est.tokens_saved, est.base_pages * 16);
    assert!(est.bytes > 0);
    let cold = target.migration_probe(3, window);
    assert_eq!(cold.tokens_saved, 0, "target starts cold");

    // export -> import round trip
    let payload = home.export_pages(3, window);
    assert_eq!(payload.pages(), est.base_pages + est.res_pages);
    assert_eq!(payload.tokens_saved(), est.tokens_saved);
    assert_eq!(home.metrics.exported_pages, payload.pages() as u64);
    let imported = target.import_pages(&payload);
    assert_eq!(imported, payload.pages());
    assert_eq!(target.metrics.migrated_pages, imported as u64);
    assert!(target.metrics.migrated_bytes > 0);
    assert_eq!(
        target.metrics.recompute_tokens_saved as usize,
        payload.tokens_saved()
    );

    // re-import of the same payload dedups against the tree: no double
    // adoption, no refcount drift — and crucially no metric inflation
    // (savings already banked must not be reported twice)
    let used_before = target.base_pool().used_pages();
    let (pages_before, saved_before, bytes_before) = (
        target.metrics.migrated_pages,
        target.metrics.recompute_tokens_saved,
        target.metrics.migrated_bytes,
    );
    assert_eq!(target.import_pages(&payload), 0, "repeat import adopts nothing");
    assert_eq!(target.base_pool().used_pages(), used_before);
    assert_eq!(target.metrics.migrated_pages, pages_before);
    assert_eq!(target.metrics.recompute_tokens_saved, saved_before);
    assert_eq!(target.metrics.migrated_bytes, bytes_before);

    // the spilled request now forks locally instead of recomputing
    target.submit(req(9, 3, prompt.clone(), 8, 0));
    let fin = run_to_completion(&mut target);
    assert_eq!(fin.len(), 1);
    assert!(
        fin[0].hit_full >= est.tokens_saved,
        "spilled request missed the migrated pages: hit {} < saved {}",
        fin[0].hit_full,
        est.tokens_saved
    );
    target.check_quiescent().unwrap();
    home.check_quiescent().unwrap();
}

#[test]
fn migration_import_respects_budget_without_preempting() {
    // a tiny target shard adopts only the payload prefix that fits its
    // budget — and never corrupts its pool doing so
    let mut home = engine(CachePolicy::Disaggregated, 32);
    let mut target = engine(CachePolicy::Disaggregated, 1);
    let prompt = toks(400, 33);
    home.submit(req(1, 2, prompt.clone(), 8, 0));
    run_to_completion(&mut home);
    let window = &prompt[..prompt.len() - 1];
    let payload = home.export_pages(2, window);
    assert!(payload.pages() > 0);
    let imported = target.import_pages(&payload);
    assert!(imported > 0, "nothing fit a 1 MB budget?");
    assert!(
        imported < payload.pages(),
        "a 1 MB shard cannot hold the whole payload ({} pages)",
        payload.pages()
    );
    assert!(
        target.used_cache_bytes() <= 1 << 20,
        "import blew the byte budget"
    );
    target.base_pool().check_invariants().unwrap();
    target.trees().base.check_invariants(target.base_pool()).unwrap();
    // a geometry-mismatched payload is refused outright
    let mut wrong = payload.clone();
    wrong.page_tokens += 1;
    assert_eq!(target.import_pages(&wrong), 0);
}

#[test]
fn decode_steady_state_does_not_grow_scratch() {
    // the per-tick gather path must reuse engine-owned buffers: once the
    // decode loop reaches steady state, no scratch vector may grow
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let shared = toks(96, 31);
    for i in 0..6 {
        let mut p = shared.clone();
        p.extend(toks(8, 400 + i));
        e.submit(req(i, i as u32, p, 48, 0));
    }
    let mut warm = 0;
    while warm < 400 && e.metrics.decode_steps < 10 {
        assert_eq!(e.tick().unwrap(), Tick::Progress, "workload stalled");
        warm += 1;
    }
    assert!(e.metrics.decode_steps >= 10, "never reached steady decode");
    let caps = e.decode_scratch_caps();
    for step in 0..30 {
        assert_eq!(e.tick().unwrap(), Tick::Progress);
        assert_eq!(
            e.decode_scratch_caps(),
            caps,
            "per-decode-step heap growth at step {step}"
        );
    }
    e.drain_finished();
}

// ---------------------------------------------------------------------------
// workflow-aware (gang) admission & eviction
// ---------------------------------------------------------------------------

/// Drive a primed K-fork fan whose members arrive interleaved with cold
/// singleton workflows; returns (fan first-token times, cold first-token
/// times, gang_admitted, max_decode_batch).
fn run_fan_vs_cold(gang: bool, k: usize) -> (Vec<u64>, Vec<u64>, u64, u64) {
    let mut e = engine_with(CachePolicy::Disaggregated, 64, gang, 25);
    let shared = toks(160, 90);
    // primer publishes the workflow's shared context (tag 9)
    let mut primer = shared.clone();
    primer.extend(toks(4, 91));
    e.submit(tagged_req(1, 9, 0, 7, primer, 4, 0));
    run_to_completion(&mut e);
    let t0 = e.now_us();
    // unfavourable arrival order: each fan member is chased by a cold
    // workflow's agent that arrives right behind it
    let mut id = 10;
    for i in 0..k as u64 {
        let mut member = shared.clone();
        member.extend(toks(6, 400 + i));
        e.submit(tagged_req(id, 9, 0, 10 + i as u32, member, 4, t0 + 2 * i + 1));
        id += 1;
        let cold = toks(180, 300 + i);
        e.submit(tagged_req(id, 100 + i, 0, 20 + i as u32, cold, 4, t0 + 2 * i + 2));
        id += 1;
    }
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 2 * k, "gang={gang}: all requests must finish");
    e.check_quiescent().unwrap();
    let fan: Vec<u64> = fin.iter().filter(|f| f.tag == 9).map(|f| f.first_token_us).collect();
    let cold: Vec<u64> = fin.iter().filter(|f| f.tag != 9).map(|f| f.first_token_us).collect();
    assert_eq!(fan.len(), k);
    (fan, cold, e.metrics.gang_admitted, e.metrics.max_decode_batch)
}

#[test]
fn gang_coadmits_fan_ahead_of_cold_interleaving() {
    let k = 4;
    // gang on: once the first member admits, the rest of the fan follows
    // back to back (warm prefix + admitted tag-mate preference) — every
    // fan first-token precedes every cold first-token, and the whole fan
    // is decode-resident together
    let (fan, cold, gang_admitted, max_batch) = run_fan_vs_cold(true, k);
    let fan_last = *fan.iter().max().unwrap();
    let cold_first = *cold.iter().min().unwrap();
    assert!(
        fan_last < cold_first,
        "gang interleaved the fan with cold work: fan {fan:?} cold {cold:?}"
    );
    assert!(
        gang_admitted >= (k - 1) as u64,
        "co-admissions not counted: {gang_admitted}"
    );
    assert!(
        max_batch >= k as u64,
        "decode occupancy never covered the whole fan: {max_batch}"
    );

    // gang off (the A/B baseline): plain FCFS interleaves the arrivals,
    // so some cold agent prefills in the middle of the fan
    let (fan, cold, gang_admitted, _) = run_fan_vs_cold(false, k);
    assert_eq!(gang_admitted, 0, "counter must be inert with gang off");
    let fan_last = *fan.iter().max().unwrap();
    let cold_first = *cold.iter().min().unwrap();
    assert!(
        cold_first < fan_last,
        "FCFS unexpectedly kept the fan together: fan {fan:?} cold {cold:?}"
    );
}

#[test]
fn gang_hold_releases_partial_fan_on_timeout() {
    let run = |fan: usize| {
        let mut e = engine_with(CachePolicy::Disaggregated, 32, true, 5);
        // two members of a declared fan of `fan` arrive; for fan > 2 the
        // stragglers never come, so only the 5 ms hold can release them
        e.submit(tagged_req(1, 3, fan, 1, toks(80, 500), 4, 0));
        e.submit(tagged_req(2, 3, fan, 2, toks(80, 501), 4, 1));
        // a cold late-comer: under an active hold it overtakes the fan
        e.submit(tagged_req(3, 8, 0, 3, toks(100, 502), 4, 2));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 3, "a hold must never lose requests");
        e.check_quiescent().unwrap();
        let first = |tag: u64| {
            fin.iter()
                .filter(|f| f.tag == tag)
                .map(|f| f.first_token_us)
                .min()
                .unwrap()
        };
        (first(3), first(8))
    };
    // declared fan of 4, only 2 ever arrive: the hold lets the cold
    // request jump ahead, and the partial fan is released no earlier
    // than the 5 ms deadline — never stranded
    let (fan_first, cold_first) = run(4);
    assert!(
        cold_first < fan_first,
        "hold did not let the cold request ahead ({cold_first} vs {fan_first})"
    );
    assert!(
        fan_first >= 5_000,
        "partial fan released before gang_hold_ms: {fan_first}"
    );
    // control: the declared fan actually arrives (2 of 2) — admission
    // releases on arrival and stays FCFS, no timeout involved
    let (fan_first, cold_first) = run(2);
    assert!(
        fan_first < cold_first,
        "satisfied fan should admit FCFS ({fan_first} vs {cold_first})"
    );
}

#[test]
fn straggler_of_admitted_fan_coadmits_without_hold() {
    // a fan member arriving after its mates already admitted must join
    // them immediately — the hold is for assembling a fan, not for
    // re-counting one that is already in flight (or partly finished)
    // hold far above the ~100ms (virtual) the straggler's own prefill
    // costs, so "held" and "not held" separate unambiguously
    let hold_ms = 200u64;
    let mut e = engine_with(CachePolicy::Disaggregated, 32, true, hold_ms);
    // the first member arrives alone (fan 3, stragglers pending): the
    // hold times out via idle fast-forward and it admits partially
    e.submit(tagged_req(1, 6, 3, 1, toks(80, 700), 64, 0));
    let mut guard = 0;
    while e.metrics.decode_steps == 0 {
        assert_eq!(e.tick().unwrap(), Tick::Progress, "member 1 never admitted");
        guard += 1;
        assert!(guard < 10_000, "stalled waiting for member 1");
    }
    // member 2 arrives while member 1 decodes: live count (2) is still
    // below the declared fan (3), but an admitted mate exists — no hold
    let arrival = e.now_us();
    e.submit(tagged_req(2, 6, 3, 2, toks(80, 701), 4, arrival));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 2);
    let m2 = fin.iter().find(|f| f.id == 2).unwrap();
    assert!(
        m2.ttft_us() < hold_ms * 1000,
        "straggler was held despite an admitted mate: ttft {}us",
        m2.ttft_us()
    );
    e.check_quiescent().unwrap();
}

#[test]
fn untagged_requests_form_no_gang() {
    // tag 0 is plain serving traffic: concurrent untagged requests must
    // not be classed as one workflow or counted as co-admissions
    let mut e = engine(CachePolicy::Disaggregated, 32); // req() uses tag 0
    let shared = toks(120, 710);
    for i in 0..4u64 {
        let mut p = shared.clone();
        p.extend(toks(6, 720 + i));
        e.submit(req(i + 1, i as u32, p, 8, i));
    }
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 4);
    assert_eq!(
        e.metrics.gang_admitted, 0,
        "untagged traffic must not inflate gang_admitted"
    );
    e.check_quiescent().unwrap();
}

#[test]
fn queued_fork_pins_parent_pages_until_admission() {
    let mut e = engine_with(CachePolicy::Disaggregated, 32, true, 25);
    let shared = toks(160, 95);
    let mut primer = shared.clone();
    primer.extend(toks(4, 96));
    e.submit(tagged_req(1, 4, 0, 1, primer, 4, 0));
    run_to_completion(&mut e);
    assert_eq!(e.trees().base.pinned_nodes(), 0);
    let t0 = e.now_us();
    // a long cold request takes the prefill stream, and a fork of tag 4
    // queues behind it (held: it declares a fan of 2 that never fills) —
    // its shared prefix must be pinned the moment it enters the queue
    e.submit(tagged_req(2, 50, 0, 2, toks(256, 97), 4, t0));
    let mut fork = shared.clone();
    fork.extend(toks(6, 98));
    e.submit(tagged_req(3, 4, 2, 3, fork, 4, t0));
    e.tick().unwrap();
    assert!(
        e.trees().base.pinned_nodes() > 0,
        "queued fork left no eviction pins"
    );
    // the hold times out, the fork admits (pins -> leases), all complete
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 2);
    assert_eq!(e.trees().base.pinned_nodes(), 0, "pins leaked");
    assert_eq!(e.trees().residual.pinned_nodes(), 0, "residual pins leaked");
    e.check_quiescent().unwrap();
}

// ---------------------------------------------------------------------------
// randomized invariants (util::prop)
// ---------------------------------------------------------------------------

#[test]
fn prop_engine_conservation_under_random_workloads() {
    // every submitted request is accounted exactly once (finished or
    // OOM-dropped), and the engine quiesces with all pages returned to
    // pool/trees — across random policies, budgets and workload shapes
    crate::util::prop::check("engine-conservation", 24, |rng| {
        let policy = match rng.below(3) {
            0 => CachePolicy::Disaggregated,
            1 => CachePolicy::UnifiedPerAdapter,
            _ => CachePolicy::FullReuse,
        };
        let budget_mb = 2 + rng.below(24);
        let mut e = engine(policy, budget_mb);
        let n = 3 + rng.below(10);
        let shared_len = 32 + rng.below(12) * 16;
        let shared = rng.fork(1).tokens(shared_len, 2000);
        for i in 0..n {
            let mut p = shared.clone();
            let extra = 1 + rng.below(20);
            p.extend(rng.tokens(extra, 2000));
            let max_new = 1 + rng.below(24);
            e.submit(req(
                i as u64,
                rng.below(6) as u32,
                p,
                max_new,
                rng.below(5_000_000) as u64,
            ));
        }
        let fin = run_to_completion(&mut e);
        if fin.len() as u64 + e.metrics.oom_drops != n as u64 {
            return Err(format!(
                "{} finished + {} dropped != {} submitted (policy {:?}, {}MB)",
                fin.len(),
                e.metrics.oom_drops,
                n,
                policy,
                budget_mb
            ));
        }
        for f in &fin {
            if f.generated.is_empty() {
                return Err(format!("request {} finished without output", f.id));
            }
            if f.finish_us < f.first_token_us {
                return Err("finish before first token".into());
            }
        }
        e.check_quiescent()?;
        Ok(())
    });
}

#[test]
fn prop_hits_never_exceed_prompt_and_clock_is_monotone() {
    crate::util::prop::check("engine-hit-bounds", 16, |rng| {
        let mut e = engine(CachePolicy::Disaggregated, 16);
        let shared = rng.fork(2).tokens(160, 2000);
        let n = 4 + rng.below(6);
        for i in 0..n {
            let mut p = shared.clone();
            let extra = 1 + rng.below(8);
            p.extend(rng.tokens(extra, 2000));
            e.submit(req(i as u64, (i % 3) as u32, p, 8, (i * 700) as u64));
        }
        let fin = run_to_completion(&mut e);
        let mut last_finish = 0;
        for f in &fin {
            if f.hit_full + f.hit_partial > f.prompt_len {
                return Err(format!(
                    "hits {}+{} exceed prompt {}",
                    f.hit_full, f.hit_partial, f.prompt_len
                ));
            }
            last_finish = last_finish.max(f.finish_us);
        }
        if e.now_us() < last_finish {
            return Err("engine clock behind finish timestamps".into());
        }
        Ok(())
    });
}

#[test]
fn context_overflow_finishes_at_window_edge() {
    // a request whose generation would cross s_max stops exactly at it
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let s_max = e.meta().s_max;
    let prompt = toks(s_max - 10, 21);
    e.submit(req(1, 0, prompt, 10, 0));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1);
    assert!(fin[0].generated.len() <= 10);
    e.check_quiescent().unwrap();
}

// ---------------------------------------------------------------------
// elastic byte budgets (ISSUE 5): capacity reporting, shrink
// enforcement, pressure accounting
// ---------------------------------------------------------------------

#[test]
fn tiny_budget_reported_capacity_never_exceeds_budget() {
    // one base page of budget: the pools' 4-page construction floors
    // give more physical capacity than the budget will ever grant, so
    // utilization derived from the raw pool size would read >100% — the
    // *reported* capacity must clamp to the budget
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: 64 << 10,
            capacity_bytes: 0,
        },
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
    let mut e = Engine::new(cfg, Box::new(sim)).unwrap();
    assert!(
        e.pool_capacity_bytes() > 64 << 10,
        "expected the 4-page construction floor to exceed the budget"
    );
    assert_eq!(e.capacity_bytes(), 64 << 10, "reported capacity must clamp");
    let j = e.stats_json();
    assert_eq!(j.at(&["capacity_bytes"]).as_usize().unwrap(), 64 << 10);
    assert_eq!(j.at(&["budget_bytes"]).as_usize().unwrap(), 64 << 10);

    // explicit headroom sizes the pools past the budget (lent budget is
    // spendable) but the reported capacity still clamps to the budget
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: 64 << 10,
            capacity_bytes: 1 << 20,
        },
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
    let e = Engine::new(cfg, Box::new(sim)).unwrap();
    assert!(e.pool_capacity_bytes() >= 2 << 20); // each pool sized to capacity
    assert_eq!(e.budget_bytes(), 64 << 10);
    assert_eq!(e.capacity_bytes(), 64 << 10);
}

#[test]
fn budget_shrink_reclaims_cold_pages_but_never_pinned_or_leased() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    // fill the trees with four distinct published contexts
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| toks(160, 100 + i)).collect();
    for (i, p) in prompts.iter().enumerate() {
        e.submit(req(i as u64 + 1, 0, p.clone(), 4, 0));
    }
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 4);
    let used_before = e.used_cache_bytes();
    assert!(used_before > 2 << 20, "cache not filled: {used_before}");

    // an in-flight export lease on context 0 and a queued-fork pin on
    // context 1: a shrink must reclaim around both
    let lease = e.trees.base.match_lease(0, &prompts[0], &mut e.base_pool);
    assert_eq!(lease.tokens, 160);
    let pins = e.trees.base.pin_prefix(0, &prompts[1]);
    assert!(!pins.is_empty());

    let target = used_before * 5 / 8;
    let freed = e.set_budget_bytes(target);
    assert!(freed > 0, "shrink evicted nothing");
    assert!(
        e.used_cache_bytes() <= target,
        "shrink did not converge: {} > {target}",
        e.used_cache_bytes()
    );
    assert_eq!(e.budget_bytes(), target);
    // the leased and pinned prefixes are fully intact
    assert_eq!(e.trees.base.probe_pages(0, &prompts[0]), 10);
    assert_eq!(e.trees.base.probe_pages(0, &prompts[1]), 10);
    assert!(e.trees.base.pinned_nodes() > 0);

    // cleanup: drop the lease (tree + pool refs) and the pins; the
    // engine must be internally consistent afterwards
    e.trees.base.release_path(&lease.path);
    for p in &lease.pages {
        e.base_pool.release(*p);
    }
    e.trees.base.unpin_path(&pins);
    e.check_quiescent().unwrap();
}

#[test]
fn budget_shrink_mid_flight_spares_running_sequences() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    // a cold published context the shrink can reclaim
    let cold = toks(160, 9);
    e.submit(req(1, 0, cold.clone(), 4, 0));
    assert_eq!(run_to_completion(&mut e).len(), 1);

    // a sequence mid-decode: prompt 120 + 8 new tokens stays within the
    // 8 pages its prefill allocated, so it needs no further allocation
    let warm = toks(120, 10);
    e.submit(req(2, 1, warm.clone(), 8, e.now_us()));
    for _ in 0..10_000 {
        e.tick().unwrap();
        if e.seqs.get(&2).is_some_and(|s| s.phase == Phase::Decode) {
            break;
        }
    }
    assert!(
        e.seqs.get(&2).is_some_and(|s| s.phase == Phase::Decode),
        "sequence never reached decode"
    );

    // shrink below current usage: the cold context goes, the running
    // sequence's pages (pool-shared with the tree, refcount > 1) stay
    let target = e.used_cache_bytes() - (600 << 10);
    e.set_budget_bytes(target);
    assert!(e.used_cache_bytes() <= target);
    assert_eq!(
        e.trees.base.probe_pages(0, &cold),
        0,
        "cold context survived the shrink"
    );

    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1, "running sequence was killed by the shrink");
    assert_eq!(fin[0].generated.len(), 8);
    assert_eq!(e.metrics.oom_drops, 0);
    e.check_quiescent().unwrap();
}

#[test]
fn budget_denials_counted_and_grow_unblocks() {
    // a 64 KiB budget (one base page) with 2 MiB of physical headroom:
    // the first request is denied admission by the budget and dropped;
    // growing the budget lets an identical request through — lent
    // budget is actually spendable thanks to the pool headroom
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: 64 << 10,
            capacity_bytes: 2 << 20,
        },
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
    let mut e = Engine::new(cfg, Box::new(sim)).unwrap();
    e.submit(req(1, 0, toks(80, 3), 8, 0));
    assert_eq!(run_to_completion(&mut e).len(), 0);
    assert_eq!(e.metrics.oom_drops, 1);
    assert!(e.metrics.budget_denials >= 1, "budget denial not counted");
    assert_eq!(e.drain_dropped().len(), 1);
    let p = e.budget_pressure();
    assert_eq!(p.budget_bytes, 64 << 10);
    assert_eq!(p.oom_drops, 1);
    assert!(p.budget_denials >= 1);
    assert!(p.capacity_bytes >= 2 << 20);

    e.set_budget_bytes(2 << 20);
    e.submit(req(2, 0, toks(80, 3), 8, e.now_us()));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1, "grown budget still blocked the request");
    assert_eq!(fin[0].generated.len(), 8);
    e.check_quiescent().unwrap();
}

// ---------------------------------------------------------------------
// tiered KV page store (ISSUE 6): demote on evict, promote on fork
// ---------------------------------------------------------------------

#[test]
fn demote_then_promote_restores_byte_identical_pages() {
    let mut e = engine_tiered(8 << 20, 16 << 20);
    let pt = e.cfg.cache.page_tokens;
    // a 3-page published context with distinctive per-float contents
    let t = toks(3 * pt, 40);
    let mut pages = Vec::new();
    for i in 0..3usize {
        let p = e.base_pool.alloc().unwrap();
        for (j, x) in e.base_pool.page_data_mut(p).iter_mut().enumerate() {
            *x = (i * 100_000 + j) as f32;
        }
        pages.push(p);
    }
    e.trees.base.insert(0, &t, &pages, &mut e.base_pool);
    for p in pages {
        e.base_pool.release(p);
    }
    assert_eq!(e.base_pool.used_pages(), 3);

    // demote: eviction moves all three pages into the host tier
    assert_eq!(e.evict_demote(Which::Base, 100, true), 3);
    assert_eq!(e.metrics.demoted_pages, 3);
    assert_eq!(e.base_pool.used_pages(), 0);
    assert_eq!(e.trees.base.probe_pages(0, &t), 0);
    let tier = e.tier().unwrap();
    assert_eq!(tier.entries(), 3);
    assert!(tier.bytes() <= tier.budget_bytes());
    tier.check_invariants().unwrap();

    // promote: the whole path comes back, byte for byte
    e.promote_from_tier(Which::Base, 0, &t);
    assert_eq!(e.metrics.promoted_pages, 3);
    assert_eq!(e.metrics.tier_hits, 1);
    assert_eq!(e.metrics.recompute_tokens_saved_tier, (3 * pt) as u64);
    assert_eq!(e.trees.base.probe_pages(0, &t), 3);
    let m = e.trees.base.match_lease(0, &t, &mut e.base_pool);
    assert_eq!(m.pages.len(), 3);
    for (i, &p) in m.pages.iter().enumerate() {
        for (j, &x) in e.base_pool.page_data(p).iter().enumerate() {
            assert_eq!(x, (i * 100_000 + j) as f32, "page {i} float {j} corrupted");
        }
    }
    e.trees.base.release_path(&m.path);
    for p in m.pages {
        e.base_pool.release(p);
    }

    // promotion invalidated the tier records ("all referencing nodes
    // released"): compaction reclaims every retained byte
    let tier = e.tier().unwrap();
    assert_eq!(tier.entries(), 0, "promotion must invalidate tier records");
    assert!(tier.bytes() > 0, "dead bytes retained until compaction");
    assert!(e.tier_compact() > 0);
    assert_eq!(e.tier().unwrap().bytes(), 0);
    e.check_quiescent().unwrap();
}

#[test]
fn promotion_refused_when_pool_budget_exhausted_leaks_nothing() {
    let page = e_base_page_bytes();
    let mut e = engine_tiered(2 * page, 1 << 20);
    let pt = e.cfg.cache.page_tokens;
    // a returning session's two pages, demoted into the tier
    let t = toks(2 * pt, 41);
    let mut pages = Vec::new();
    for _ in 0..2 {
        pages.push(e.base_pool.alloc().unwrap());
    }
    e.trees.base.insert(0, &t, &pages, &mut e.base_pool);
    for p in pages {
        e.base_pool.release(p);
    }
    assert_eq!(e.evict_demote(Which::Base, 100, true), 2);
    assert_eq!(e.tier().unwrap().entries(), 2);

    // an unrelated *leased* context now occupies the whole pool budget,
    // so promotion cannot fund a single page by evicting
    let u = toks(2 * pt, 42);
    let mut upages = Vec::new();
    for _ in 0..2 {
        upages.push(e.base_pool.alloc().unwrap());
    }
    e.trees.base.insert(0, &u, &upages, &mut e.base_pool);
    for p in upages {
        e.base_pool.release(p);
    }
    let lease = e.trees.base.match_lease(0, &u, &mut e.base_pool);
    assert_eq!(lease.pages.len(), 2);
    let used_before = e.base_pool.used_pages();

    // the lookup finds the records but the refusal must be clean: no
    // promoted pages, no leaked allocations, records intact for a later
    // (funded) attempt
    e.promote_from_tier(Which::Base, 0, &t);
    assert_eq!(e.metrics.promoted_pages, 0);
    assert_eq!(e.metrics.tier_hits, 1, "the tier lookup still counts");
    assert_eq!(e.base_pool.used_pages(), used_before, "pages leaked");
    assert_eq!(e.trees.base.probe_pages(0, &t), 0);
    assert_eq!(e.tier().unwrap().entries(), 2, "records must survive the refusal");
    e.base_pool.check_invariants().unwrap();
    e.tier().unwrap().check_invariants().unwrap();

    e.trees.base.release_path(&lease.path);
    for p in lease.pages {
        e.base_pool.release(p);
    }
    e.check_quiescent().unwrap();
}

/// One base page at the llama3-8b-sim geometry with 16-token pages:
/// 16 tokens x 4 layers x K+V x kv_width 128 x 4 bytes.
fn e_base_page_bytes() -> usize {
    16 * 4 * 2 * 128 * 4
}

#[test]
fn returning_session_promotes_instead_of_recomputing() {
    // session A visits, session B's working set forces A's pages out of
    // the 2 MB pool, A returns. With the tier on, the return visit
    // promotes the demoted pages back (bytes) instead of re-prefilling
    // them (FLOPs); tier off (0 bytes) is the identical-construction
    // control.
    let run = |tier_bytes: usize| {
        let mut e = engine_tiered(2 << 20, tier_bytes);
        let pa = toks(300, 50);
        let pb = toks(300, 51);
        e.submit(req(1, 0, pa.clone(), 8, 0));
        assert_eq!(run_to_completion(&mut e).len(), 1);
        e.submit(req(2, 1, pb, 8, e.now_us() + 1));
        assert_eq!(run_to_completion(&mut e).len(), 1);
        e.submit(req(3, 0, pa, 8, e.now_us() + 1));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 1);
        e.check_quiescent().unwrap();
        (
            fin[0].computed_prompt,
            fin[0].hit_full + fin[0].hit_partial,
            e.metrics.promoted_pages,
            e.metrics.tier_hits,
        )
    };
    let (warm_computed, warm_hits, promoted, hits) = run(64 << 20);
    let (cold_computed, cold_hits, promoted_off, _) = run(0);
    assert_eq!(promoted_off, 0, "tier off must never promote");
    assert!(promoted > 0, "returning session never promoted");
    assert!(hits > 0, "tier lookups never hit");
    assert!(
        warm_computed < cold_computed,
        "tier saved no prompt recompute: {warm_computed} vs {cold_computed}"
    );
    assert!(
        warm_hits > cold_hits,
        "tier did not raise the hit tokens: {warm_hits} vs {cold_hits}"
    );
}

#[test]
fn prefetch_lease_pins_pages_and_survives_eviction_pressure() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    // publish four contexts; the first is the successor step's prefix
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| toks(160, 300 + i)).collect();
    for (i, p) in prompts.iter().enumerate() {
        e.submit(req(i as u64 + 1, 0, p.clone(), 4, 0));
    }
    assert_eq!(run_to_completion(&mut e).len(), 4);

    let pages = e.prefetch_pin(1, 0, &prompts[0]);
    assert!(pages > 0, "resident prefix covered no pages");
    assert_eq!(e.metrics.prefetched_pages, pages as u64);
    assert_eq!(e.prefetch_live_leases(), 1);

    // eviction pressure reclaims the cold contexts but never the leased
    // prefix: shrink to well under the four-context working set
    let used = e.used_cache_bytes();
    let freed = e.set_budget_bytes(used * 5 / 8);
    assert!(freed > 0, "shrink evicted nothing");
    assert_eq!(e.trees.base.probe_pages(0, &prompts[0]), 10);

    // the warmed step arrived: release is a hit, pages unpin, and the
    // engine is fully quiescent again
    assert!(e.prefetch_release(1, true));
    assert_eq!(e.metrics.prefetch_hits, 1);
    assert_eq!(e.metrics.prefetch_wasted, 0);
    assert_eq!(e.prefetch_live_leases(), 0);
    e.check_quiescent().unwrap();
}

#[test]
fn prefetch_release_is_exactly_once_and_unknown_ids_are_noops() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    let ctx = toks(160, 42);
    e.submit(req(1, 0, ctx.clone(), 4, 0));
    assert_eq!(run_to_completion(&mut e).len(), 1);

    let pages = e.prefetch_pin(7, 0, &ctx);
    assert!(pages > 0);

    // abandonment: the one live release accounts the lease's pages as
    // wasted ...
    assert!(e.prefetch_release(7, false));
    assert_eq!(e.metrics.prefetch_wasted, pages as u64);
    // ... and every later release of the same id — or of an id that was
    // never issued (the stale-lease case) — is a no-op on both the pin
    // ledger and the counters
    assert!(!e.prefetch_release(7, false));
    assert!(!e.prefetch_release(7, true));
    assert!(!e.prefetch_release(999, true));
    assert_eq!(e.metrics.prefetch_wasted, pages as u64);
    assert_eq!(e.metrics.prefetch_hits, 0);
    assert_eq!(e.trees.base.pinned_nodes(), 0);
    e.check_quiescent().unwrap();
}

#[test]
fn prefetch_pin_without_resident_prefix_leaves_no_lease() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    // nothing cached yet (the predecessors are still prefilling):
    // zero coverage, no lease, nothing pinned — the caller retries later
    assert_eq!(e.prefetch_pin(1, 0, &toks(160, 5)), 0);
    // a sub-page prefix can never cover a full page either
    assert_eq!(e.prefetch_pin(2, 0, &toks(8, 6)), 0);
    assert_eq!(e.prefetch_live_leases(), 0);
    assert_eq!(e.metrics.prefetched_pages, 0);
    assert_eq!(e.trees.base.pinned_nodes(), 0);
    e.check_quiescent().unwrap();
}

#[test]
fn prefetch_reissue_replaces_the_old_pin_and_releases_once() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    let ctx = toks(160, 77);
    e.submit(req(1, 0, ctx.clone(), 4, 0));
    assert_eq!(run_to_completion(&mut e).len(), 1);

    let first = e.prefetch_pin(3, 0, &ctx);
    assert!(first > 0);
    let pinned_once = e.trees.base.pinned_nodes();
    // a supervisor retry reissues the same lease id: the old pin path is
    // unpinned before the new one lands, so pins never accumulate
    let second = e.prefetch_pin(3, 0, &ctx);
    assert_eq!(second, first);
    assert_eq!(e.trees.base.pinned_nodes(), pinned_once);
    assert_eq!(e.prefetch_live_leases(), 1);

    // one release fully unwinds the reissued lease
    assert!(e.prefetch_release(3, true));
    assert_eq!(e.trees.base.pinned_nodes(), 0);
    e.check_quiescent().unwrap();
}

#[test]
fn leaked_prefetch_lease_fails_quiescence() {
    let mut e = engine(CachePolicy::Disaggregated, 8);
    let ctx = toks(160, 88);
    e.submit(req(1, 0, ctx.clone(), 4, 0));
    assert_eq!(run_to_completion(&mut e).len(), 1);

    assert!(e.prefetch_pin(4, 0, &ctx) > 0);
    let err = e.check_quiescent().unwrap_err();
    assert!(err.contains("prefetch lease"), "unexpected error: {err}");
    assert!(e.prefetch_release(4, true));
    e.check_quiescent().unwrap();
}
