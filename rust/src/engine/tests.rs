//! Engine unit tests over the sim executor: scheduling, fork semantics,
//! policy-specific sharing behaviour, memory pressure, and quiescence.

use super::*;
use crate::config::{CacheConfig, CachePolicy, EngineConfig, SchedulerConfig};
use crate::exec::SimExecutor;
use crate::util::rng::Rng;

fn engine(policy: CachePolicy, budget_mb: usize) -> Engine {
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig {
            page_tokens: 16,
            budget_bytes: budget_mb << 20,
        },
        sched: SchedulerConfig::default(),
        seed: 7,
        greedy: true,
    };
    let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8, 16]).unwrap();
    Engine::new(cfg, Box::new(sim)).unwrap()
}

fn req(id: u64, adapter: u32, tokens: Vec<u32>, max_new: usize, arrival_us: u64) -> Request {
    Request {
        id,
        tag: 0,
        adapter,
        tokens,
        max_new,
        arrival_us,
        ignore_eos: true,
    }
}

fn run_to_completion(e: &mut Engine) -> Vec<crate::metrics::FinishedRequest> {
    let mut out = Vec::new();
    for _ in 0..200_000 {
        match e.tick().unwrap() {
            Tick::Progress => out.extend(e.drain_finished()),
            Tick::Idle => {
                if let Some(t) = e.next_pending_arrival() {
                    let now = e.now_us().max(t);
                    e.now_us = now;
                } else {
                    break;
                }
            }
        }
    }
    out
}

fn toks(n: usize, seed: u64) -> Vec<u32> {
    Rng::seeded(seed).tokens(n, 2000)
}

#[test]
fn single_request_completes() {
    let mut e = engine(CachePolicy::Disaggregated, 32);
    e.submit(req(1, 0, toks(100, 1), 20, 0));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1);
    assert_eq!(fin[0].generated.len(), 20);
    assert!(fin[0].finish_us > 0);
    assert!(fin[0].first_token_us >= fin[0].arrival_us);
    e.check_quiescent().unwrap();
}

#[test]
fn same_agent_reuses_prefix_all_policies() {
    for policy in [
        CachePolicy::Disaggregated,
        CachePolicy::UnifiedPerAdapter,
        CachePolicy::FullReuse,
    ] {
        let mut e = engine(policy, 32);
        let prompt = toks(200, 2);
        e.submit(req(1, 3, prompt.clone(), 8, 0));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin[0].hit_full, 0, "{policy:?}: cold start");

        // same adapter, same prompt, later arrival
        e.submit(req(2, 3, prompt.clone(), 8, e.now_us() + 1));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 1);
        // everything except the (never-cached) tail is a full hit
        assert!(
            fin[0].hit_full >= 176,
            "{policy:?}: hit_full {} too small",
            fin[0].hit_full
        );
        e.check_quiescent().unwrap();
    }
}

#[test]
fn cross_adapter_fork_is_the_policy_differentiator() {
    let prompt = toks(320, 3);

    // ForkKV: agent 2 inherits agent 1's bCache => large partial hit
    let mut e = engine(CachePolicy::Disaggregated, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt.clone(), 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin[0].hit_full, 0, "different adapter: no full hit");
    assert!(
        fin[0].hit_partial >= 304,
        "bCache must be inherited cross-adapter: {}",
        fin[0].hit_partial
    );

    // prefix caching baseline: nothing shared cross-adapter
    let mut e = engine(CachePolicy::UnifiedPerAdapter, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt.clone(), 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin[0].hit_full, 0);
    assert_eq!(fin[0].hit_partial, 0);

    // full reuse: everything shared cross-adapter (lossy)
    let mut e = engine(CachePolicy::FullReuse, 32);
    e.submit(req(1, 1, prompt.clone(), 8, 0));
    run_to_completion(&mut e);
    e.submit(req(2, 2, prompt, 8, e.now_us() + 1));
    let fin = run_to_completion(&mut e);
    assert!(fin[0].hit_full >= 304, "full reuse shares everything");
}

#[test]
fn react_chain_hits_grow_with_published_outputs() {
    // agent k+1's prompt extends agent k's prompt+output: each fork should
    // match the previously published span (ForkKV base tree, ns 0)
    let mut e = engine(CachePolicy::Disaggregated, 32);
    let shared = toks(256, 4);
    let mut transcript = shared.clone();
    let mut id = 0;
    for step in 0..4u32 {
        id += 1;
        e.submit(req(id, step, transcript.clone(), 16, e.now_us() + 1));
        let fin = run_to_completion(&mut e);
        assert_eq!(fin.len(), 1);
        if step > 0 {
            // bCache from previous agents (different adapters) inherited
            assert!(
                fin[0].hit_partial + fin[0].hit_full >= (transcript.len() / 2),
                "step {step}: inherited {} of {}",
                fin[0].hit_partial + fin[0].hit_full,
                transcript.len()
            );
        }
        transcript.extend(fin[0].generated.iter().copied());
        transcript.extend(Rng::seeded(step as u64).tokens(8, 2000)); // tool output
    }
    e.check_quiescent().unwrap();
}

#[test]
fn decode_batches_fill_under_concurrency() {
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let shared = toks(128, 5);
    for i in 0..8 {
        let mut p = shared.clone();
        p.extend(toks(8, 100 + i));
        e.submit(req(i, i as u32, p, 32, 0));
    }
    run_to_completion(&mut e);
    assert!(
        e.metrics.avg_decode_batch() > 3.0,
        "decode batching too small: {}",
        e.metrics.avg_decode_batch()
    );
    e.check_quiescent().unwrap();
}

#[test]
fn memory_pressure_evicts_and_preempts_but_completes() {
    // deliberately tiny budget: 2 MB for 16 concurrent agents
    let mut e = engine(CachePolicy::UnifiedPerAdapter, 2);
    let shared = toks(300, 6);
    for i in 0..16 {
        let mut p = shared.clone();
        p.extend(toks(10, 200 + i));
        e.submit(req(i, i as u32, p, 24, (i * 1000) as u64));
    }
    let fin = run_to_completion(&mut e);
    assert_eq!(
        fin.len() as u64 + e.metrics.oom_drops,
        16,
        "all requests finish or are accounted as drops"
    );
    assert!(fin.len() >= 12, "most requests must still complete");
    e.check_quiescent().unwrap();
}

#[test]
fn forkkv_serves_more_agents_in_same_budget() {
    // the core paper claim at allocator level: with a fixed budget and N
    // agents on the same context, ForkKV sustains a much higher hit rate
    let shared = toks(320, 7);
    let run = |policy| {
        let mut e = engine(policy, 4);
        for i in 0..12 {
            let mut p = shared.clone();
            p.extend(toks(6, 300 + i));
            e.submit(req(i, i as u32, p, 8, (i * 500) as u64));
        }
        run_to_completion(&mut e);
        (
            e.metrics.hit_rate()
                + e.metrics.hit_partial_tokens as f64 / e.metrics.prompt_tokens as f64,
            e.metrics.preemptions,
        )
    };
    let (fork_shared_frac, _) = run(CachePolicy::Disaggregated);
    let (unified_shared_frac, _) = run(CachePolicy::UnifiedPerAdapter);
    assert!(
        fork_shared_frac > unified_shared_frac + 0.3,
        "forkkv shared fraction {fork_shared_frac:.2} vs unified {unified_shared_frac:.2}"
    );
}

#[test]
fn driver_loop_with_poisson_arrivals() {
    struct D {
        released: usize,
        finished: usize,
        next_t: u64,
        rng: Rng,
        shared: Vec<u32>,
    }
    impl Driver for D {
        fn poll(&mut self, now: u64, fin: &[crate::metrics::FinishedRequest]) -> Vec<Request> {
            self.finished += fin.len();
            let mut out = Vec::new();
            let _ = now;
            while self.released < 10 {
                self.released += 1;
                let mut p = self.shared.clone();
                p.extend(self.rng.tokens(4, 2000));
                out.push(Request {
                    id: self.released as u64,
                    tag: 1,
                    adapter: (self.released % 4) as u32,
                    tokens: p,
                    max_new: 8,
                    arrival_us: self.next_t,
                    ignore_eos: true,
                });
                self.next_t += (self.rng.exponential(2.0) * 1e6) as u64;
            }
            out
        }
        fn done(&self) -> bool {
            self.released == 10 && self.finished == 10
        }
    }
    let mut e = engine(CachePolicy::Disaggregated, 32);
    let mut d = D {
        released: 0,
        finished: 0,
        next_t: 0,
        rng: Rng::seeded(9),
        shared: toks(200, 8),
    };
    let fin = e.run_driver(&mut d).unwrap();
    assert_eq!(fin.len(), 10);
    assert!(d.done());
    e.check_quiescent().unwrap();
}

#[test]
fn deterministic_under_fixed_seed() {
    let run = || {
        let mut e = engine(CachePolicy::Disaggregated, 8);
        for i in 0..6 {
            e.submit(req(i, i as u32, toks(150, 10 + i), 12, i * 2000));
        }
        let fin = run_to_completion(&mut e);
        (
            e.now_us(),
            fin.iter().map(|f| f.finish_us).collect::<Vec<_>>(),
            fin.iter()
                .flat_map(|f| f.generated.clone())
                .collect::<Vec<_>>(),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn migration_export_import_moves_cache_hits_across_engines() {
    // two peer shards: prime one, migrate its pages to the other, and
    // the "spilled" request must hit there as if it had stayed home
    let mut home = engine(CachePolicy::Disaggregated, 32);
    let mut target = engine(CachePolicy::Disaggregated, 32);
    let prompt = toks(200, 30);
    home.submit(req(1, 3, prompt.clone(), 8, 0));
    run_to_completion(&mut home);

    // probe over the admit_fork match window (prompt minus final token)
    let window = &prompt[..prompt.len() - 1];
    let est = home.migration_probe(3, window);
    assert!(est.base_pages >= 12, "home not primed: {est:?}");
    assert_eq!(est.res_pages, est.base_pages, "both components published");
    assert_eq!(est.tokens_saved, est.base_pages * 16);
    assert!(est.bytes > 0);
    let cold = target.migration_probe(3, window);
    assert_eq!(cold.tokens_saved, 0, "target starts cold");

    // export -> import round trip
    let payload = home.export_pages(3, window);
    assert_eq!(payload.pages(), est.base_pages + est.res_pages);
    assert_eq!(payload.tokens_saved(), est.tokens_saved);
    assert_eq!(home.metrics.exported_pages, payload.pages() as u64);
    let imported = target.import_pages(&payload);
    assert_eq!(imported, payload.pages());
    assert_eq!(target.metrics.migrated_pages, imported as u64);
    assert!(target.metrics.migrated_bytes > 0);
    assert_eq!(
        target.metrics.recompute_tokens_saved as usize,
        payload.tokens_saved()
    );

    // re-import of the same payload dedups against the tree: no double
    // adoption, no refcount drift — and crucially no metric inflation
    // (savings already banked must not be reported twice)
    let used_before = target.base_pool().used_pages();
    let (pages_before, saved_before, bytes_before) = (
        target.metrics.migrated_pages,
        target.metrics.recompute_tokens_saved,
        target.metrics.migrated_bytes,
    );
    assert_eq!(target.import_pages(&payload), 0, "repeat import adopts nothing");
    assert_eq!(target.base_pool().used_pages(), used_before);
    assert_eq!(target.metrics.migrated_pages, pages_before);
    assert_eq!(target.metrics.recompute_tokens_saved, saved_before);
    assert_eq!(target.metrics.migrated_bytes, bytes_before);

    // the spilled request now forks locally instead of recomputing
    target.submit(req(9, 3, prompt.clone(), 8, 0));
    let fin = run_to_completion(&mut target);
    assert_eq!(fin.len(), 1);
    assert!(
        fin[0].hit_full >= est.tokens_saved,
        "spilled request missed the migrated pages: hit {} < saved {}",
        fin[0].hit_full,
        est.tokens_saved
    );
    target.check_quiescent().unwrap();
    home.check_quiescent().unwrap();
}

#[test]
fn migration_import_respects_budget_without_preempting() {
    // a tiny target shard adopts only the payload prefix that fits its
    // budget — and never corrupts its pool doing so
    let mut home = engine(CachePolicy::Disaggregated, 32);
    let mut target = engine(CachePolicy::Disaggregated, 1);
    let prompt = toks(400, 33);
    home.submit(req(1, 2, prompt.clone(), 8, 0));
    run_to_completion(&mut home);
    let window = &prompt[..prompt.len() - 1];
    let payload = home.export_pages(2, window);
    assert!(payload.pages() > 0);
    let imported = target.import_pages(&payload);
    assert!(imported > 0, "nothing fit a 1 MB budget?");
    assert!(
        imported < payload.pages(),
        "a 1 MB shard cannot hold the whole payload ({} pages)",
        payload.pages()
    );
    assert!(
        target.used_cache_bytes() <= 1 << 20,
        "import blew the byte budget"
    );
    target.base_pool().check_invariants().unwrap();
    target.trees().base.check_invariants(target.base_pool()).unwrap();
    // a geometry-mismatched payload is refused outright
    let mut wrong = payload.clone();
    wrong.page_tokens += 1;
    assert_eq!(target.import_pages(&wrong), 0);
}

#[test]
fn decode_steady_state_does_not_grow_scratch() {
    // the per-tick gather path must reuse engine-owned buffers: once the
    // decode loop reaches steady state, no scratch vector may grow
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let shared = toks(96, 31);
    for i in 0..6 {
        let mut p = shared.clone();
        p.extend(toks(8, 400 + i));
        e.submit(req(i, i as u32, p, 48, 0));
    }
    let mut warm = 0;
    while warm < 400 && e.metrics.decode_steps < 10 {
        assert_eq!(e.tick().unwrap(), Tick::Progress, "workload stalled");
        warm += 1;
    }
    assert!(e.metrics.decode_steps >= 10, "never reached steady decode");
    let caps = e.decode_scratch_caps();
    for step in 0..30 {
        assert_eq!(e.tick().unwrap(), Tick::Progress);
        assert_eq!(
            e.decode_scratch_caps(),
            caps,
            "per-decode-step heap growth at step {step}"
        );
    }
    e.drain_finished();
}

// ---------------------------------------------------------------------------
// randomized invariants (util::prop)
// ---------------------------------------------------------------------------

#[test]
fn prop_engine_conservation_under_random_workloads() {
    // every submitted request is accounted exactly once (finished or
    // OOM-dropped), and the engine quiesces with all pages returned to
    // pool/trees — across random policies, budgets and workload shapes
    crate::util::prop::check("engine-conservation", 24, |rng| {
        let policy = match rng.below(3) {
            0 => CachePolicy::Disaggregated,
            1 => CachePolicy::UnifiedPerAdapter,
            _ => CachePolicy::FullReuse,
        };
        let budget_mb = 2 + rng.below(24);
        let mut e = engine(policy, budget_mb);
        let n = 3 + rng.below(10);
        let shared_len = 32 + rng.below(12) * 16;
        let shared = rng.fork(1).tokens(shared_len, 2000);
        for i in 0..n {
            let mut p = shared.clone();
            let extra = 1 + rng.below(20);
            p.extend(rng.tokens(extra, 2000));
            let max_new = 1 + rng.below(24);
            e.submit(req(
                i as u64,
                rng.below(6) as u32,
                p,
                max_new,
                rng.below(5_000_000) as u64,
            ));
        }
        let fin = run_to_completion(&mut e);
        if fin.len() as u64 + e.metrics.oom_drops != n as u64 {
            return Err(format!(
                "{} finished + {} dropped != {} submitted (policy {:?}, {}MB)",
                fin.len(),
                e.metrics.oom_drops,
                n,
                policy,
                budget_mb
            ));
        }
        for f in &fin {
            if f.generated.is_empty() {
                return Err(format!("request {} finished without output", f.id));
            }
            if f.finish_us < f.first_token_us {
                return Err("finish before first token".into());
            }
        }
        e.check_quiescent()?;
        Ok(())
    });
}

#[test]
fn prop_hits_never_exceed_prompt_and_clock_is_monotone() {
    crate::util::prop::check("engine-hit-bounds", 16, |rng| {
        let mut e = engine(CachePolicy::Disaggregated, 16);
        let shared = rng.fork(2).tokens(160, 2000);
        let n = 4 + rng.below(6);
        for i in 0..n {
            let mut p = shared.clone();
            let extra = 1 + rng.below(8);
            p.extend(rng.tokens(extra, 2000));
            e.submit(req(i as u64, (i % 3) as u32, p, 8, (i * 700) as u64));
        }
        let fin = run_to_completion(&mut e);
        let mut last_finish = 0;
        for f in &fin {
            if f.hit_full + f.hit_partial > f.prompt_len {
                return Err(format!(
                    "hits {}+{} exceed prompt {}",
                    f.hit_full, f.hit_partial, f.prompt_len
                ));
            }
            last_finish = last_finish.max(f.finish_us);
        }
        if e.now_us() < last_finish {
            return Err("engine clock behind finish timestamps".into());
        }
        Ok(())
    });
}

#[test]
fn context_overflow_finishes_at_window_edge() {
    // a request whose generation would cross s_max stops exactly at it
    let mut e = engine(CachePolicy::Disaggregated, 64);
    let s_max = e.meta().s_max;
    let prompt = toks(s_max - 10, 21);
    e.submit(req(1, 0, prompt, 10, 0));
    let fin = run_to_completion(&mut e);
    assert_eq!(fin.len(), 1);
    assert!(fin[0].generated.len() <= 10);
    e.check_quiescent().unwrap();
}
