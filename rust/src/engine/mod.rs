//! The ForkKV serving engine: continuous batching + chunked prefill over
//! the paged dual pools and the DualRadixTree, with OS-style fork/CoW
//! admission (paper §4–5) — the L3 coordination contribution.
//!
//! One engine implementation serves all three cache policies (DESIGN.md §3):
//!   - `Disaggregated` (ForkKV): bCache keyed by tokens (shared), rCache
//!     keyed by (adapter, tokens); fork inherits the base, CoW-allocates
//!     the residual.
//!   - `UnifiedPerAdapter` (vLLM/SGLang prefix caching): monolithic merged
//!     KV keyed by (adapter, tokens) — lossless baseline.
//!   - `FullReuse`: monolithic merged KV keyed by tokens only — lossy
//!     baseline.
//! The policies differ *only* in tree keying and which tensors are
//! persisted; scheduler, allocator and kernel path are shared, so the
//! benchmarks isolate exactly the paper's variable.
//!
//! Determinism: the engine is a discrete-event state machine over a
//! monotone clock. With `SimExecutor` the clock is fully virtual; with
//! `PjrtExecutor` it advances by measured execution time — the same
//! scheduler code path either way.
//!
//! Replication: the engine is deliberately single-owner — no interior
//! locking, no shared caches. The serving layer scales it by running N
//! `Engine` instances as peer *shards* (`server::Server::start_sharded`),
//! each with its own executor, pools and trees and a 1/N slice of the
//! byte budget (`EngineConfig::shard_slice`); the `router` module decides
//! which shard a request's prefix affinity lands it on. The slice is
//! *elastic*: the server's rebalance supervisor moves budget between
//! shards at runtime (`Engine::set_budget_bytes`, `rebalance` module).
//! Per-shard determinism is preserved: a shard's event stream depends
//! only on the requests and budget moves routed to it.
//!
//! CoW invariant (checked by debug assertions + tests): a page is written
//! only while its refcount is 1. Fork inheritance is page-aligned, the
//! final prompt token is never served from cache, and only full pages are
//! published to the trees — together these guarantee divergence always
//! lands in fresh pages, so sharing never requires a copy.
#![warn(missing_docs)]

use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::batch::{self, SeqSlab, SlabSpec};
use crate::config::{CachePolicy, EngineConfig};
use crate::exec::{CostModel, Executor};
use crate::kvcache::{pages_for, BlockPool, PageId, PoolSpec};
use crate::metrics::{DropReason, DroppedRequest, EngineMetrics, FinishedRequest};
use crate::migrate::{export_component, MigrationEstimate, MigrationPayload};
use crate::radix::{DualRadixTree, MatchResult, PinPath};
use crate::rebalance::BudgetPressure;
use crate::tier::{Component, PageKey, TierStore};
use crate::util::json::Json;
use crate::runtime::{argmax, DecodeArgs, PrefillArgs};
use crate::util::rng::Rng;
use crate::util::tokenizer::EOS;

/// Namespace scheme per policy (radix-tree key prefix).
fn base_ns(policy: CachePolicy, adapter: u32) -> u32 {
    match policy {
        CachePolicy::Disaggregated => 0, // globally shared bCache
        CachePolicy::UnifiedPerAdapter => 1 + adapter,
        CachePolicy::FullReuse => 0,
    }
}

/// One generation request as the engine sees it: the prompt, its adapter
/// namespace, the generation bound, and the workflow-scheduling hints
/// (`tag`, `fan`) the gang scheduler reads.
#[derive(Debug, Clone)]
pub struct Request {
    /// engine-unique request id (assigned by the shard thread)
    pub id: u64,
    /// opaque grouping tag (workflow id) carried into FinishedRequest.
    /// Tag 0 means *untagged* (the HTTP default): it names no workflow,
    /// so the gang scheduler gives it no tag preference and counts it in
    /// no gang metrics — plain serving traffic keeps plain FCFS.
    pub tag: u64,
    /// LoRA adapter id (namespace key for the rCache / unified trees)
    pub adapter: u32,
    /// prompt token ids
    pub tokens: Vec<u32>,
    /// generation bound: decode stops after this many new tokens
    pub max_new: usize,
    /// request release time on the engine's monotone clock (µs)
    pub arrival_us: u64,
    /// ignore EOS sampling and always decode `max_new` tokens
    /// (benchmarks want deterministic lengths)
    pub ignore_eos: bool,
    /// declared fan width of this request's workflow step (gang-admission
    /// hint): with `sched.gang` on, admission briefly holds this tag until
    /// `fan` requests of the tag are present — or `gang_hold_ms` elapses —
    /// so a MapReduce fan admits together. 0/1 = no hint, never held;
    /// requires a nonzero `tag` (untagged members cannot be counted).
    pub fan: usize,
}

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
enum Phase {
    Prefill,
    Decode,
}

struct Seq {
    req: Request,
    /// prompt + generated tokens
    all: Vec<u32>,
    generated: Vec<u32>,
    phase: Phase,
    // ---- cache state ----
    base_pages: Vec<PageId>,
    res_pages: Vec<PageId>,
    base_lease: Vec<u32>,
    res_lease: Vec<u32>,
    /// inherited coverage (token counts, page aligned)
    base_cached: usize,
    res_cached: usize,
    /// tokens with materialized KV
    processed: usize,
    slab: Option<SeqSlab>,
    // ---- accounting ----
    admitted: bool,
    /// hit metrics recorded (first admission only; re-admissions after
    /// preemption would otherwise count recompute hits as cache wins)
    counted: bool,
    hit_full: usize,
    hit_partial: usize,
    computed_prompt: usize,
    preemptions: u32,
    first_token_us: Option<u64>,
    first_logits: Option<Vec<f32>>,
}

impl Seq {
    fn new(req: Request) -> Self {
        let all = req.tokens.clone();
        Seq {
            req,
            all,
            generated: Vec::new(),
            phase: Phase::Prefill,
            base_pages: Vec::new(),
            res_pages: Vec::new(),
            base_lease: Vec::new(),
            res_lease: Vec::new(),
            base_cached: 0,
            res_cached: 0,
            processed: 0,
            slab: None,
            admitted: false,
            counted: false,
            hit_full: 0,
            hit_partial: 0,
            computed_prompt: 0,
            preemptions: 0,
            first_token_us: None,
            first_logits: None,
        }
    }

    /// FCFS priority: earlier arrivals are strictly higher priority and
    /// are never preempted by younger sequences (prevents livelock).
    fn priority_key(&self) -> (u64, u64) {
        (self.req.arrival_us, self.req.id)
    }

    /// Tokens that must have KV before decode can run. Fresh sequences
    /// prefill the whole prompt (the last row's logits sample the first
    /// token); resumed ones stop one short — the newest token is the next
    /// decode input.
    fn prefill_target(&self) -> usize {
        if self.generated.is_empty() {
            self.req.tokens.len()
        } else {
            self.all.len() - 1
        }
    }
}

/// What one `Engine::tick` call accomplished — the shard thread blocks
/// on its command channel after `Idle` instead of spinning.
#[derive(Debug, PartialEq, Eq)]
pub enum Tick {
    /// the tick prefilled, decoded, or admitted something
    Progress,
    /// nothing to do: no admissible request and no running sequence
    Idle,
}

/// Outcome of the admission scan (`Engine::next_prefill`): which waiting
/// sequence to prefill, and — when every queued candidate is fan-held —
/// the earliest hold deadline so the idle path can fast-forward to it
/// instead of stalling.
struct AdmissionPick {
    sid: Option<u64>,
    hold_until: Option<u64>,
}

/// Per-tag admission state assembled by each `next_prefill` scan (kept
/// in an engine-owned scratch map — cleared, not reallocated): live
/// member count, whether any member is admitted, and the earliest
/// waiting arrival (the fan hold's clock base).
#[derive(Clone, Copy)]
struct TagState {
    live: usize,
    admitted: bool,
    first_wait: u64,
}

/// Workload driver: releases requests over (virtual) time and observes
/// completions (the agent-workflow layer implements this).
pub trait Driver {
    /// Observe completions and release any requests due at `now_us`.
    fn poll(&mut self, now_us: u64, finished: &[FinishedRequest]) -> Vec<Request>;
    /// True once the workload has released and observed everything.
    fn done(&self) -> bool;
}

/// One serving shard: continuous-batching scheduler, paged dual pools,
/// radix trees, optional host-memory tier, and the executor that runs
/// prefill/decode — single-owner by design (the server scales by running
/// N engines as peer shards; see the module docs).
pub struct Engine {
    /// construction-time configuration (policy, cache geometry, scheduler
    /// knobs); public so the serving layer can consult page geometry
    pub cfg: EngineConfig,
    exec: Box<dyn Executor>,
    /// the *currently enforced* byte budget across both pools. Starts at
    /// `cfg.cache.budget_bytes` and moves at runtime via
    /// `set_budget_bytes` — the elastic-budget rebalancer lends budget
    /// between shards. Distinct from the pools' physical capacity, which
    /// is fixed at construction (with headroom; see
    /// `CacheConfig::capacity_bytes`).
    budget_bytes: usize,
    base_pool: BlockPool,
    res_pool: Option<BlockPool>,
    trees: DualRadixTree,
    /// host-memory tier-2 page store (`None` = tiering off): pages the
    /// trees evict are *demoted* here instead of destroyed, and a later
    /// fork admission *promotes* them back when copying their bytes is
    /// priced below recomputing their tokens (see `promote_from_tier`).
    tier: Option<TierStore>,
    /// pricing for the promote-vs-recompute decision — tier bandwidth
    /// (`CostModel::tier_cost_us`) against prefill FLOPs
    /// (`CostModel::prefill_cost_us`). `cfg.tier.cost` when calibrated,
    /// else derived from the model geometry.
    tier_cost: CostModel,
    seqs: HashMap<u64, Seq>,
    pending: BinaryHeap<std::cmp::Reverse<(u64, u64)>>, // (arrival, id)
    pending_reqs: HashMap<u64, Request>,
    /// workflow-eviction pins held on behalf of *queued* (unadmitted)
    /// forks: sid -> (base PinPath, residual PinPath). Taken when a fork
    /// enters the waiting queue, dropped at admission (real leases take
    /// over) or teardown — `check_quiescent` asserts no leaks.
    seq_pins: HashMap<u64, (PinPath, PinPath)>,
    /// cross-step prefetch leases (the KVFlow horizon): server-issued
    /// lease id -> the pinned prefix of a *future* step that has not
    /// submitted yet. Epoch-safe like `seq_pins` (stale releases no-op
    /// after slot recycling), released exactly once on arrival or DAG
    /// abandonment — `check_quiescent` asserts no leaks.
    prefetch_leases: HashMap<u64, PrefetchLease>,
    /// `next_prefill` per-tag scratch (cleared each scan, capacity
    /// retained — the admission scan must not allocate per tick)
    scratch_tags: HashMap<u64, TagState>,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    now_us: u64,
    rng: Rng,
    /// cumulative serving counters (`docs/METRICS.md`), read and
    /// serialized by `stats_json`
    pub metrics: EngineMetrics,
    finished: Vec<FinishedRequest>,
    dropped: Vec<DroppedRequest>,
    /// keep each sequence's first-token logits on its FinishedRequest
    /// (numeric-equivalence tests; off for serving — logits are large)
    pub collect_first_logits: bool,
    max_bucket: usize,
    /// executor bucket ladder, cached (`Executor::decode_buckets`
    /// allocates a fresh Vec — not something to pay per decode step)
    buckets: Vec<usize>,
    /// executor geometry scalars, cached for the same reason: cloning
    /// `ModelMeta` heap-allocates its name/bucket list every step
    scal: MetaScalars,
    // reusable decode scratch slabs + incremental-assembly state
    scratch_kb: Vec<f32>,
    scratch_vb: Vec<f32>,
    scratch_kr: Vec<f32>,
    scratch_vr: Vec<f32>,
    /// (seq id, preemption epoch) of the last stacked batch — the epoch
    /// guards against a re-admitted sequence whose rebuilt slab content
    /// changed beneath an unchanged `filled` watermark
    scratch_rows: Vec<(u64, u32)>,
    scratch_filled: Vec<usize>,
    scratch_bucket: usize,
    // per-tick gather scratch: every decode step used to rebuild these
    // as fresh Vecs; they are now engine-owned and only cleared, so a
    // steady decode loop performs zero heap allocation (asserted by
    // `decode_steady_state_does_not_grow_scratch`)
    scratch_run: Vec<u64>,
    scratch_rows_now: Vec<u64>,
    scratch_tokens: Vec<u32>,
    scratch_cache_lens: Vec<usize>,
    scratch_adapter_ids: Vec<u32>,
    scratch_adapter_on: Vec<bool>,
    scratch_row_keys: Vec<(u64, u32)>,
}

/// The executor-geometry scalars the per-step hot paths need, copied out
/// of `ModelMeta` once at construction (see the `scal` field).
#[derive(Debug, Clone, Copy)]
struct MetaScalars {
    n_layers: usize,
    s_max: usize,
    kv_width: usize,
    rank_max: usize,
    vocab: usize,
    n_adapters: usize,
    chunk: usize,
}

impl Engine {
    /// Build a shard around an executor: size both page pools against the
    /// single byte budget, wire the radix trees for `cfg.cache.policy`,
    /// and (with `cfg.tier.enabled`) attach a host-memory tier store.
    pub fn new(cfg: EngineConfig, exec: Box<dyn Executor>) -> anyhow::Result<Self> {
        let meta = exec.meta().clone();
        let pt = cfg.cache.page_tokens;
        anyhow::ensure!(meta.chunk % pt == 0, "chunk must be page aligned");

        // Both pools draw on ONE byte budget (the experiment's "GPU
        // memory"): each pool's page table is sized so it alone could fill
        // the *capacity*, and `alloc_pages` enforces the (elastic) budget
        // — so the base/residual split is fully dynamic, exactly like two
        // data structures sharing one device memory. Capacity may exceed
        // the budget (`CacheConfig::capacity_bytes` headroom): the extra
        // pages are spendable only when the pool rebalancer lends this
        // shard budget from a cold peer.
        let budget = cfg.cache.budget_bytes;
        let capacity = cfg.cache.capacity_bytes.max(budget);
        let base_pool = BlockPool::new(PoolSpec {
            page_tokens: pt,
            n_layers: meta.n_layers,
            width: meta.kv_width(),
            n_pages: (capacity / (meta.n_layers * 2 * meta.kv_width() * 4 * pt)).max(4),
        });
        let res_pool = if cfg.policy.uses_residual() {
            Some(BlockPool::new(PoolSpec {
                page_tokens: pt,
                n_layers: meta.n_layers,
                width: meta.rank_effective,
                n_pages: (capacity / (meta.n_layers * 2 * meta.rank_effective * 4 * pt))
                    .max(4),
            }))
        } else {
            None
        };
        let buckets = exec.decode_buckets();
        let max_bucket = buckets.iter().copied().max().unwrap_or(1);
        let scal = MetaScalars {
            n_layers: meta.n_layers,
            s_max: meta.s_max,
            kv_width: meta.kv_width(),
            rank_max: meta.rank_max,
            vocab: meta.vocab,
            n_adapters: meta.n_adapters,
            chunk: meta.chunk,
        };
        let tier = (cfg.tier.tier_bytes > 0).then(|| TierStore::new(cfg.tier.tier_bytes));
        let tier_cost = cfg
            .tier
            .cost
            .clone()
            .unwrap_or_else(|| CostModel::derived(&meta));
        Ok(Engine {
            rng: Rng::seeded(cfg.seed ^ 0xF0F0),
            budget_bytes: budget,
            cfg,
            exec,
            base_pool,
            res_pool,
            trees: DualRadixTree::new(pt),
            tier,
            tier_cost,
            seqs: HashMap::new(),
            pending: BinaryHeap::new(),
            pending_reqs: HashMap::new(),
            seq_pins: HashMap::new(),
            prefetch_leases: HashMap::new(),
            scratch_tags: HashMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            now_us: 0,
            metrics: EngineMetrics::default(),
            finished: Vec::new(),
            dropped: Vec::new(),
            collect_first_logits: false,
            max_bucket,
            buckets,
            scal,
            scratch_kb: Vec::new(),
            scratch_vb: Vec::new(),
            scratch_kr: Vec::new(),
            scratch_vr: Vec::new(),
            scratch_rows: Vec::new(),
            scratch_filled: Vec::new(),
            scratch_bucket: 0,
            scratch_run: Vec::new(),
            scratch_rows_now: Vec::new(),
            scratch_tokens: Vec::new(),
            scratch_cache_lens: Vec::new(),
            scratch_adapter_ids: Vec::new(),
            scratch_adapter_on: Vec::new(),
            scratch_row_keys: Vec::new(),
        })
    }

    /// The executor's model geometry (vocab, context window, page size).
    pub fn meta(&self) -> &crate::runtime::ModelMeta {
        self.exec.meta()
    }
    /// Current position of the engine's monotone clock (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us
    }
    /// The bCache page pool (shared base KV).
    pub fn base_pool(&self) -> &BlockPool {
        &self.base_pool
    }
    /// The rCache page pool (per-adapter residual KV); `None` for the
    /// monolithic baseline policies.
    pub fn res_pool(&self) -> Option<&BlockPool> {
        self.res_pool.as_ref()
    }
    /// The radix trees indexing both caches.
    pub fn trees(&self) -> &DualRadixTree {
        &self.trees
    }
    /// Sequences currently admitted or waiting (not yet terminal).
    pub fn active_seqs(&self) -> usize {
        self.seqs.len()
    }
    /// Bytes in use across both pools right now.
    pub fn used_cache_bytes(&self) -> usize {
        self.base_pool.used_bytes() + self.res_pool.as_ref().map_or(0, |p| p.used_bytes())
    }

    // -----------------------------------------------------------------
    // elastic byte budget (dynamic shard budgets; ROADMAP item)
    // -----------------------------------------------------------------

    /// The currently enforced byte budget across both pools. Starts at
    /// `cfg.cache.budget_bytes`; the pool rebalancer moves it at runtime.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Physical pool capacity: the bytes the constructed page tables
    /// could hold if every page were used. May exceed the budget — the
    /// headroom a borrowing shard spends lent budget against — or, with
    /// a budget below the minimum pool floor (4 pages), fall short of it.
    pub fn pool_capacity_bytes(&self) -> usize {
        self.base_pool.capacity_bytes()
            + self.res_pool.as_ref().map_or(0, |p| p.capacity_bytes())
    }

    /// *Reported* capacity: how many bytes this shard could actually
    /// cache right now — the smaller of the physical pools and the
    /// enforced budget. The pools are floored at 4 pages each, so with a
    /// tiny budget the raw pool capacity exceeds what allocation will
    /// ever grant; utilization derived from the raw number read >100%.
    pub fn capacity_bytes(&self) -> usize {
        self.pool_capacity_bytes().min(self.budget_bytes)
    }

    /// Set the enforced byte budget and converge to it: a shrink evicts
    /// cold (unleased, unpinned) radix pages until usage fits the new
    /// budget or nothing cold remains. Previously a shrunk budget was
    /// only consulted at the *next* allocation, so a quiet shard never
    /// reclaimed anything. Returns the pages evicted by the enforcement.
    pub fn set_budget_bytes(&mut self, bytes: usize) -> usize {
        self.budget_bytes = bytes;
        self.enforce_budget()
    }

    /// Evict cold radix pages until `used_cache_bytes() <= budget_bytes`
    /// or no evictable page remains. Never touches running sequences
    /// (their pages are leased or pool-held outside the trees) and never
    /// takes workflow-pinned pages (`RadixTree::evict_unpinned` — a
    /// shrink defers pins exactly like first-pass LRU pressure). Any
    /// remaining overage stays enforced lazily by the allocation-time
    /// budget check, exactly as before. With tiering on, every page this
    /// shrink takes is demoted to the host tier (`evict_demote`), not
    /// destroyed.
    ///
    /// Both trees shrink (base first — its pages are ~n/r times larger):
    /// this is not a violation of the decoupled eviction policy (paper
    /// §5.2), which forbids *one pool's allocation pressure* from
    /// cascading into the other; a budget move is global by definition,
    /// like the construction-time sizing.
    pub fn enforce_budget(&mut self) -> usize {
        let mut freed_pages = 0;
        loop {
            let used = self.used_cache_bytes();
            if used <= self.budget_bytes {
                break;
            }
            let over = used - self.budget_bytes;
            let bpb = self.base_pool.spec().bytes_per_page();
            let freed_base = self.evict_demote(Which::Base, over.div_ceil(bpb), false);
            let used = self.used_cache_bytes();
            let mut freed_res = 0;
            if used > self.budget_bytes {
                if let Some(rpb) =
                    self.res_pool.as_ref().map(|p| p.spec().bytes_per_page())
                {
                    let want = (used - self.budget_bytes).div_ceil(rpb);
                    freed_res = self.evict_demote(Which::Res, want, false);
                }
            }
            if freed_base + freed_res == 0 {
                break; // the remainder is running/pinned/leased state
            }
            freed_pages += freed_base + freed_res;
        }
        freed_pages
    }

    /// This shard's budget-pressure snapshot — what `Cmd::Pressure`
    /// serves the pool rebalancer. Counters are cumulative (the planner
    /// differences them across ticks).
    pub fn budget_pressure(&self) -> BudgetPressure {
        BudgetPressure {
            used_bytes: self.used_cache_bytes(),
            budget_bytes: self.budget_bytes,
            capacity_bytes: self.pool_capacity_bytes(),
            budget_denials: self.metrics.budget_denials,
            alloc_failures: self.base_pool.alloc_failures()
                + self.res_pool.as_ref().map_or(0, |p| p.alloc_failures()),
            oom_drops: self.metrics.oom_drops,
            // the engine has no pool-wide view of which contexts are
            // replicated; the server overlays its replica-map holder
            // count before handing the snapshot to the rebalancer
            hot_replicas: 0,
        }
    }

    /// Queue a request for admission at its `arrival_us`. Contract
    /// violations (empty prompt, prompt+output past the context window)
    /// panic — the serving layer validates before submitting.
    pub fn submit(&mut self, req: Request) {
        let max_ctx = self.exec.meta().s_max;
        assert!(
            req.tokens.len() + req.max_new <= max_ctx,
            "request {}: {} prompt + {} new > s_max {}",
            req.id,
            req.tokens.len(),
            req.max_new,
            max_ctx
        );
        assert!(!req.tokens.is_empty(), "empty prompt");
        self.pending.push(std::cmp::Reverse((req.arrival_us, req.id)));
        self.pending_reqs.insert(req.id, req);
    }

    /// Take the completions accumulated since the last drain.
    pub fn drain_finished(&mut self) -> Vec<FinishedRequest> {
        std::mem::take(&mut self.finished)
    }

    /// Requests the engine evicted without completing (OOM deadlock
    /// breaking). The serving layer must drain these alongside
    /// `drain_finished` — every submitted request produces exactly one
    /// terminal record across the two queues.
    pub fn drain_dropped(&mut self) -> Vec<DroppedRequest> {
        std::mem::take(&mut self.dropped)
    }

    /// The earliest queued arrival time, if any — what the event-driven
    /// shard thread sleeps toward when the engine reports `Idle`.
    pub fn next_pending_arrival(&self) -> Option<u64> {
        self.pending.peek().map(|std::cmp::Reverse((t, _))| *t)
    }

    // analyze:allow(panic_path, fn) every pending-heap id has a pending_reqs entry (inserted together in submit)
    fn admit_pending(&mut self) {
        while let Some(&std::cmp::Reverse((t, id))) = self.pending.peek() {
            if t > self.now_us {
                break;
            }
            self.pending.pop();
            let req = self.pending_reqs.remove(&id).expect("pending req");
            self.seqs.insert(id, Seq::new(req));
            self.waiting.push_back(id);
            // workflow-aware eviction: mark the queued fork's cached
            // prefix so LRU pressure takes it last
            self.pin_seq(id);
        }
    }

    /// Workflow-eviction pin for a queued fork (gang mode): mark the
    /// longest cached prefix of its prompt in both trees so eviction
    /// defers those pages until this fork admits — the KVFlow-style
    /// "a queued step of this tag still needs this prefix" signal. Pins
    /// are advisory (second-pass evictable), so they can never deadlock
    /// allocation; see `RadixTree::pin_prefix`.
    fn pin_seq(&mut self, sid: u64) {
        if !self.cfg.sched.gang {
            return;
        }
        let Some(seq) = self.seqs.get(&sid) else {
            return;
        };
        if seq.all.len() < 2 {
            return;
        }
        // the fork's match window: everything but the final token. Read
        // in place — `seqs` and `trees` are disjoint fields, so no copy
        // of a potentially huge prompt is needed on this (preemption-
        // frequented) path.
        let window = &seq.all[..seq.all.len() - 1];
        let adapter = seq.req.adapter;
        let ns = base_ns(self.cfg.policy, adapter);
        let base = self.trees.base.pin_prefix(ns, window);
        let res = if self.cfg.policy.uses_residual() {
            self.trees.residual.pin_prefix(adapter, window)
        } else {
            Vec::new()
        };
        if !base.is_empty() || !res.is_empty() {
            self.seq_pins.insert(sid, (base, res));
        }
    }

    /// Drop a queued fork's eviction pins (admission, drop, or preempt
    /// teardown). No-op if the sequence holds none.
    fn unpin_seq(&mut self, sid: u64) {
        if let Some((base, res)) = self.seq_pins.remove(&sid) {
            self.trees.base.unpin_path(&base);
            self.trees.residual.unpin_path(&res);
        }
    }

    /// One scheduling decision: prefill-first (vLLM default); a prefill
    /// blocked on memory falls through to decode so running sequences keep
    /// draining and eventually release the memory the head is waiting for.
    /// With `sched.gang` on, *which* queued fork prefills next is chosen
    /// workflow-aware (`next_prefill`): a workflow's fan admits together
    /// instead of interleaving with unrelated workflows.
    pub fn tick(&mut self) -> anyhow::Result<Tick> {
        self.admit_pending();
        let mut prefill_blocked = false;
        let pick = self.next_prefill();
        if let Some(sid) = pick.sid {
            if self.prefill_tick(sid)? {
                self.sample_memory();
                return Ok(Tick::Progress);
            }
            prefill_blocked = true;
        }
        if !self.running.is_empty() && self.decode_tick()? {
            self.sample_memory();
            return Ok(Tick::Progress);
        }
        if !prefill_blocked && self.running.is_empty() {
            if let Some(hold) = pick.hold_until {
                // everything admissible is a fan waiting for stragglers:
                // fast-forward the virtual clock to the next event that
                // can change the decision (hold deadline or a pending
                // arrival, whichever is first) — discrete-event idling,
                // so a partial fan never stalls an otherwise idle shard
                let t = self.next_pending_arrival().map_or(hold, |p| p.min(hold));
                debug_assert!(t > self.now_us);
                self.now_us = self.now_us.max(t);
                self.sample_memory();
                return Ok(Tick::Progress);
            }
        }
        if prefill_blocked || !self.running.is_empty() {
            // Memory deadlock: every schedulable unit is blocked on pages
            // that only blocked sequences hold. Break it by dropping the
            // youngest memory-holding sequence (guaranteed progress).
            let victim = self
                .seqs
                .iter()
                .filter(|(_, s)| s.admitted)
                .max_by_key(|(_, s)| s.priority_key())
                .map(|(&id, _)| id)
                .or_else(|| {
                    self.seqs
                        .iter()
                        .max_by_key(|(_, s)| s.priority_key())
                        .map(|(&id, _)| id)
                });
            if let Some(vid) = victim {
                self.oom_drop(vid);
                self.sample_memory();
                return Ok(Tick::Progress);
            }
        }
        Ok(Tick::Idle)
    }

    /// Pick the waiting sequence to prefill this tick (see
    /// `AdmissionPick`). Gang off reproduces the pre-gang scheduler
    /// exactly: plain FCFS on the waiting queue. Gang on adds, in
    /// priority order:
    ///   1. **continuation** — a mid-prefill (admitted) fork always
    ///      finishes before anything new starts;
    ///   2. **fan holds** — a fork declaring `fan = K` waits (bounded by
    ///      `gang_hold_ms`) until K requests of its tag are present, so
    ///      the whole fan admits back to back; a fan with an *admitted*
    ///      member never holds (stragglers co-admit with their in-flight
    ///      mates instead of waiting on a stale head-count);
    ///   3. **gang preference** — forks whose tag already has an admitted
    ///      member, then forks whose shared prefix is resident, then cold
    ///      forks; FCFS within a class. New admissions are bounded by
    ///      `max_running`. A starvation guard returns to strict FCFS for
    ///      any older lower-class fork (warm bypassed by gang, cold
    ///      bypassed by either) that has waited far past the hold window.
    ///
    /// Tag 0 is untagged traffic (the HTTP default): it takes no tag
    /// preference, no fan holds, and no gang accounting — a plain
    /// deployment that never sets `tag` keeps plain FCFS (modulo the
    /// content-based warm-prefix preference, which is tag-free).
    // analyze:allow(panic_path, fn) every id in `waiting` has a live `seqs` entry (scheduler list invariant), and the scan above tallied every live tag
    fn next_prefill(&mut self) -> AdmissionPick {
        // gang off: the pre-gang O(1) scheduler, verbatim. FCFS only
        // ever admits (and chunk-continues) the queue head, so an
        // admitted sequence — if one exists — is always at the front;
        // no scan is needed.
        if !self.cfg.sched.gang {
            return AdmissionPick { sid: self.waiting.front().copied(), hold_until: None };
        }
        if self.waiting.is_empty() {
            return AdmissionPick { sid: None, hold_until: None };
        }
        // continuation first: an admitted fork holds leases + chunk state
        if let Some(&sid) = self
            .waiting
            .iter()
            .find(|&&sid| self.seqs[&sid].admitted)
        {
            return AdmissionPick { sid: Some(sid), hold_until: None };
        }
        let resident = self.seqs.values().filter(|s| s.admitted).count();
        if resident >= self.cfg.sched.max_running {
            return AdmissionPick { sid: None, hold_until: None };
        }
        let now = self.now_us;
        let hold_us = self.cfg.sched.gang_hold_ms.saturating_mul(1000);
        // per-tag admission state, in engine-owned scratch (a decode
        // tick with a memory-blocked queue head runs through here too —
        // the scan must clear, not reallocate): live member counts (fan
        // satisfaction), admitted members (gang preference), earliest
        // waiting arrival (the fan hold's clock base)
        let mut tags = std::mem::take(&mut self.scratch_tags);
        tags.clear();
        for s in self.seqs.values() {
            let st = tags.entry(s.req.tag).or_insert(TagState {
                live: 0,
                admitted: false,
                first_wait: u64::MAX,
            });
            st.live += 1;
            st.admitted |= s.admitted;
        }
        for &sid in &self.waiting {
            let s = &self.seqs[&sid];
            let st = tags.get_mut(&s.req.tag).expect("waiting seq is live");
            st.first_wait = st.first_wait.min(s.req.arrival_us);
        }
        let mut best: Option<(u8, u64, u64)> = None; // (class, arrival, id)
        let mut best_sid = None;
        let mut hold_until: Option<u64> = None;
        // oldest non-held candidate by plain FCFS, with its class — the
        // starvation guard's fallback pick
        let mut oldest: Option<(u64, u64, u64, u8)> = None; // (arrival, id, sid, class)
        for &sid in &self.waiting {
            let s = &self.seqs[&sid];
            let tag = s.req.tag;
            // tag 0 is untagged (the HTTP default): it names no workflow,
            // so it earns no gang preference and fan hints cannot count
            // its members — untagged traffic schedules plain FCFS/warmth
            let untagged = tag == 0;
            let st = tags[&tag];
            // fan hold — but never once the fan is partially admitted: a
            // straggler joining in-flight mates should co-admit now, not
            // wait for a head-count its admitted (or already finished)
            // mates no longer satisfy
            if !untagged && !st.admitted && s.req.fan > 1 && st.live < s.req.fan {
                let deadline = st.first_wait.saturating_add(hold_us);
                if now < deadline {
                    hold_until = Some(hold_until.map_or(deadline, |t: u64| t.min(deadline)));
                    continue;
                }
            }
            let class: u8 = if !untagged && st.admitted {
                0 // gang: co-admit with the tag's in-flight members
            } else if self.prefix_resident(s) {
                1 // warm: this workflow's shared pages are resident
            } else {
                2 // cold
            };
            let key = (class, s.req.arrival_us, s.req.id);
            let better = match best {
                None => true,
                Some(b) => key < b,
            };
            if better {
                best = Some(key);
                best_sid = Some(sid);
            }
            let older = match oldest {
                None => true,
                Some((a, i, _, _)) => (s.req.arrival_us, s.req.id) < (a, i),
            };
            if older {
                oldest = Some((s.req.arrival_us, s.req.id, sid, class));
            }
        }
        // starvation guard: class preference must not bypass an *older*
        // lower-class fork forever — warm behind a continuous gang
        // stream, or cold behind either. A bypassed head aged far past
        // the hold window wins on plain FCFS.
        if let (Some((best_class, arr, _)), Some((old_arr, _, old_sid, old_class))) =
            (best, oldest)
        {
            let age_cap = hold_us.saturating_mul(8).max(250_000);
            if old_class > best_class
                && old_arr < arr
                && now.saturating_sub(old_arr) > age_cap
            {
                best_sid = Some(old_sid);
            }
        }
        self.scratch_tags = tags;
        AdmissionPick {
            sid: best_sid,
            hold_until: if best_sid.is_none() { hold_until } else { None },
        }
    }

    /// Cheap warmth probe for admission classing: is the first page of
    /// this fork's prompt resident in the base tree? One child-map lookup
    /// — deliberately not a full prefix walk.
    fn prefix_resident(&self, seq: &Seq) -> bool {
        let pt = self.cfg.cache.page_tokens;
        if seq.all.len() < pt + 1 {
            return false; // match window (prompt minus tail) has no full page
        }
        let ns = base_ns(self.cfg.policy, seq.req.adapter);
        self.trees.base.probe_pages(ns, &seq.all[..pt]) > 0
    }

    /// Drive to completion against a workload driver (discrete-event loop).
    pub fn run_driver(
        &mut self,
        driver: &mut dyn Driver,
    ) -> anyhow::Result<Vec<FinishedRequest>> {
        let mut all_finished = Vec::new();
        let mut delivered: Vec<FinishedRequest> = Vec::new();
        loop {
            let newly = driver.poll(self.now_us, &delivered);
            delivered.clear();
            for r in newly {
                self.submit(r);
            }
            match self.tick()? {
                Tick::Progress => {
                    // driver mode has no waiter to notify: drop records are
                    // already counted in metrics.oom_drops, so discard them
                    // instead of letting the vec grow for the process life
                    self.dropped.clear();
                    let fin = self.drain_finished();
                    if !fin.is_empty() {
                        delivered.extend(fin.iter().cloned());
                        all_finished.extend(delivered.iter().cloned());
                    }
                }
                Tick::Idle => {
                    if let Some(t) = self.next_pending_arrival() {
                        self.now_us = self.now_us.max(t);
                        continue;
                    }
                    if driver.done() {
                        break;
                    }
                    anyhow::ensure!(
                        !delivered.is_empty(),
                        "driver stalled: not done, nothing pending or in flight \
                         ({} requests dropped under memory pressure)",
                        self.metrics.oom_drops
                    );
                }
            }
        }
        Ok(all_finished)
    }

    fn sample_memory(&mut self) {
        let res = self.res_pool.as_ref().map_or(0, |p| p.used_bytes());
        self.metrics
            .sample_memory(self.base_pool.used_bytes(), res, self.seqs.len());
        self.metrics
            .sample_queue_depth(self.waiting.len() + self.pending.len());
    }

    // -----------------------------------------------------------------
    // memory management: alloc -> evict (decoupled LRU) -> preempt
    // -----------------------------------------------------------------

    // analyze:allow(panic_path, fn) Which::Res is only constructed when the residual pool exists (policy.uses_residual())
    fn alloc_pages(&mut self, which: Which, n: usize, for_seq: u64) -> Option<Vec<PageId>> {
        let budget = self.budget_bytes;
        let mut budget_denied = false;
        let mut pages = Vec::with_capacity(n);
        loop {
            while pages.len() < n {
                let page_bytes = match which {
                    Which::Base => self.base_pool.spec().bytes_per_page(),
                    Which::Res => self.res_pool.as_ref().unwrap().spec().bytes_per_page(),
                };
                if self.used_cache_bytes() + page_bytes > budget {
                    budget_denied = true;
                    break; // global (elastic) budget exhausted
                }
                let pool = match which {
                    Which::Base => &mut self.base_pool,
                    Which::Res => self.res_pool.as_mut().expect("res pool"),
                };
                match pool.alloc() {
                    Some(p) => pages.push(p),
                    None => break,
                }
            }
            if pages.len() == n {
                return Some(pages);
            }
            // Decoupled eviction (paper §5.2): each tree keeps its own LRU;
            // global pressure first drains the tree backing the requested
            // kind, then the other — never as a cascading unit.
            let want = n - pages.len() + self.cfg.sched.evict_slack_pages;
            let evicted = self.evict_demote(which, want, true);
            if evicted > 0 {
                continue;
            }
            // Strictly decoupled (paper §5.2): base pressure never evicts
            // the residual tree and vice versa. A residual page is ~n/r
            // times smaller than a base page, so cross-eviction would
            // cannibalize entire agents' rCaches for negligible bytes —
            // the cascading coupling the decoupled policy exists to avoid.
            if self.preempt_one(for_seq) {
                continue;
            }
            // out of options: roll back. If the byte budget (rather than
            // physical pool exhaustion) ever blocked this attempt, count
            // it — the rebalancer's hot-shard signal.
            if budget_denied {
                self.metrics.budget_denials += 1;
            }
            let pool = match which {
                Which::Base => &mut self.base_pool,
                Which::Res => self.res_pool.as_mut().expect("res pool"),
            };
            for p in pages {
                pool.release(p);
            }
            return None;
        }
    }

    /// Preempt the *youngest* admitted sequence that is strictly lower
    /// priority than `for_seq` (recompute-style preemption: release
    /// everything, requeue). Never preempts upward — FCFS priority is what
    /// guarantees forward progress under memory thrash.
    // analyze:allow(panic_path, fn) the victim id was selected from running/waiting via seqs.get() on this same call
    fn preempt_one(&mut self, for_seq: u64) -> bool {
        let my_key = self.seqs.get(&for_seq).map(|s| s.priority_key());
        let Some(my_key) = my_key else { return false };
        let victim = self
            .running
            .iter()
            .chain(self.waiting.iter())
            .copied()
            .filter(|&id| {
                id != for_seq
                    && self.seqs.get(&id).is_some_and(|s| {
                        s.admitted && s.priority_key() > my_key
                    })
            })
            .max_by_key(|&id| self.seqs[&id].priority_key());
        let Some(vid) = victim else {
            return false;
        };
        self.release_seq_resources(vid);
        let seq = self.seqs.get_mut(&vid).unwrap();
        seq.preemptions += 1;
        seq.phase = Phase::Prefill;
        self.metrics.preemptions += 1;
        self.running.retain(|&id| id != vid);
        if !self.waiting.contains(&vid) {
            self.waiting.push_back(vid);
        }
        // back in the queue: re-pin whatever of its prefix is still
        // cached so eviction keeps its re-admission warm
        self.pin_seq(vid);
        true
    }

    /// Release every cache resource a sequence holds (teardown/preempt),
    /// including any queued-fork eviction pins.
    fn release_seq_resources(&mut self, sid: u64) {
        self.unpin_seq(sid);
        let Some(seq) = self.seqs.get_mut(&sid) else {
            return;
        };
        for &p in &seq.base_pages {
            self.base_pool.release(p);
        }
        if let Some(pool) = self.res_pool.as_mut() {
            for &p in &seq.res_pages {
                pool.release(p);
            }
        }
        self.trees.base.release_path(&seq.base_lease);
        self.trees.residual.release_path(&seq.res_lease);
        seq.base_pages.clear();
        seq.res_pages.clear();
        seq.base_lease.clear();
        seq.res_lease.clear();
        seq.base_cached = 0;
        seq.res_cached = 0;
        seq.processed = 0;
        seq.admitted = false;
        seq.slab = None;
    }

    fn oom_drop(&mut self, sid: u64) {
        self.release_seq_resources(sid);
        self.waiting.retain(|&id| id != sid);
        self.running.retain(|&id| id != sid);
        if let Some(seq) = self.seqs.remove(&sid) {
            // the waiter on this request must learn its fate: record the
            // drop so drain_dropped surfaces it (a silent delete left
            // Server::generate blocked on its reply channel forever)
            self.dropped.push(DroppedRequest {
                id: seq.req.id,
                tag: seq.req.tag,
                adapter: seq.req.adapter,
                prompt_len: seq.req.tokens.len(),
                arrival_us: seq.req.arrival_us,
                drop_us: self.now_us,
                reason: DropReason::OutOfMemory,
            });
        }
        self.metrics.oom_drops += 1;
    }

    // -----------------------------------------------------------------
    // host-memory tier: demote on evict, promote on fork admission
    // -----------------------------------------------------------------

    /// Evict up to `want` pages from one tree, demoting each victim's
    /// bytes into the host-memory tier (when on) instead of destroying
    /// them. `escalate` picks allocation-pressure eviction
    /// (`RadixTree::evict`: pins deferred to a second pass) over
    /// budget-shrink eviction (`RadixTree::evict_unpinned`: pins never
    /// taken). The demotion sink runs at the instant the radix leaf is
    /// removed, so the victims are exactly the pages the pre-tier drop
    /// path freed — never leased, running-sequence, or (first-pass)
    /// workflow-pinned state.
    // analyze:allow(panic_path, fn) Which::Res is only constructed when the residual pool exists
    fn evict_demote(&mut self, which: Which, want: usize, escalate: bool) -> usize {
        let (tree, pool, component) = match which {
            Which::Base => {
                (&mut self.trees.base, &mut self.base_pool, Component::Base)
            }
            Which::Res => (
                &mut self.trees.residual,
                self.res_pool.as_mut().expect("res pool"),
                Component::Residual,
            ),
        };
        match self.tier.as_mut() {
            Some(store) => {
                let metrics = &mut self.metrics;
                let mut sink = |ns: u32, path: &[u32], data: &[f32]| {
                    if store.insert_path(PageKey::new(component, ns, path), path, data) {
                        metrics.demoted_pages += 1;
                    }
                };
                if escalate {
                    tree.evict_with_sink(want, pool, Some(&mut sink))
                } else {
                    tree.evict_unpinned_with_sink(want, pool, Some(&mut sink))
                }
            }
            None if escalate => tree.evict(want, pool),
            None => tree.evict_unpinned(want, pool),
        }
    }

    /// Fork-admission promotion (the demote inverse): if the host tier
    /// holds pages extending this prompt's cached prefix, and the cost
    /// model prices copying them below recomputing their tokens
    /// ("pay bytes, not FLOPs" — PR 3's migration calculus one tier
    /// down), copy them back into the pool and graft them into the tree
    /// so the admission match that follows inherits them. The cached
    /// prefix is leased for the duration — the allocations below can
    /// evict, and the graft point must survive them. Partial promotion
    /// under budget pressure keeps the affordable prefix, which is
    /// still a valid radix path.
    fn promote_from_tier(&mut self, which: Which, ns: u32, tokens: &[u32]) {
        self.pull_from_tier(which, ns, tokens, true);
    }

    /// Shared tier→pool copy-back core behind both promotion (`priced`:
    /// cost-gated, charged to the promotion ledger) and warm-restart
    /// checkpoint replay (unpriced — a restart rebuilds whatever the tier
    /// still holds, charged to `restored_pages`). Returns the pages
    /// grafted into the tree.
    // analyze:allow(panic_path, fn) tier expects are behind the is_none() early return; res-pool expects behind Which::Res
    fn pull_from_tier(
        &mut self,
        which: Which,
        ns: u32,
        tokens: &[u32],
        priced: bool,
    ) -> usize {
        if self.tier.is_none() {
            return 0;
        }
        let pt = self.cfg.cache.page_tokens;
        let total_pages = tokens.len() / pt;
        if total_pages == 0 {
            return 0;
        }
        let component = match which {
            Which::Base => Component::Base,
            Which::Res => Component::Residual,
        };
        let m = match which {
            Which::Base => self.trees.base.match_lease(ns, tokens, &mut self.base_pool),
            Which::Res => self.trees.residual.match_lease(
                ns,
                tokens,
                self.res_pool.as_mut().expect("res pool"),
            ),
        };
        let have = m.pages.len();
        let mut keys = Vec::new();
        {
            let tier = self.tier.as_ref().expect("tier on");
            for i in have..total_pages {
                let key = PageKey::new(component, ns, &tokens[..(i + 1) * pt]);
                if !tier.contains(&key) {
                    break;
                }
                keys.push(key);
            }
        }
        if keys.is_empty() {
            self.release_match(which, &m);
            return 0;
        }
        let (page_bytes, floats) = match which {
            Which::Base => {
                let s = self.base_pool.spec();
                (s.bytes_per_page(), s.floats_per_page())
            }
            Which::Res => {
                let s = self.res_pool.as_ref().expect("res pool").spec();
                (s.bytes_per_page(), s.floats_per_page())
            }
        };
        if priced {
            self.metrics.tier_hits += 1;
            // a short tail next to a long cached prefix recomputes faster
            // than a tier round-trip's dispatch: leave it tiered
            let copy_us = self.tier_cost.tier_cost_us(keys.len() * page_bytes);
            let recompute_us =
                self.tier_cost.prefill_cost_us(keys.len() * pt, have * pt);
            if copy_us >= recompute_us {
                self.release_match(which, &m);
                return 0;
            }
        }
        let mut fresh: Vec<PageId> = Vec::with_capacity(keys.len());
        for key in &keys {
            let Some(p) = self.alloc_import_page(which) else {
                break; // budget exhausted: keep the affordable prefix
            };
            // that allocation may have evicted — demoting INTO the tier,
            // whose own budget may have evicted this very record:
            // re-resolve by key before copying
            let tier = self.tier.as_ref().expect("tier on");
            match tier.get(key) {
                Some(data) if data.len() == floats => {
                    let pool = match which {
                        Which::Base => &mut self.base_pool,
                        Which::Res => self.res_pool.as_mut().expect("res pool"),
                    };
                    pool.page_data_mut(p).copy_from_slice(data);
                    fresh.push(p);
                }
                _ => {
                    let pool = match which {
                        Which::Base => &mut self.base_pool,
                        Which::Res => self.res_pool.as_mut().expect("res pool"),
                    };
                    pool.release(p);
                    break;
                }
            }
        }
        let got = fresh.len();
        if got > 0 {
            let mut pages = Vec::with_capacity(have + got);
            pages.extend_from_slice(&m.pages);
            pages.extend_from_slice(&fresh);
            let (tree, pool) = match which {
                Which::Base => (&mut self.trees.base, &mut self.base_pool),
                Which::Res => (
                    &mut self.trees.residual,
                    self.res_pool.as_mut().expect("res pool"),
                ),
            };
            tree.insert(ns, &tokens[..(have + got) * pt], &pages, pool);
            for p in fresh {
                pool.release(p); // the tree holds its own refs now
            }
            let tier = self.tier.as_mut().expect("tier on");
            for key in keys.iter().take(got) {
                tier.remove(key);
            }
            if priced {
                self.metrics.promoted_pages += got as u64;
                self.metrics.recompute_tokens_saved_tier += (got * pt) as u64;
            } else {
                self.metrics.restored_pages += got as u64;
            }
        }
        self.release_match(which, &m);
        got
    }

    /// Drop a protective `match_lease` taken by promotion: release the
    /// matched pages' pool refs and the lease path.
    // analyze:allow(panic_path, fn) Which::Res is only constructed when the residual pool exists
    fn release_match(&mut self, which: Which, m: &MatchResult) {
        let (tree, pool) = match which {
            Which::Base => (&mut self.trees.base, &mut self.base_pool),
            Which::Res => (
                &mut self.trees.residual,
                self.res_pool.as_mut().expect("res pool"),
            ),
        };
        for &p in &m.pages {
            pool.release(p);
        }
        tree.release_path(&m.path);
    }

    /// The host-memory tier store, when tiering is on.
    pub fn tier(&self) -> Option<&TierStore> {
        self.tier.as_ref()
    }

    /// One compaction pass over the tier store: rewrite the segments
    /// dropping dead (replaced / promoted / tier-evicted) records.
    /// Returns the bytes reclaimed. Driven by the server's tier
    /// compaction supervisor on the `--tier-compact-ms` cadence; a no-op
    /// with tiering off or nothing dead.
    pub fn tier_compact(&mut self) -> usize {
        self.tier.as_mut().map_or(0, |t| t.compact())
    }

    /// Detach the host-memory tier store (crash salvage): the tier lives
    /// in host memory, so a dying shard hands it to the supervisor and a
    /// restarted engine adopts it — GPU pool bytes die with the shard,
    /// tiered bytes do not.
    pub fn take_tier(&mut self) -> Option<TierStore> {
        self.tier.take()
    }

    /// Install a salvaged tier store into a freshly constructed engine
    /// (the `take_tier` inverse, run before checkpoint replay).
    pub fn adopt_tier(&mut self, tier: TierStore) {
        self.tier = Some(tier);
    }

    /// Serialize the cache's *metadata* — every live radix leaf path plus
    /// every tiered page's token path, no KV bytes — as the warm-restart
    /// checkpoint. A restarted shard replays this against the (salvaged)
    /// tier via `restore_checkpoint`; paths whose bytes did not survive
    /// degrade to no-ops there, so the checkpoint is advisory and can
    /// never corrupt state.
    pub fn checkpoint_json(&self) -> Json {
        let pt = self.cfg.cache.page_tokens;
        let mut entries: Vec<Json> = Vec::new();
        let mut seen: std::collections::HashSet<(u8, u32, Vec<u32>)> =
            std::collections::HashSet::new();
        let mut tagged: Vec<(u8, u32, Vec<u32>)> = Vec::new();
        for (ns, toks) in self.trees.base.live_paths() {
            tagged.push((0, ns, toks));
        }
        for (ns, toks) in self.trees.residual.live_paths() {
            tagged.push((1, ns, toks));
        }
        if let Some(tier) = self.tier.as_ref() {
            for (component, ns, toks) in tier.live_paths() {
                let c = match component {
                    Component::Base => 0,
                    Component::Residual => 1,
                };
                tagged.push((c, ns, toks.to_vec()));
            }
        }
        for (c, ns, toks) in tagged {
            // page-aligned full pages only: a sub-page tail can never be
            // restored, and duplicate paths (tree + tier agreeing on a
            // prefix) would just burn replay work
            let toks = toks[..(toks.len() / pt) * pt].to_vec();
            if toks.is_empty() || !seen.insert((c, ns, toks.clone())) {
                continue;
            }
            entries.push(Json::obj(vec![
                ("c", Json::str(if c == 0 { "b" } else { "r" })),
                ("ns", Json::num(ns as f64)),
                ("toks", Json::arr(toks.iter().map(|&t| Json::num(t)))),
            ]));
        }
        Json::obj(vec![
            ("v", Json::num(1)),
            ("page_tokens", Json::num(pt as f64)),
            ("entries", Json::arr(entries)),
        ])
    }

    /// Replay a `checkpoint_json` snapshot against the tier: for each
    /// recorded path, copy back whatever contiguous-from-root prefix the
    /// tier still holds and graft it into the radix tree (unpriced — a
    /// restart rebuilds what it can, it does not haggle). Returns the
    /// pages restored; mismatched geometry or versions restore nothing.
    pub fn restore_checkpoint(&mut self, ckpt: &Json) -> usize {
        if ckpt.get("v").and_then(Json::as_usize) != Some(1) {
            return 0;
        }
        if ckpt.get("page_tokens").and_then(Json::as_usize)
            != Some(self.cfg.cache.page_tokens)
        {
            return 0;
        }
        let Some(entries) = ckpt.get("entries").and_then(Json::as_arr) else {
            return 0;
        };
        let mut restored = 0;
        for e in entries {
            let (which, ns) = match (
                e.get("c").and_then(Json::as_str),
                e.get("ns").and_then(Json::as_usize),
            ) {
                (Some("b"), Some(ns)) => (Which::Base, ns as u32),
                (Some("r"), Some(ns)) if self.cfg.policy.uses_residual() => {
                    (Which::Res, ns as u32)
                }
                _ => continue,
            };
            let Some(toks) = e.get("toks").and_then(Json::as_arr) else {
                continue;
            };
            let tokens: Vec<u32> = toks
                .iter()
                .filter_map(|t| t.as_usize().map(|v| v as u32))
                .collect();
            if tokens.len() != toks.len() {
                continue; // non-numeric token: refuse the entry
            }
            restored += self.pull_from_tier(which, ns, &tokens, false);
        }
        restored
    }

    // -----------------------------------------------------------------
    // cross-step workflow prefetch (the KVFlow horizon)
    // -----------------------------------------------------------------

    /// Pre-warm and pin a *future* step's known prefix under a prefetch
    /// lease: promote any demoted pages of the prefix back from the host
    /// tier (priced by the cost model, exactly like fork admission), then
    /// soft-pin the resident coverage in both trees so LRU pressure takes
    /// it last while the successor step is still in flight upstream.
    /// Pins reuse the PR 4 pin epochs, so a lease can never hold freed
    /// slots and never blocks allocation (second-pass evictable —
    /// prefetch is advisory, not a reservation, and cannot leak budget).
    ///
    /// Returns the pages the lease covers across both trees. A reissued
    /// lease id silently replaces its predecessor (no hit/waste
    /// accounting — the server re-evaluates pending steps as their
    /// prefixes materialize). A covering lease counts toward
    /// `prefetched_pages`; a zero-coverage call leaves no lease behind.
    pub fn prefetch_pin(&mut self, lease: u64, adapter: u32, tokens: &[u32]) -> usize {
        if let Some(old) = self.prefetch_leases.remove(&lease) {
            self.trees.base.unpin_path(&old.base);
            self.trees.residual.unpin_path(&old.res);
        }
        let pt = self.cfg.cache.page_tokens;
        if tokens.len() < pt {
            return 0; // no full page to warm
        }
        let ns = base_ns(self.cfg.policy, adapter);
        // warm-start sources first: tier promotion grafts demoted pages
        // back in, so the pin below covers them too
        self.promote_from_tier(Which::Base, ns, tokens);
        if self.cfg.policy.uses_residual() {
            self.promote_from_tier(Which::Res, adapter, tokens);
        }
        let base = self.trees.base.pin_prefix(ns, tokens);
        let res = if self.cfg.policy.uses_residual() {
            self.trees.residual.pin_prefix(adapter, tokens)
        } else {
            Vec::new()
        };
        let pages = self.trees.base.probe_pages(ns, tokens)
            + if self.cfg.policy.uses_residual() {
                self.trees.residual.probe_pages(adapter, tokens)
            } else {
                0
            };
        if pages == 0 {
            // nothing resident yet (predecessors may still be
            // prefilling): drop the empty pin paths and leave no lease,
            // so the server's next evaluation pass can retry
            self.trees.base.unpin_path(&base);
            self.trees.residual.unpin_path(&res);
            return 0;
        }
        self.metrics.prefetched_pages += pages as u64;
        self.prefetch_leases.insert(lease, PrefetchLease { base, res, pages });
        pages
    }

    /// Release a prefetch lease *exactly once*: unpin its paths (stale
    /// epochs no-op — a pinned node recycled by eviction is skipped, per
    /// `RadixTree::unpin_path`) and account the outcome. `hit` means the
    /// step the lease was warmed for actually arrived; an abandoned
    /// lease's covered pages count as `prefetch_wasted`. Returns whether
    /// a live lease was released — a second release of the same id (or a
    /// release of an id that never covered a page) is a no-op.
    pub fn prefetch_release(&mut self, lease: u64, hit: bool) -> bool {
        let Some(l) = self.prefetch_leases.remove(&lease) else {
            return false;
        };
        self.trees.base.unpin_path(&l.base);
        self.trees.residual.unpin_path(&l.res);
        if hit {
            self.metrics.prefetch_hits += 1;
        } else {
            self.metrics.prefetch_wasted += l.pages as u64;
        }
        true
    }

    /// Live (issued, unreleased) prefetch leases — observability/test hook.
    pub fn prefetch_live_leases(&self) -> usize {
        self.prefetch_leases.len()
    }

    /// Re-warm a replica of `tokens` that was demoted to the host tier:
    /// promote both cache components back on-device (priced by the cost
    /// model, exactly like fork admission and prefetch warm-starts) and
    /// return the device-resident page coverage afterwards. Unlike
    /// [`Engine::prefetch_pin`] this takes no pin and issues no lease —
    /// replica residency is advisory (the server's replica map verifies
    /// on use), so the promoted pages compete for budget like any other
    /// cached prefix. Unlike a migration import it moves no bytes across
    /// shards: whatever the tier cannot supply, the follow-up export/
    /// import ship fills.
    pub fn replica_warm(&mut self, adapter: u32, tokens: &[u32]) -> usize {
        if tokens.len() < self.cfg.cache.page_tokens {
            return 0;
        }
        let ns = base_ns(self.cfg.policy, adapter);
        self.promote_from_tier(Which::Base, ns, tokens);
        if self.cfg.policy.uses_residual() {
            self.promote_from_tier(Which::Res, adapter, tokens);
        }
        self.trees.base.probe_pages(ns, tokens)
            + if self.cfg.policy.uses_residual() {
                self.trees.residual.probe_pages(adapter, tokens)
            } else {
                0
            }
    }

    // -----------------------------------------------------------------
    // prefill
    // -----------------------------------------------------------------

    /// Fork admission (paper Fig. 9): Step 1 = prefix match + inherit the
    /// shared pages; the chunk loop below performs Step 2's CoW
    /// allocations for the un-cached tail.
    // analyze:allow(panic_path, fn) sid is the admission scan's pick from `waiting` (live seqs entry); res-pool/slab expects follow policy and needs_data gates
    fn admit_fork(&mut self, sid: u64) {
        // the real leases below supersede the queued-fork eviction pins
        self.unpin_seq(sid);
        let policy = self.cfg.policy;
        let (match_tokens, adapter, prompt_len, tag) = {
            let seq = &self.seqs[&sid];
            // never serve the newest token from cache: its logits (fresh
            // seq) or its KV-write (resumed seq) must be recomputed
            (
                seq.all[..seq.all.len() - 1].to_vec(),
                seq.req.adapter,
                seq.req.tokens.len(),
                seq.req.tag,
            )
        };
        // gang accounting: is this fork joining a workflow that already
        // has an admitted member on this shard? (evaluated before this
        // sequence is marked admitted, so it never counts itself; tag 0
        // is untagged traffic and forms no workflow)
        let gang_mate =
            tag != 0 && self.seqs.values().any(|s| s.admitted && s.req.tag == tag);
        let ns = base_ns(policy, adapter);
        // tier promotion (Step 0, before the fork's Step 1 match): if
        // the demoted tail of this prompt survives in the host tier and
        // copying it back is priced below re-prefilling it, graft it in
        // now so the match below inherits it
        self.promote_from_tier(Which::Base, ns, &match_tokens);
        if policy.uses_residual() {
            self.promote_from_tier(Which::Res, adapter, &match_tokens);
        }
        let bm: MatchResult =
            self.trees
                .base
                .match_lease(ns, &match_tokens, &mut self.base_pool);
        let rm: MatchResult = if policy.uses_residual() {
            self.trees.residual.match_lease(
                adapter,
                &match_tokens,
                self.res_pool.as_mut().expect("res pool"),
            )
        } else {
            MatchResult::default()
        };

        let skip = if policy.uses_residual() {
            bm.tokens.min(rm.tokens)
        } else {
            bm.tokens
        };
        let needs_data = self.exec.needs_data();
        let slab_spec = {
            let meta = self.exec.meta();
            SlabSpec {
                n_layers: meta.n_layers,
                s_max: meta.s_max,
                base_width: meta.kv_width(),
                res_width: meta.rank_max,
            }
        };
        let first_admission = !self.seqs[&sid].counted;
        {
            let seq = self.seqs.get_mut(&sid).expect("seq");
            seq.base_cached = bm.tokens;
            seq.res_cached = rm.tokens;
            seq.base_pages = bm.pages;
            seq.base_lease = bm.path;
            seq.res_pages = rm.pages;
            seq.res_lease = rm.path;
            seq.processed = skip;
            seq.admitted = true;
            if first_admission {
                seq.counted = true;
                seq.hit_full = skip.min(prompt_len);
                seq.hit_partial =
                    (seq.base_cached.max(seq.res_cached)).min(prompt_len) - seq.hit_full;
            }
        }
        if first_admission {
            self.metrics.prompt_tokens += prompt_len as u64;
            let (hit_full, hit_partial) = {
                let s = &self.seqs[&sid];
                (s.hit_full as u64, s.hit_partial as u64)
            };
            self.metrics.hit_full_tokens += hit_full;
            self.metrics.hit_partial_tokens += hit_partial;
            // per-workflow observability: this tag's matched fraction
            self.metrics
                .record_tag_hit(tag, prompt_len as u64, hit_full + hit_partial);
            if self.cfg.sched.gang && gang_mate {
                self.metrics.gang_admitted += 1;
            }
        }

        if needs_data {
            let mut slab = SeqSlab::new(slab_spec);
            let seq = &self.seqs[&sid];
            slab.load_base_pages(&self.base_pool, &seq.base_pages, seq.base_cached);
            if let Some(pool) = self.res_pool.as_ref() {
                slab.load_res_pages(pool, &seq.res_pages, seq.res_cached);
            }
            // the load calls fill rows but only the base load moves
            // `filled` (see the load_res_pages contract): the coverage
            // decode may attend over is the *joint* one — min(base, res),
            // which is what `processed` was set to above
            slab.filled = seq.processed;
            self.seqs.get_mut(&sid).unwrap().slab = Some(slab);
        }
    }

    /// Admission control (vLLM-style `can_allocate`): a new sequence
    /// starts prefill only if its whole lifetime footprint could be
    /// satisfied from free + tree-reclaimable memory. Without this gate,
    /// prefill-first scheduling over-admits under saturation and the
    /// engine preempt-thrashes.
    // analyze:allow(panic_path, fn) sid comes from `waiting` — a live seqs entry by the scheduler list invariant
    fn can_admit(&self, sid: u64) -> bool {
        let seq = &self.seqs[&sid];
        let pt = self.cfg.cache.page_tokens;
        let policy = self.cfg.policy;
        let ns = base_ns(policy, seq.req.adapter);
        let total_pages = pages_for(seq.all.len() + seq.req.max_new, pt);
        // sharing-aware footprint: pages this fork would inherit rather
        // than allocate (the mechanism behind the paper's Fig. 1 claim
        // that one budget serves many more ForkKV agents)
        let probe = &seq.all[..seq.all.len() - 1];
        let base_hit = self.trees.base.probe_pages(ns, probe);
        let base_page = self.base_pool.spec().bytes_per_page();
        let mut needed = total_pages.saturating_sub(base_hit) * base_page;
        if let Some(res) = &self.res_pool {
            let res_hit = self.trees.residual.probe_pages(seq.req.adapter, probe);
            needed += total_pages.saturating_sub(res_hit) * res.spec().bytes_per_page();
        }
        let free = self.budget_bytes.saturating_sub(self.used_cache_bytes());
        let reclaimable = self.trees.base.reclaimable_pages(&self.base_pool) * base_page
            + self.res_pool.as_ref().map_or(0, |p| {
                self.trees.residual.reclaimable_pages(p) * p.spec().bytes_per_page()
            });
        // headroom: concurrent decode growth + estimate error would
        // otherwise preempt-thrash right at the admission boundary
        let slack = self.budget_bytes / 16;
        needed + slack <= free + reclaimable
    }

    /// Returns Ok(false) when the chunk is blocked on memory (the caller
    /// falls through to decode; the sequence keeps its state and retries).
    // analyze:allow(panic_path, fn) sid is the admission pick from `waiting` (live entry, kept live across the chunk); res-pool/slab expects follow policy and needs_data gates
    fn prefill_tick(&mut self, sid: u64) -> anyhow::Result<bool> {
        if !self.seqs[&sid].admitted {
            if !self.can_admit(sid) {
                // the admission gate is budget-bound (free + reclaimable
                // vs lifetime footprint): a blocked head is this shard
                // asking for more budget, tick after tick
                self.metrics.budget_denials += 1;
                return Ok(false); // wait for memory; decode keeps draining
            }
            self.admit_fork(sid);
        }
        let policy = self.cfg.policy;
        let meta = self.scal;
        let pt = self.cfg.cache.page_tokens;

        let (start, end, target) = {
            let seq = &self.seqs[&sid];
            let target = seq.prefill_target();
            let start = seq.processed;
            let end = (start + meta.chunk).min(target);
            (start, end, target)
        };

        if start >= target {
            // resumed sequence whose whole KV prefix was still cached
            self.to_decode(sid, None, 0);
            return Ok(true);
        }

        // ---- Step 2 (CoW): allocate pages for the un-cached span ----
        let need_base = pages_for(end, pt);
        let have_base = self.seqs[&sid].base_pages.len();
        if need_base > have_base {
            match self.alloc_pages(Which::Base, need_base - have_base, sid) {
                Some(pages) => self.seqs.get_mut(&sid).unwrap().base_pages.extend(pages),
                None => return Ok(false), // blocked on base pool
            }
        }
        if policy.uses_residual() {
            let need_res = pages_for(end, pt);
            let have_res = self.seqs[&sid].res_pages.len();
            if need_res > have_res {
                match self.alloc_pages(Which::Res, need_res - have_res, sid) {
                    Some(pages) => self.seqs.get_mut(&sid).unwrap().res_pages.extend(pages),
                    None => return Ok(false), // blocked on residual pool
                }
            }
        }

        // ---- execute the chunk ----
        let n = end - start;
        let exec_out = {
            let seq = &self.seqs[&sid];
            let empty: [f32; 0] = [];
            let (kb, vb, kr, vr): (&[f32], &[f32], &[f32], &[f32]) =
                if let Some(slab) = &seq.slab {
                    (&slab.kb, &slab.vb, &slab.kr, &slab.vr)
                } else {
                    (&empty, &empty, &empty, &empty)
                };
            let args = PrefillArgs {
                tokens: &seq.all[start..end],
                cache_len: start,
                adapter_id: seq.req.adapter % meta.n_adapters as u32,
                adapter_on: true,
                kb,
                vb,
                kr,
                vr,
            };
            self.exec.prefill(&args)?
        };
        self.now_us += exec_out.elapsed_us;
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_busy_us += exec_out.elapsed_us;
        self.metrics.computed_prompt_tokens += n as u64;

        // ---- persist into pages + mirror into the slab ----
        let use_merged = !policy.uses_residual();
        if let Some(out) = &exec_out.out {
            let (base_cached, res_cached) = {
                let s = &self.seqs[&sid];
                (s.base_cached, s.res_cached)
            };
            // base component: only positions beyond the inherited coverage
            let base_from = start.max(base_cached);
            if base_from < end {
                let (k_src, v_src) = if use_merged {
                    (&out.km, &out.vm)
                } else {
                    (&out.kb, &out.vb)
                };
                let pages = self.seqs[&sid].base_pages.clone();
                scatter_range(
                    &mut self.base_pool,
                    &pages,
                    base_from,
                    end,
                    start,
                    meta.chunk,
                    meta.kv_width,
                    k_src,
                    v_src,
                );
            }
            if policy.uses_residual() {
                let res_from = start.max(res_cached);
                if res_from < end {
                    let pages = self.seqs[&sid].res_pages.clone();
                    let pool = self.res_pool.as_mut().expect("res pool");
                    scatter_range(
                        pool,
                        &pages,
                        res_from,
                        end,
                        start,
                        meta.chunk,
                        meta.rank_max,
                        &out.kr,
                        &out.vr,
                    );
                }
            }
            let seq = self.seqs.get_mut(&sid).unwrap();
            let slab = seq.slab.as_mut().expect("slab in real mode");
            slab.append_prefill(out, start, n, meta.chunk, use_merged);
        }
        {
            let seq = self.seqs.get_mut(&sid).unwrap();
            seq.processed = end;
            seq.computed_prompt += n;
        }

        // ---- publish completed full pages (cache-as-you-go) ----
        self.publish(sid);

        if end >= target {
            let last_logits = exec_out.out.map(|o| {
                let v = meta.vocab;
                o.logits[(n - 1) * v..n * v].to_vec()
            });
            self.to_decode(sid, last_logits, meta.vocab);
        }
        Ok(true)
    }

    /// Transition a sequence out of prefill; sample its first token if it
    /// has none yet (fresh prefill).
    // analyze:allow(panic_path, fn) called only from prefill_tick with its live sid
    fn to_decode(&mut self, sid: u64, last_logits: Option<Vec<f32>>, _vocab: usize) {
        let sample_first = self.seqs[&sid].generated.is_empty();
        if sample_first {
            let tok = match &last_logits {
                Some(row) => argmax(row),
                None => self.rng.token(self.exec.meta().vocab),
            };
            let seq = self.seqs.get_mut(&sid).unwrap();
            if self.collect_first_logits {
                seq.first_logits = last_logits;
            }
            seq.generated.push(tok);
            seq.all.push(tok);
            seq.first_token_us = Some(self.now_us);
        }
        let seq = self.seqs.get_mut(&sid).unwrap();
        seq.phase = Phase::Decode;
        if seq.first_token_us.is_none() {
            seq.first_token_us = Some(self.now_us);
        }
        self.waiting.retain(|&id| id != sid);
        if !self.running.contains(&sid) {
            self.running.push(sid);
        }
        let eos_hit = {
            let s = &self.seqs[&sid];
            !s.req.ignore_eos && s.generated.last() == Some(&EOS)
        };
        if self.seqs[&sid].generated.len() >= self.seqs[&sid].req.max_new || eos_hit {
            self.finish_seq(sid);
        }
    }

    /// Insert this sequence's full pages into the trees so concurrent and
    /// future agents can fork from them (SGLang-style cache-as-you-go).
    // analyze:allow(panic_path, fn) guarded by the seqs.get() early return; res-pool expect behind uses_residual()
    fn publish(&mut self, sid: u64) {
        let policy = self.cfg.policy;
        let pt = self.cfg.cache.page_tokens;
        let Some(seq) = self.seqs.get(&sid) else {
            return;
        };
        let aligned = (seq.processed / pt) * pt;
        if aligned == 0 {
            return;
        }
        let ns = base_ns(policy, seq.req.adapter);
        let tokens = seq.all[..aligned].to_vec();
        let base_pages = seq.base_pages[..aligned / pt].to_vec();
        self.trees
            .base
            .insert(ns, &tokens, &base_pages, &mut self.base_pool);
        if policy.uses_residual() {
            let res_pages = self.seqs[&sid].res_pages[..aligned / pt].to_vec();
            self.trees.residual.insert(
                self.seqs[&sid].req.adapter,
                &tokens,
                &res_pages,
                self.res_pool.as_mut().expect("res pool"),
            );
        }
    }

    // -----------------------------------------------------------------
    // decode
    // -----------------------------------------------------------------

    /// Returns Ok(false) when no decode row could be scheduled (all blocked
    /// on memory or preempted) — the caller breaks the deadlock.
    ///
    /// Hot-path contract: in steady state (stable row set) this performs
    /// no heap allocation — every per-step buffer lives on the engine
    /// (`scratch_*`) and is cleared, not rebuilt.
    // analyze:allow(panic_path, fn) row sids are filtered through seqs.get() at snapshot time and the batch is rebuilt whenever a preemption epoch moves; res-pool/slab expects follow policy and needs_data gates
    fn decode_tick(&mut self) -> anyhow::Result<bool> {
        let meta = self.scal;
        let pt = self.cfg.cache.page_tokens;
        let policy = self.cfg.policy;

        // ---- pick rows; ensure page capacity for the incoming token ----
        // snapshot `running` into a reusable buffer: the alloc path below
        // may preempt (mutating `running`) while we iterate
        let mut snapshot = std::mem::take(&mut self.scratch_run);
        snapshot.clear();
        snapshot.extend_from_slice(&self.running);
        let mut rows = std::mem::take(&mut self.scratch_rows_now);
        rows.clear();
        for &sid in &snapshot {
            if rows.len() >= self.max_bucket {
                break;
            }
            if !self.seqs.get(&sid).is_some_and(|s| s.phase == Phase::Decode && s.admitted)
            {
                continue;
            }
            let write_pos = self.seqs[&sid].all.len() - 1;
            let need = pages_for(write_pos + 1, pt);
            let mut ok = true;
            if self.seqs[&sid].base_pages.len() < need {
                match self.alloc_pages(Which::Base, 1, sid) {
                    Some(p) => self.seqs.get_mut(&sid).unwrap().base_pages.extend(p),
                    None => ok = false, // blocked this step; retry next tick
                }
            }
            if ok
                && policy.uses_residual()
                && self.seqs.get(&sid).is_some_and(|s| s.res_pages.len() < need)
            {
                match self.alloc_pages(Which::Res, 1, sid) {
                    Some(p) => self.seqs.get_mut(&sid).unwrap().res_pages.extend(p),
                    None => ok = false,
                }
            }
            if ok {
                rows.push(sid);
            }
        }
        // allocs above may have preempted earlier-chosen rows — drop them
        rows.retain(|&sid| {
            self.running.contains(&sid)
                && self.seqs.get(&sid).is_some_and(|s| s.phase == Phase::Decode && s.admitted)
        });
        if rows.is_empty() {
            self.scratch_run = snapshot;
            self.scratch_rows_now = rows;
            return Ok(false); // nothing schedulable this step
        }

        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= rows.len())
            .unwrap_or(self.max_bucket);

        // ---- assemble args (engine-owned buffers, cleared not rebuilt) ----
        let mut tokens = std::mem::take(&mut self.scratch_tokens);
        tokens.clear();
        tokens.extend(rows.iter().map(|id| *self.seqs[id].all.last().unwrap()));
        let mut cache_lens = std::mem::take(&mut self.scratch_cache_lens);
        cache_lens.clear();
        cache_lens.extend(rows.iter().map(|id| self.seqs[id].all.len() - 1));
        let mut adapter_ids = std::mem::take(&mut self.scratch_adapter_ids);
        adapter_ids.clear();
        adapter_ids.extend(
            rows.iter()
                .map(|id| self.seqs[id].req.adapter % meta.n_adapters as u32),
        );
        let mut adapter_on = std::mem::take(&mut self.scratch_adapter_on);
        adapter_on.clear();
        adapter_on.resize(rows.len(), true);
        // pad to the bucket with inert rows
        while tokens.len() < bucket {
            tokens.push(0);
            cache_lens.push(0);
            adapter_ids.push(0);
            adapter_on.push(false);
        }

        if self.exec.needs_data() {
            // Batch assembly is the L3 hot path in real mode (§Perf).
            // Re-stacking every padded slab costs ~2ms/step at bucket 8;
            // decode batches are usually stable across steps, so when the
            // row set is unchanged we copy only each row's newly appended
            // positions (~100x less traffic; see EXPERIMENTS.md §Perf).
            let row_b = meta.n_layers * meta.s_max * meta.kv_width;
            let row_r = meta.n_layers * meta.s_max * meta.rank_max;
            let mut row_keys = std::mem::take(&mut self.scratch_row_keys);
            row_keys.clear();
            row_keys.extend(rows.iter().map(|id| (*id, self.seqs[id].preemptions)));
            let same_batch = self.scratch_bucket == bucket
                && self.scratch_rows == row_keys
                && rows.iter().zip(self.scratch_filled.iter()).all(|(id, &old)| {
                    self.seqs[id].slab.as_ref().unwrap().filled >= old
                });
            if !same_batch {
                batch::stack_slabs(
                    rows.iter().map(|id| self.seqs[id].slab.as_ref().unwrap().kb.as_slice()),
                    row_b, bucket, &mut self.scratch_kb,
                );
                batch::stack_slabs(
                    rows.iter().map(|id| self.seqs[id].slab.as_ref().unwrap().vb.as_slice()),
                    row_b, bucket, &mut self.scratch_vb,
                );
                batch::stack_slabs(
                    rows.iter().map(|id| self.seqs[id].slab.as_ref().unwrap().kr.as_slice()),
                    row_r, bucket, &mut self.scratch_kr,
                );
                batch::stack_slabs(
                    rows.iter().map(|id| self.seqs[id].slab.as_ref().unwrap().vr.as_slice()),
                    row_r, bucket, &mut self.scratch_vr,
                );
            } else {
                let wb = meta.kv_width;
                let wr = meta.rank_max;
                let s = meta.s_max;
                for (i, id) in rows.iter().enumerate() {
                    let slab = self.seqs[id].slab.as_ref().unwrap();
                    let (from, to) = (self.scratch_filled[i], slab.filled);
                    for l in 0..meta.n_layers {
                        let src = (l * s + from) * wb;
                        let len = (to - from) * wb;
                        let dst = i * row_b + src;
                        self.scratch_kb[dst..dst + len]
                            .copy_from_slice(&slab.kb[src..src + len]);
                        self.scratch_vb[dst..dst + len]
                            .copy_from_slice(&slab.vb[src..src + len]);
                        let src_r = (l * s + from) * wr;
                        let len_r = (to - from) * wr;
                        let dst_r = i * row_r + src_r;
                        self.scratch_kr[dst_r..dst_r + len_r]
                            .copy_from_slice(&slab.kr[src_r..src_r + len_r]);
                        self.scratch_vr[dst_r..dst_r + len_r]
                            .copy_from_slice(&slab.vr[src_r..src_r + len_r]);
                    }
                }
            }
            self.scratch_bucket = bucket;
            // swap: `scratch_rows` becomes this batch's keys, and last
            // batch's key buffer is retained for the next tick
            std::mem::swap(&mut self.scratch_rows, &mut row_keys);
            self.scratch_row_keys = row_keys;
            self.scratch_filled.clear();
            self.scratch_filled.extend(
                rows.iter()
                    .map(|id| self.seqs[id].slab.as_ref().unwrap().filled),
            );
        }

        let out = {
            let args = DecodeArgs {
                tokens: &tokens,
                cache_lens: &cache_lens,
                adapter_ids: &adapter_ids,
                adapter_on: &adapter_on,
                kb: &self.scratch_kb,
                vb: &self.scratch_vb,
                kr: &self.scratch_kr,
                vr: &self.scratch_vr,
            };
            self.exec.decode(bucket, &args)?
        };
        self.now_us += out.elapsed_us;
        self.metrics.decode_steps += 1;
        self.metrics.decode_rows += rows.len() as u64;
        self.metrics.decode_busy_us += out.elapsed_us;
        self.metrics.record_decode_batch(rows.len());

        // ---- apply results per row ----
        let use_merged = !policy.uses_residual();
        for (i, &sid) in rows.iter().enumerate() {
            let write_pos = self.seqs[&sid].all.len() - 1;
            if let Some(d) = &out.out {
                let (k_src, v_src) = if use_merged { (&d.km, &d.vm) } else { (&d.kb, &d.vb) };
                let page = self.seqs[&sid].base_pages[write_pos / pt];
                debug_assert_eq!(
                    self.base_pool.refcount(page),
                    1,
                    "decode must never write a shared page (CoW invariant)"
                );
                batch::scatter_token(
                    &mut self.base_pool,
                    page,
                    write_pos,
                    i,
                    meta.n_layers,
                    meta.kv_width,
                    k_src,
                    v_src,
                );
                if policy.uses_residual() {
                    let page = self.seqs[&sid].res_pages[write_pos / pt];
                    let pool = self.res_pool.as_mut().expect("res pool");
                    debug_assert_eq!(pool.refcount(page), 1);
                    batch::scatter_token(
                        pool,
                        page,
                        write_pos,
                        i,
                        meta.n_layers,
                        meta.rank_max,
                        &d.kr,
                        &d.vr,
                    );
                }
                let seq = self.seqs.get_mut(&sid).unwrap();
                let slab = seq.slab.as_mut().expect("slab");
                slab.append_decode(d, i, write_pos, bucket, use_merged);
            }
            // sample the next token
            let tok = match &out.out {
                Some(d) => argmax(&d.logits[i * meta.vocab..(i + 1) * meta.vocab]),
                None => self.rng.token(meta.vocab),
            };
            let seq = self.seqs.get_mut(&sid).unwrap();
            seq.processed = write_pos + 1;
            seq.generated.push(tok);
            seq.all.push(tok);
            let eos_hit = !seq.req.ignore_eos && tok == EOS;
            let len_hit = seq.generated.len() >= seq.req.max_new;
            let ctx_hit = seq.all.len() >= meta.s_max;
            if eos_hit || len_hit || ctx_hit {
                self.finish_seq(sid);
            }
        }
        // hand the gather buffers back for the next tick (capacity kept)
        self.scratch_run = snapshot;
        self.scratch_rows_now = rows;
        self.scratch_tokens = tokens;
        self.scratch_cache_lens = cache_lens;
        self.scratch_adapter_ids = adapter_ids;
        self.scratch_adapter_on = adapter_on;
        Ok(true)
    }

    // analyze:allow(panic_path, fn) callers pass a sid they just observed live in seqs
    fn finish_seq(&mut self, sid: u64) {
        // publish the generated span too: successor agents (ReAct) fork
        // from prompt + previous outputs
        self.publish(sid);
        self.release_seq_resources(sid);
        self.running.retain(|&id| id != sid);
        self.waiting.retain(|&id| id != sid);
        let seq = self.seqs.remove(&sid).expect("seq");
        self.metrics.completed += 1;
        self.finished.push(FinishedRequest {
            id: seq.req.id,
            tag: seq.req.tag,
            adapter: seq.req.adapter,
            prompt_len: seq.req.tokens.len(),
            generated: seq.generated,
            arrival_us: seq.req.arrival_us,
            first_token_us: seq.first_token_us.unwrap_or(self.now_us),
            finish_us: self.now_us,
            hit_full: seq.hit_full,
            hit_partial: seq.hit_partial,
            computed_prompt: seq.computed_prompt,
            preemptions: seq.preemptions,
            first_logits: seq.first_logits,
        });
    }

    /// Full per-shard stats snapshot: the engine metrics plus the
    /// tree-derived eviction counters and the live budget/capacity
    /// gauges — what `Cmd::Stats` (and therefore `/stats` and
    /// `/metrics`) serve per shard. The per-shard `budget_bytes` always
    /// sum to the pool's configured budget (the rebalancer conserves
    /// the total); `capacity_bytes` is the *reported* capacity,
    /// `min(physical pools, budget)`, so utilization never reads >100%.
    pub fn stats_json(&mut self) -> Json {
        let deferred = self.trees.base.stats().deferred_evictions
            + self.trees.residual.stats().deferred_evictions;
        let budget = self.budget_bytes;
        let capacity = self.capacity_bytes();
        let (tier_bytes, tier_budget) = self
            .tier
            .as_ref()
            .map_or((0, 0), |t| (t.bytes(), t.budget_bytes()));
        let mut j = self.metrics.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("evictions_deferred".into(), Json::num(deferred as f64));
            m.insert("budget_bytes".into(), Json::num(budget as f64));
            m.insert("capacity_bytes".into(), Json::num(capacity as f64));
            m.insert("tier_bytes".into(), Json::num(tier_bytes as f64));
            m.insert("tier_budget_bytes".into(), Json::num(tier_budget as f64));
        }
        j
    }

    /// Consistency checks used by integration tests after a run.
    pub fn check_quiescent(&self) -> Result<(), String> {
        if !self.seqs.is_empty() {
            return Err(format!("{} sequences still live", self.seqs.len()));
        }
        if !self.seq_pins.is_empty() {
            return Err(format!(
                "{} queued-fork eviction pin sets leaked",
                self.seq_pins.len()
            ));
        }
        if !self.prefetch_leases.is_empty() {
            return Err(format!(
                "{} prefetch leases leaked",
                self.prefetch_leases.len()
            ));
        }
        let pinned =
            self.trees.base.pinned_nodes() + self.trees.residual.pinned_nodes();
        if pinned != 0 {
            return Err(format!("{pinned} tree nodes still workflow-pinned"));
        }
        self.base_pool.check_invariants()?;
        if let Some(p) = &self.res_pool {
            p.check_invariants()?;
        }
        if let Some(t) = &self.tier {
            t.check_invariants()?;
        }
        self.trees.base.check_invariants(&self.base_pool)?;
        if let Some(p) = &self.res_pool {
            self.trees.residual.check_invariants(p)?;
        }
        // all remaining pages must be owned by the trees
        let tree_pages = self.trees.base.total_pages();
        if self.base_pool.used_pages() != tree_pages {
            return Err(format!(
                "base pool has {} used pages but trees own {}",
                self.base_pool.used_pages(),
                tree_pages
            ));
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // cross-shard page migration (spill costs bandwidth, not FLOPs)
    // -----------------------------------------------------------------

    /// Probe half of the migration protocol: what would an export of
    /// this prompt move? Read-only (`RadixTree::probe_pages` — no
    /// leases, no copies), so the home shard can be asked cheaply before
    /// any bytes change hands. `tokens` should be the prompt minus its
    /// final token, mirroring `admit_fork`'s match window.
    pub fn migration_probe(&self, adapter: u32, tokens: &[u32]) -> MigrationEstimate {
        let ns = base_ns(self.cfg.policy, adapter);
        let pt = self.cfg.cache.page_tokens;
        let base_pages = self.trees.base.probe_pages(ns, tokens);
        let base_bytes = base_pages * self.base_pool.spec().bytes_per_page();
        let (res_pages, res_bytes, tokens_saved) = match &self.res_pool {
            Some(pool) => {
                let n = self.trees.residual.probe_pages(adapter, tokens);
                // fork admission skips the *joint* coverage
                (n, n * pool.spec().bytes_per_page(), base_pages.min(n) * pt)
            }
            None => (0, 0, base_pages * pt),
        };
        MigrationEstimate {
            base_pages,
            res_pages,
            bytes: base_bytes + res_bytes,
            tokens_saved,
        }
    }

    /// Export half: snapshot the matched pages' bytes plus their token
    /// path. The pages are leased (`match_lease`) for the duration of
    /// the copy so the LRU cannot evict them mid-export; both leases and
    /// pool refs are dropped before returning — the payload owns plain
    /// buffers, fully decoupled from this shard's pool.
    pub fn export_pages(&mut self, adapter: u32, tokens: &[u32]) -> MigrationPayload {
        let ns = base_ns(self.cfg.policy, adapter);
        let base = export_component(&mut self.trees.base, &mut self.base_pool, ns, tokens);
        let residual = self.res_pool.as_mut().map(|pool| {
            export_component(&mut self.trees.residual, pool, adapter, tokens)
        });
        let payload = MigrationPayload {
            page_tokens: self.cfg.cache.page_tokens,
            base,
            residual,
        };
        self.metrics.exported_pages += payload.pages() as u64;
        payload
    }

    /// Import half: adopt a peer shard's snapshot into this shard's
    /// pools and trees so the spilled request's `fork_match` hits
    /// locally. Refcount-correct: freshly allocated pages are handed to
    /// `RadixTree::insert` (which retains what it adopts and ignores
    /// chunks it already holds), then this method's own allocation refs
    /// are released — the tree ends up the sole owner either way.
    /// Allocation respects the byte budget and may evict this shard's
    /// own LRU tail, but never preempts running sequences; under
    /// pressure only a prefix of the payload is adopted (a prefix is
    /// still a valid radix path).
    ///
    /// Returns the number of pages *newly adopted* — pages the tree
    /// already held are deduplicated and do NOT count, so the migration
    /// metrics report only savings that were actually at risk (a repeat
    /// import of a payload the shard already holds reports 0).
    pub fn import_pages(&mut self, payload: &MigrationPayload) -> usize {
        let pt = self.cfg.cache.page_tokens;
        if payload.page_tokens != pt {
            return 0; // geometry mismatch: refuse rather than corrupt
        }
        let covered_before = self.joint_payload_coverage(payload);
        let adopted_base = self.import_component(Which::Base, &payload.base);
        let adopted_res = match &payload.residual {
            Some(res) if self.cfg.policy.uses_residual() => {
                self.import_component(Which::Res, res)
            }
            _ => 0,
        };
        let adopted = adopted_base + adopted_res;
        // recompute protection actually *gained*: joint coverage over
        // the payload's token path after minus before the import.
        // Coverage the target already had (a previous migration, its own
        // traffic) is never banked twice.
        let saved = self
            .joint_payload_coverage(payload)
            .saturating_sub(covered_before);
        let bytes = adopted_base * self.base_pool.spec().bytes_per_page()
            + self
                .res_pool
                .as_ref()
                .map_or(0, |p| adopted_res * p.spec().bytes_per_page());
        self.metrics.migrated_pages += adopted as u64;
        self.metrics.migrated_bytes += bytes as u64;
        self.metrics.recompute_tokens_saved += saved as u64;
        adopted
    }

    /// Joint (base ∧ residual) cached coverage of this shard's trees
    /// over a payload's token paths, in tokens — what fork admission
    /// would skip for a request carrying that prefix. Both component
    /// paths are prefixes of one request window, so the page-wise min is
    /// exactly the joint coverage.
    fn joint_payload_coverage(&self, payload: &MigrationPayload) -> usize {
        let pt = self.cfg.cache.page_tokens;
        let base = self
            .trees
            .base
            .probe_pages(payload.base.ns, &payload.base.tokens);
        match (&payload.residual, self.cfg.policy.uses_residual()) {
            (Some(r), true) => {
                base.min(self.trees.residual.probe_pages(r.ns, &r.tokens)) * pt
            }
            _ => base * pt,
        }
    }

    /// Returns the number of pages *newly adopted* by the tree — pages
    /// it already held are deduplicated (and this method's redundant
    /// copies freed), so the count can be below the payload prefix that
    /// was walked.
    // analyze:allow(panic_path, fn) Which::Res is only constructed when the residual pool exists
    fn import_component(&mut self, which: Which, c: &crate::migrate::ComponentExport) -> usize {
        let pt = self.cfg.cache.page_tokens;
        if c.tokens.len() < c.pages.len() * pt {
            return 0; // malformed payload: refuse
        }
        let expect = match which {
            Which::Base => self.base_pool.spec().floats_per_page(),
            Which::Res => self
                .res_pool
                .as_ref()
                .expect("res pool")
                .spec()
                .floats_per_page(),
        };
        let mut pages: Vec<PageId> = Vec::with_capacity(c.pages.len());
        for data in &c.pages {
            if data.len() != expect {
                break; // page-size mismatch past here: keep the valid prefix
            }
            let Some(p) = self.alloc_import_page(which) else {
                break; // budget exhausted: keep the prefix we could afford
            };
            let pool = match which {
                Which::Base => &mut self.base_pool,
                Which::Res => self.res_pool.as_mut().expect("res pool"),
            };
            pool.page_data_mut(p).copy_from_slice(data);
            pages.push(p);
        }
        let n = pages.len();
        let mut adopted = 0;
        if n > 0 {
            let (tree, pool) = match which {
                Which::Base => (&mut self.trees.base, &mut self.base_pool),
                Which::Res => (
                    &mut self.trees.residual,
                    self.res_pool.as_mut().expect("res pool"),
                ),
            };
            adopted = tree.insert(c.ns, &c.tokens[..n * pt], &pages, pool);
            for p in pages {
                pool.release(p); // tree holds its own refs now (dedup
                                 // frees the redundant copies here)
            }
        }
        adopted
    }

    /// Budget-respecting single-page allocation for imports: evicts this
    /// tree's own LRU tail under pressure, but never preempts sequences
    /// — a migration must not cannibalize running work to speed up
    /// future work.
    // analyze:allow(panic_path, fn) Which::Res is only constructed when the residual pool exists
    fn alloc_import_page(&mut self, which: Which) -> Option<PageId> {
        loop {
            let page_bytes = match which {
                Which::Base => self.base_pool.spec().bytes_per_page(),
                Which::Res => self
                    .res_pool
                    .as_ref()
                    .expect("res pool")
                    .spec()
                    .bytes_per_page(),
            };
            // the *current* (elastic) budget, not the constructed one: a
            // shard whose budget was lent away must not let imports
            // push it back over the shrunken limit
            if self.used_cache_bytes() + page_bytes <= self.budget_bytes {
                let pool = match which {
                    Which::Base => &mut self.base_pool,
                    Which::Res => self.res_pool.as_mut().expect("res pool"),
                };
                if let Some(p) = pool.alloc() {
                    return Some(p);
                }
            }
            let evicted = self.evict_demote(which, 1, true);
            if evicted == 0 {
                return None;
            }
        }
    }

    /// Test hook: capacities of every per-tick gather buffer — the
    /// steady-state decode loop must not grow any of them.
    #[cfg(test)]
    pub(crate) fn decode_scratch_caps(&self) -> Vec<usize> {
        vec![
            self.scratch_run.capacity(),
            self.scratch_rows_now.capacity(),
            self.scratch_tokens.capacity(),
            self.scratch_cache_lens.capacity(),
            self.scratch_adapter_ids.capacity(),
            self.scratch_adapter_on.capacity(),
            self.scratch_row_keys.capacity(),
            self.scratch_rows.capacity(),
            self.scratch_filled.capacity(),
            self.scratch_kb.capacity(),
            self.scratch_vb.capacity(),
            self.scratch_kr.capacity(),
            self.scratch_vr.capacity(),
        ]
    }
}

#[derive(Clone, Copy)]
enum Which {
    Base,
    Res,
}

/// A cross-step prefetch lease: the pinned (epoch-stamped) paths covering
/// a future step's known prefix, plus the page count the lease covered at
/// issue time (the `prefetch_wasted` ledger on abandonment).
struct PrefetchLease {
    base: PinPath,
    res: PinPath,
    pages: usize,
}

/// Scatter chunk rows for absolute positions `[from, end)` where the chunk
/// was computed starting at `chunk_start` (layout `[L, chunk, src_width]`).
// analyze:allow(panic_path, fn) callers allocate pages_for(end) pages before scattering, so pos/pt < pages.len()
#[allow(clippy::too_many_arguments)]
fn scatter_range(
    pool: &mut BlockPool,
    pages: &[PageId],
    from: usize,
    end: usize,
    chunk_start: usize,
    chunk: usize,
    src_width: usize,
    k_src: &[f32],
    v_src: &[f32],
) {
    let pt = pool.spec().page_tokens;
    let w = pool.spec().width;
    let n_layers = pool.spec().n_layers;
    assert!(w <= src_width);
    for l in 0..n_layers {
        for pos in from..end {
            let t = pos - chunk_start;
            let page = pages[pos / pt];
            let slot = pos % pt;
            let src = (l * chunk + t) * src_width;
            let dst = slot * w;
            pool.kv_slice_mut(page, l, 0)[dst..dst + w]
                .copy_from_slice(&k_src[src..src + w]);
            pool.kv_slice_mut(page, l, 1)[dst..dst + w]
                .copy_from_slice(&v_src[src..src + w]);
        }
    }
}

#[cfg(test)]
mod tests;
