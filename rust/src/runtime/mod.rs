//! PJRT runtime: loads the AOT artifacts (`*.hlo.txt`), uploads the model
//! weights + adapter bank once as device buffers, and exposes typed
//! prefill/decode calls for the engine's hot path.
//!
//! Python never runs here — the HLO text was produced by `make artifacts`
//! and this module replays it through the `xla` crate's PJRT CPU client
//! (`HloModuleProto::from_text_file` → compile → `execute_b`).
//!
//! The `xla` crate only exists in environments carrying the vendored XLA
//! bindings, so the real runtime is gated behind the off-by-default `pjrt`
//! cargo feature. Without it, `PjrtRuntime::load` returns a descriptive
//! error and every other code path (sim executor, engine, server, CLI)
//! works unchanged.

pub mod manifest;

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

pub use manifest::{ArtifactEntry, Manifest, ModelMeta};

#[cfg(feature = "pjrt")]
fn xe(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e:?}")
}

/// Per-call inputs of one prefill chunk (single sequence).
pub struct PrefillArgs<'a> {
    /// real tokens of the chunk (<= chunk size; padded internally)
    pub tokens: &'a [u32],
    pub cache_len: usize,
    pub adapter_id: u32,
    pub adapter_on: bool,
    /// padded cache slabs, layouts [L,S,KH*HD] (kb/vb) and [L,S,R] (kr/vr)
    pub kb: &'a [f32],
    pub vb: &'a [f32],
    pub kr: &'a [f32],
    pub vr: &'a [f32],
}

/// Chunk outputs; `n` below is the number of *real* tokens in the call.
pub struct PrefillOut {
    /// [chunk, vocab] (rows past n are padding garbage)
    pub logits: Vec<f32>,
    /// [L, chunk, KH*HD]
    pub kb: Vec<f32>,
    pub vb: Vec<f32>,
    /// [L, chunk, R]
    pub kr: Vec<f32>,
    pub vr: Vec<f32>,
    /// merged monolithic chunk KV for the unified baselines [L, chunk, KH*HD]
    pub km: Vec<f32>,
    pub vm: Vec<f32>,
    /// per-layer hidden states [L, chunk, d] (Fig. 5b probe)
    pub xs: Vec<f32>,
}

/// One decode step over `rows.len()` sequences (padded to an AOT bucket).
pub struct DecodeArgs<'a> {
    pub tokens: &'a [u32],
    pub cache_lens: &'a [usize],
    pub adapter_ids: &'a [u32],
    pub adapter_on: &'a [bool],
    /// [B, L, S, KH*HD] and [B, L, S, R] slabs (B = padded bucket size)
    pub kb: &'a [f32],
    pub vb: &'a [f32],
    pub kr: &'a [f32],
    pub vr: &'a [f32],
}

pub struct DecodeOut {
    /// [B, vocab]
    pub logits: Vec<f32>,
    /// [B, L, KH*HD]
    pub kb: Vec<f32>,
    pub vb: Vec<f32>,
    /// [B, L, R]
    pub kr: Vec<f32>,
    pub vr: Vec<f32>,
    /// merged new-token KV [B, L, KH*HD]
    pub km: Vec<f32>,
    pub vm: Vec<f32>,
}

#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    /// params + bank, uploaded once, in manifest order
    weights: Vec<xla::PjRtBuffer>,
    prefill_exe: xla::PjRtLoadedExecutable,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

/// Built without the `pjrt` feature: a never-constructible placeholder with
/// the same API, so callers (executor, CLI, tests) compile unchanged and get
/// a clear error from `load` at runtime.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    never: std::convert::Infallible,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn load(_dir: &Path) -> anyhow::Result<Self> {
        anyhow::bail!(
            "forkkv was built without the `pjrt` feature: real PJRT execution \
             needs the vendored `xla` crate. Use the sim backend (`forkkv run` \
             / `forkkv bench-http`), or add the `xla` dependency in \
             rust/Cargo.toml and rebuild with `--features pjrt` (see \
             rust/README.md)."
        )
    }
    pub fn meta(&self) -> &ModelMeta {
        match self.never {}
    }
    pub fn decode_buckets(&self) -> Vec<usize> {
        match self.never {}
    }
    pub fn bucket_for(&self, _rows: usize) -> anyhow::Result<usize> {
        match self.never {}
    }
    pub fn prefill(&self, _a: &PrefillArgs) -> anyhow::Result<PrefillOut> {
        match self.never {}
    }
    pub fn decode(&self, _bucket: usize, _a: &DecodeArgs) -> anyhow::Result<DecodeOut> {
        match self.never {}
    }
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Load manifest + weights + compile all artifacts from
    /// `artifacts/<model>/`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;

        // ---- weights.bin -> per-tensor device buffers (uploaded once) ----
        let raw = std::fs::read(dir.join("weights.bin"))?;
        anyhow::ensure!(raw.len() % 4 == 0, "weights.bin not f32-aligned");
        let floats: &[f32] = unsafe {
            std::slice::from_raw_parts(raw.as_ptr() as *const f32, raw.len() / 4)
        };
        let mut weights = Vec::new();
        for entry in manifest.params.iter().chain(manifest.bank.iter()) {
            let n = entry.elems();
            anyhow::ensure!(
                entry.offset + n <= floats.len(),
                "weights.bin too small for {}",
                entry.name
            );
            let data = &floats[entry.offset..entry.offset + n];
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &entry.shape, None)
                .map_err(xe)?;
            weights.push(buf);
        }

        // ---- compile artifacts ----
        let compile = |file: &str| -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let proto =
                xla::HloModuleProto::from_text_file(dir.join(file)).map_err(xe)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(xe)
        };
        let prefill_entry = manifest
            .artifact("prefill")
            .ok_or_else(|| anyhow::anyhow!("manifest lacks prefill artifact"))?;
        let prefill_exe = compile(&prefill_entry.file)?;
        let mut decode_exes = BTreeMap::new();
        for a in &manifest.artifacts {
            if a.kind == "decode" {
                decode_exes.insert(a.batch, compile(&a.file)?);
            }
        }
        anyhow::ensure!(!decode_exes.is_empty(), "no decode artifacts");
        Ok(PjrtRuntime {
            client,
            manifest,
            weights,
            prefill_exe,
            decode_exes,
            dir: dir.to_path_buf(),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.manifest.model
    }

    pub fn decode_buckets(&self) -> Vec<usize> {
        self.decode_exes.keys().copied().collect()
    }

    /// Smallest compiled decode bucket that fits `rows`.
    pub fn bucket_for(&self, rows: usize) -> anyhow::Result<usize> {
        self.decode_exes
            .keys()
            .copied()
            .find(|&b| b >= rows)
            .ok_or_else(|| anyhow::anyhow!("no decode bucket >= {rows}"))
    }

    fn f32_buf(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(xe)
    }

    fn i32_buf(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(xe)
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: Vec<xla::PjRtBuffer>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(
            self.weights.len() + inputs.len(),
        );
        args.extend(self.weights.iter());
        args.extend(inputs.iter());
        let outs = exe.execute_b(&args).map_err(xe)?;
        anyhow::ensure!(!outs.is_empty() && !outs[0].is_empty(), "no outputs");
        // jax lowers with return_tuple=True: a single tuple-shaped output
        let lit = outs[0][0].to_literal_sync().map_err(xe)?;
        let mut lit = lit;
        lit.decompose_tuple().map_err(xe)
    }

    /// Execute one prefill chunk. `args.tokens.len()` may be < chunk; the
    /// tail is padded with PAD ids (outputs beyond the real rows are
    /// ignored by the caller).
    pub fn prefill(&self, a: &PrefillArgs) -> anyhow::Result<PrefillOut> {
        let m = self.meta();
        let (c, s, l) = (m.chunk, m.s_max, m.n_layers);
        let (kvw, r) = (m.kv_width(), m.rank_max);
        anyhow::ensure!(a.tokens.len() <= c, "chunk overflow");
        anyhow::ensure!(a.cache_len + a.tokens.len() <= s, "cache overflow");
        anyhow::ensure!(a.kb.len() == l * s * kvw, "kb slab shape");
        anyhow::ensure!(a.kr.len() == l * s * r, "kr slab shape");

        let mut tokens = vec![0i32; c];
        for (i, &t) in a.tokens.iter().enumerate() {
            tokens[i] = t as i32;
        }
        let inputs = vec![
            self.i32_buf(&tokens, &[c])?,
            self.i32_buf(&[a.cache_len as i32], &[])?,
            self.i32_buf(&[a.adapter_id as i32], &[])?,
            self.f32_buf(&[if a.adapter_on { 1.0 } else { 0.0 }], &[])?,
            self.f32_buf(a.kb, &[l, s, m.n_kv_heads, m.head_dim])?,
            self.f32_buf(a.vb, &[l, s, m.n_kv_heads, m.head_dim])?,
            self.f32_buf(a.kr, &[l, s, r])?,
            self.f32_buf(a.vr, &[l, s, r])?,
        ];
        let lits = self.run(&self.prefill_exe, inputs)?;
        anyhow::ensure!(lits.len() == 8, "prefill outputs: {}", lits.len());
        let v = |i: usize| -> anyhow::Result<Vec<f32>> {
            lits[i].to_vec::<f32>().map_err(xe)
        };
        Ok(PrefillOut {
            logits: v(0)?,
            kb: v(1)?,
            vb: v(2)?,
            kr: v(3)?,
            vr: v(4)?,
            km: v(5)?,
            vm: v(6)?,
            xs: v(7)?,
        })
    }

    /// Execute one decode step for up to `bucket` rows (caller pads).
    pub fn decode(&self, bucket: usize, a: &DecodeArgs) -> anyhow::Result<DecodeOut> {
        let m = self.meta();
        let (s, l) = (m.s_max, m.n_layers);
        let (kvw, r) = (m.kv_width(), m.rank_max);
        let exe = self
            .decode_exes
            .get(&bucket)
            .ok_or_else(|| anyhow::anyhow!("no decode bucket {bucket}"))?;
        let b = bucket;
        anyhow::ensure!(a.tokens.len() == b, "decode rows != bucket");
        anyhow::ensure!(a.kb.len() == b * l * s * kvw, "kb batch slab shape");
        anyhow::ensure!(a.kr.len() == b * l * s * r, "kr batch slab shape");

        let tokens: Vec<i32> = a.tokens.iter().map(|&t| t as i32).collect();
        let lens: Vec<i32> = a.cache_lens.iter().map(|&x| x as i32).collect();
        let ids: Vec<i32> = a.adapter_ids.iter().map(|&x| x as i32).collect();
        let on: Vec<f32> = a
            .adapter_on
            .iter()
            .map(|&x| if x { 1.0 } else { 0.0 })
            .collect();
        let inputs = vec![
            self.i32_buf(&tokens, &[b])?,
            self.i32_buf(&lens, &[b])?,
            self.i32_buf(&ids, &[b])?,
            self.f32_buf(&on, &[b])?,
            self.f32_buf(a.kb, &[b, l, s, m.n_kv_heads, m.head_dim])?,
            self.f32_buf(a.vb, &[b, l, s, m.n_kv_heads, m.head_dim])?,
            self.f32_buf(a.kr, &[b, l, s, r])?,
            self.f32_buf(a.vr, &[b, l, s, r])?,
        ];
        let lits = self.run(exe, inputs)?;
        anyhow::ensure!(lits.len() == 7, "decode outputs: {}", lits.len());
        let v = |i: usize| -> anyhow::Result<Vec<f32>> {
            lits[i].to_vec::<f32>().map_err(xe)
        };
        Ok(DecodeOut {
            logits: v(0)?,
            kb: v(1)?,
            vb: v(2)?,
            kr: v(3)?,
            vr: v(4)?,
            km: v(5)?,
            vm: v(6)?,
        })
    }
}

/// Greedy argmax over one logits row.
pub fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
