//! `manifest.json` — the AOT contract between the python compile path and
//! this runtime. Mirrors `python/compile/aot.py`.

use std::path::Path;

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f64,
    pub qkv_bias: bool,
    pub s_max: usize,
    pub chunk: usize,
    pub rank_max: usize,
    pub n_adapters: usize,
    pub decode_batches: Vec<usize>,
    pub rank_effective: usize,
}

impl ModelMeta {
    /// `n` in the paper's Eq. 3: per-layer K (or V) width of the bCache.
    pub fn kv_width(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
    /// bCache bytes per token across all layers (K + V, f32).
    pub fn base_bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.kv_width() * 4
    }
    /// rCache bytes per token across all layers (K_res + V_res, f32),
    /// using the *effective* rank (the padded tail is a compile-time
    /// convenience, not real state — accounting matches the paper).
    pub fn res_bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.rank_effective * 4
    }
}

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// offset in f32 elements into weights.bin
    pub offset: usize,
}

impl TensorEntry {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelMeta,
    pub params: Vec<TensorEntry>,
    pub bank: Vec<TensorEntry>,
    /// artifact key ("prefill", "decode_b4", ...) -> (file, runtime inputs, outputs)
    pub artifacts: Vec<ArtifactEntry>,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub key: String,
    pub kind: String,
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

fn tensor_entries(j: &Json, section: &str) -> anyhow::Result<Vec<TensorEntry>> {
    j.req_arr(section)?
        .iter()
        .map(|e| {
            Ok(TensorEntry {
                name: e.req_str("name")?.to_string(),
                shape: e
                    .req_arr("shape")?
                    .iter()
                    .map(|s| s.as_usize().unwrap_or(0))
                    .collect(),
                offset: e.req_usize("offset")?,
            })
        })
        .collect()
}

fn io_specs(j: &Json) -> anyhow::Result<Vec<IoSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("io spec not an array"))?
        .iter()
        .map(|e| {
            let trip = e.as_arr().ok_or_else(|| anyhow::anyhow!("io entry"))?;
            anyhow::ensure!(trip.len() == 3, "io entry len");
            Ok(IoSpec {
                name: trip[0].as_str().unwrap_or("").to_string(),
                shape: trip[1]
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|s| s.as_usize().unwrap_or(0))
                    .collect(),
                dtype: trip[2].as_str().unwrap_or("f32").to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let m = j
            .get("model")
            .ok_or_else(|| anyhow::anyhow!("manifest missing model"))?;
        let model = ModelMeta {
            name: m.req_str("name")?.to_string(),
            n_layers: m.req_usize("n_layers")?,
            d_model: m.req_usize("d_model")?,
            n_heads: m.req_usize("n_heads")?,
            n_kv_heads: m.req_usize("n_kv_heads")?,
            head_dim: m.req_usize("head_dim")?,
            d_ff: m.req_usize("d_ff")?,
            vocab: m.req_usize("vocab")?,
            rope_theta: m.req_f64("rope_theta")?,
            qkv_bias: m.get("qkv_bias").and_then(Json::as_bool).unwrap_or(false),
            s_max: m.req_usize("s_max")?,
            chunk: m.req_usize("chunk")?,
            rank_max: m.req_usize("rank_max")?,
            n_adapters: m.req_usize("n_adapters")?,
            decode_batches: m
                .req_arr("decode_batches")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            rank_effective: m.req_usize("rank_effective")?,
        };
        let ri = j
            .get("runtime_inputs")
            .ok_or_else(|| anyhow::anyhow!("missing runtime_inputs"))?;
        let outs = j
            .get("outputs")
            .ok_or_else(|| anyhow::anyhow!("missing outputs"))?;
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            let kind = a.req_str("kind")?.to_string();
            let batch = a.req_usize("batch")?;
            let key = if kind == "prefill" {
                "prefill".to_string()
            } else {
                format!("decode_b{batch}")
            };
            artifacts.push(ArtifactEntry {
                inputs: io_specs(ri.at(&[&key]))?,
                outputs: io_specs(outs.at(&[&key]))?,
                key,
                kind,
                batch,
                file: a.req_str("file")?.to_string(),
            });
        }
        Ok(Manifest {
            model,
            params: tensor_entries(&j, "params")?,
            bank: tensor_entries(&j, "bank")?,
            artifacts,
        })
    }

    pub fn artifact(&self, key: &str) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.key == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_geometry() {
        let m = ModelMeta {
            name: "t".into(),
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 32,
            d_ff: 704,
            vocab: 2048,
            rope_theta: 1e4,
            qkv_bias: false,
            s_max: 768,
            chunk: 64,
            rank_max: 32,
            n_adapters: 16,
            decode_batches: vec![1, 2, 4, 8],
            rank_effective: 16,
        };
        assert_eq!(m.kv_width(), 128);
        assert_eq!(m.base_bytes_per_token(), 4 * 2 * 128 * 4);
        assert_eq!(m.res_bytes_per_token(), 4 * 2 * 16 * 4);
        // Eq. 3 asymmetry: rCache is r/n of bCache
        let ratio = m.res_bytes_per_token() as f64 / m.base_bytes_per_token() as f64;
        assert!((ratio - 16.0 / 128.0).abs() < 1e-9);
    }
}
