//! ForkKV: Scaling Multi-LoRA Agent Serving via Copy-on-Write Disaggregated
//! KV Cache — full-system reproduction (see DESIGN.md).
//!
//! Layer map:
//!   - L1/L2 live in `python/compile` (build time only; `make artifacts`)
//!   - this crate is L3: the serving coordinator that loads the AOT HLO
//!     artifacts via PJRT and owns the request path end to end.

pub mod analysis;
pub mod batch;
pub mod config;
pub mod engine;
pub mod exec;
pub mod journal;
pub mod kvcache;
pub mod metrics;
pub mod migrate;
pub mod radix;
pub mod rebalance;
pub mod router;
pub mod runtime;
pub mod server;
pub mod tier;
pub mod util;
pub mod workload;
