//! Tier-2 (host-memory) KV page store: demoted radix pages survive here
//! instead of being destroyed, so a session returning after its pages
//! lost the LRU race warm-starts by copying bytes back instead of
//! re-prefilling FLOPs — the paper's "pay bytes, not FLOPs" thesis
//! applied one tier down from PR 3's cross-shard migration.
//!
//! Layout follows the mini-lsm exemplar scaled to page granularity:
//! **append-only segments** of page-sized records plus an **in-memory
//! index** keyed by `(tree component, namespace, node-path fingerprint)`.
//! A record's fingerprint hashes the *full token path* from the radix
//! root through the demoted node, so it uniquely names both the prefix
//! and the page index within it — the tier index IS the demoted-residency
//! marker (the radix node itself is removed at demotion, exactly like
//! the pre-tier eviction, so no tree invariant changes).
//!
//! Records are never rewritten in place: replacement and promotion mark
//! the old record **dead**, and [`TierStore::compact`] (driven inline
//! under insert pressure and by the server's background supervisor)
//! rewrites the segments dropping dead records. The store enforces its
//! own byte budget: an insert that would overflow first compacts, then
//! evicts the oldest live records (append order ≈ demotion order ≈ LRU),
//! so retained bytes never exceed the configured budget.

#![warn(missing_docs)]

use std::collections::HashMap;

/// Which radix tree a demoted page belongs to. Kept separate from the
/// namespace because base ns 0 and residual adapter 0 would otherwise
/// collide in the index.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Component {
    /// the token-keyed bCache tree
    Base,
    /// the (adapter, token)-keyed rCache tree
    Residual,
}

/// FNV-1a 64-bit hash of a token path — the node-path fingerprint half
/// of a [`PageKey`]. Stable across processes (no randomized state), so
/// calibration or debugging tools can reproduce keys offline.
pub fn fingerprint(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Identity of one demoted page: the tree it came from, its namespace,
/// and the fingerprint of the full token path from the root through the
/// page (which encodes the page index — path length grows one page per
/// level).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageKey {
    component: Component,
    ns: u32,
    fp: u64,
}

impl PageKey {
    /// Key for the page whose node path spells exactly `token_path`
    /// (page-aligned: the demoted node's tokens are the final
    /// `page_tokens` of the path).
    pub fn new(component: Component, ns: u32, token_path: &[u32]) -> Self {
        PageKey { component, ns, fp: fingerprint(token_path) }
    }
}

/// One page-sized record in a segment. `data` is an owned snapshot of
/// the pool page's floats (the same owned-buffer discipline as
/// `migrate::ComponentExport`), fully decoupled from any pool.
#[derive(Debug)]
struct Record {
    key: PageKey,
    /// the full token path the key fingerprints, recorded at demote time
    /// (fingerprints are one-way, so without this a checkpoint could
    /// never name the tier's contents). Empty when the caller used the
    /// path-less [`TierStore::insert`].
    path: Vec<u32>,
    data: Vec<f32>,
    dead: bool,
}

impl Record {
    fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// One append-only run of records. Sealed implicitly: appends go to the
/// last segment until it crosses the store's segment byte target.
#[derive(Debug, Default)]
struct Segment {
    records: Vec<Record>,
    live_bytes: usize,
    dead_bytes: usize,
}

impl Segment {
    fn bytes(&self) -> usize {
        self.live_bytes + self.dead_bytes
    }
}

/// Lifetime counters for the tier store (see each field).
#[derive(Debug, Default, Clone)]
pub struct TierStats {
    /// records accepted by [`TierStore::insert`]
    pub inserted_pages: u64,
    /// inserts that replaced an existing record for the same key
    pub replaced_pages: u64,
    /// live records evicted to make room under the tier's own budget
    pub evicted_pages: u64,
    /// inserts refused because the record could not fit the budget
    pub rejected_pages: u64,
    /// compaction passes that actually reclaimed bytes
    pub compactions: u64,
    /// dead bytes reclaimed across all compactions
    pub reclaimed_bytes: u64,
}

/// The host-memory tier-2 page store (module docs). Single-owner like
/// everything else in the engine: each shard's `Engine` owns one.
#[derive(Debug)]
pub struct TierStore {
    budget_bytes: usize,
    /// seal threshold: appends open a fresh segment past this many bytes
    seg_bytes: usize,
    segments: Vec<Segment>,
    /// key -> (segment, record) location of the live record
    index: HashMap<PageKey, (u32, u32)>,
    live_bytes: usize,
    total_bytes: usize,
    stats: TierStats,
}

impl TierStore {
    /// Empty store enforcing `budget_bytes` of retained (live + dead)
    /// record bytes.
    pub fn new(budget_bytes: usize) -> Self {
        TierStore {
            budget_bytes,
            seg_bytes: (budget_bytes / 8).max(1),
            segments: Vec::new(),
            index: HashMap::new(),
            live_bytes: 0,
            total_bytes: 0,
            stats: TierStats::default(),
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Bytes currently retained by the segments (live + not-yet-compacted
    /// dead). Never exceeds [`TierStore::budget_bytes`].
    pub fn bytes(&self) -> usize {
        self.total_bytes
    }

    /// Bytes held by live (promotable) records only.
    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    /// Live records in the index.
    pub fn entries(&self) -> usize {
        self.index.len()
    }

    /// Lifetime counters.
    pub fn stats(&self) -> &TierStats {
        &self.stats
    }

    /// The live record for `key`, if resident.
    pub fn get(&self, key: &PageKey) -> Option<&[f32]> {
        let &(seg, rec) = self.index.get(key)?;
        Some(&self.segments[seg as usize].records[rec as usize].data)
    }

    /// Is a live record for `key` resident?
    pub fn contains(&self, key: &PageKey) -> bool {
        self.index.contains_key(key)
    }

    /// Every live record's identity with a recorded token path:
    /// `(component, namespace, full token path)` in no particular order.
    /// Records inserted without a path (the path-less
    /// [`TierStore::insert`]) are skipped — they are promotable by probe
    /// but not checkpointable. This is the restart scan: a shard
    /// checkpoint unions these paths with the radix tree's own.
    pub fn live_paths(&self) -> Vec<(Component, u32, &[u32])> {
        self.index
            .values()
            .map(|&(seg, rec)| &self.segments[seg as usize].records[rec as usize])
            .filter(|r| !r.path.is_empty())
            .map(|r| (r.key.component, r.key.ns, r.path.as_slice()))
            .collect()
    }

    /// Mark the live record for `key` dead (promotion took its bytes, or
    /// the caller invalidated it). The bytes stay retained until the next
    /// [`TierStore::compact`]. Returns whether a record was removed.
    pub fn remove(&mut self, key: &PageKey) -> bool {
        match self.index.remove(key) {
            Some(loc) => {
                self.kill(loc);
                true
            }
            None => false,
        }
    }

    /// Demote one page's bytes under `key`. An existing record for the
    /// key is replaced (marked dead). Enforces the tier budget: an insert
    /// that would overflow first compacts dead records away, then evicts
    /// the oldest live records; a record that still cannot fit is refused
    /// (`false`) — retained bytes never exceed the budget.
    pub fn insert(&mut self, key: PageKey, data: &[f32]) -> bool {
        self.insert_inner(key, Vec::new(), data)
    }

    /// [`TierStore::insert`] plus the page's full token path, so the
    /// record shows up in [`TierStore::live_paths`] — the variant the
    /// engine's demotion sink uses, making the tier's contents
    /// checkpointable (restart metadata, not just promote-by-probe).
    pub fn insert_path(&mut self, key: PageKey, token_path: &[u32], data: &[f32]) -> bool {
        self.insert_inner(key, token_path.to_vec(), data)
    }

    fn insert_inner(&mut self, key: PageKey, path: Vec<u32>, data: &[f32]) -> bool {
        let bytes = data.len() * 4;
        if bytes == 0 || bytes > self.budget_bytes {
            self.stats.rejected_pages += 1;
            return false;
        }
        if let Some(loc) = self.index.remove(&key) {
            self.kill(loc);
            self.stats.replaced_pages += 1;
        }
        if self.total_bytes + bytes > self.budget_bytes {
            while self.live_bytes + bytes > self.budget_bytes {
                if !self.evict_oldest() {
                    break;
                }
            }
            self.compact();
            if self.total_bytes + bytes > self.budget_bytes {
                self.stats.rejected_pages += 1;
                return false;
            }
        }
        if !self.segments.last().is_some_and(|s| s.bytes() < self.seg_bytes) {
            self.segments.push(Segment::default());
        }
        let seg = self.segments.len() - 1;
        let s = &mut self.segments[seg];
        s.live_bytes += bytes;
        s.records.push(Record { key, path, data: data.to_vec(), dead: false });
        self.index.insert(key, (seg as u32, (s.records.len() - 1) as u32));
        self.live_bytes += bytes;
        self.total_bytes += bytes;
        self.stats.inserted_pages += 1;
        true
    }

    /// Rewrite the segments dropping dead records (replaced, promoted, or
    /// budget-evicted), rebuilding the index. Returns the bytes
    /// reclaimed; a store with no dead bytes returns 0 without touching
    /// anything. Driven inline by insert-time budget pressure and
    /// periodically by the server's tier compaction supervisor.
    pub fn compact(&mut self) -> usize {
        let reclaimed = self.total_bytes - self.live_bytes;
        if reclaimed == 0 {
            return 0;
        }
        let old = std::mem::take(&mut self.segments);
        self.index.clear();
        for seg in old {
            for rec in seg.records {
                if rec.dead {
                    continue;
                }
                if !self.segments.last().is_some_and(|s| s.bytes() < self.seg_bytes) {
                    self.segments.push(Segment::default());
                }
                let si = self.segments.len() - 1;
                let s = &mut self.segments[si];
                s.live_bytes += rec.bytes();
                self.index
                    .insert(rec.key, (si as u32, s.records.len() as u32));
                s.records.push(rec);
            }
        }
        self.total_bytes = self.live_bytes;
        self.stats.compactions += 1;
        self.stats.reclaimed_bytes += reclaimed as u64;
        reclaimed
    }

    /// Mark dead the oldest live record (front of the oldest segment —
    /// append order approximates demotion recency, so this is the tier's
    /// own LRU). Returns false when nothing live remains.
    fn evict_oldest(&mut self) -> bool {
        for (si, seg) in self.segments.iter().enumerate() {
            if let Some(ri) = seg.records.iter().position(|r| !r.dead) {
                let key = seg.records[ri].key;
                self.index.remove(&key);
                self.kill((si as u32, ri as u32));
                self.stats.evicted_pages += 1;
                return true;
            }
        }
        false
    }

    fn kill(&mut self, (seg, rec): (u32, u32)) {
        let s = &mut self.segments[seg as usize];
        let r = &mut s.records[rec as usize];
        debug_assert!(!r.dead, "double kill of tier record");
        r.dead = true;
        let bytes = r.bytes();
        s.live_bytes -= bytes;
        s.dead_bytes += bytes;
        self.live_bytes -= bytes;
    }

    /// Structural invariants (tests): byte accounting matches the
    /// records, every index entry points at a live record with the same
    /// key, and every live record is indexed.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut live = 0usize;
        let mut total = 0usize;
        let mut live_records = 0usize;
        for (si, seg) in self.segments.iter().enumerate() {
            let mut seg_live = 0usize;
            let mut seg_dead = 0usize;
            for (ri, rec) in seg.records.iter().enumerate() {
                total += rec.bytes();
                if rec.dead {
                    seg_dead += rec.bytes();
                    continue;
                }
                seg_live += rec.bytes();
                live += rec.bytes();
                live_records += 1;
                match self.index.get(&rec.key) {
                    Some(&(s, r)) if (s, r) == (si as u32, ri as u32) => {}
                    _ => return Err(format!("live record ({si},{ri}) not indexed")),
                }
            }
            if seg_live != seg.live_bytes || seg_dead != seg.dead_bytes {
                return Err(format!("segment {si} byte accounting drifted"));
            }
        }
        if live != self.live_bytes || total != self.total_bytes {
            return Err(format!(
                "store accounting drifted: live {live} vs {}, total {total} vs {}",
                self.live_bytes, self.total_bytes
            ));
        }
        if live_records != self.index.len() {
            return Err(format!(
                "index holds {} entries for {live_records} live records",
                self.index.len()
            ));
        }
        if self.total_bytes > self.budget_bytes {
            return Err(format!(
                "retained {} bytes exceed budget {}",
                self.total_bytes, self.budget_bytes
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(v: f32, floats: usize) -> Vec<f32> {
        vec![v; floats]
    }

    fn key(ns: u32, path: &[u32]) -> PageKey {
        PageKey::new(Component::Base, ns, path)
    }

    #[test]
    fn fingerprint_distinguishes_paths_and_components() {
        assert_ne!(fingerprint(&[1, 2, 3]), fingerprint(&[1, 2, 4]));
        assert_ne!(fingerprint(&[1, 2]), fingerprint(&[1, 2, 0]));
        assert_ne!(
            PageKey::new(Component::Base, 0, &[1, 2]),
            PageKey::new(Component::Residual, 0, &[1, 2]),
            "base ns 0 and residual adapter 0 must not collide"
        );
        assert_ne!(key(0, &[1, 2]), key(1, &[1, 2]));
    }

    #[test]
    fn insert_get_round_trip_is_byte_identical() {
        let mut t = TierStore::new(1 << 20);
        let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        assert!(t.insert(key(0, &[1, 2, 3, 4]), &data));
        assert_eq!(t.get(&key(0, &[1, 2, 3, 4])).unwrap(), &data[..]);
        assert_eq!(t.entries(), 1);
        assert_eq!(t.bytes(), 64 * 4);
        assert!(t.get(&key(0, &[1, 2, 3, 5])).is_none());
        t.check_invariants().unwrap();
    }

    #[test]
    fn replacement_marks_old_record_dead_and_compaction_reclaims() {
        let mut t = TierStore::new(1 << 20);
        let k = key(7, &[9, 9, 9, 9]);
        assert!(t.insert(k, &page(1.0, 32)));
        assert!(t.insert(k, &page(2.0, 32)));
        assert_eq!(t.stats().replaced_pages, 1);
        assert!(t.get(&k).unwrap().iter().all(|&x| x == 2.0));
        assert_eq!(t.entries(), 1);
        assert_eq!(t.bytes(), 2 * 32 * 4, "dead bytes retained until compaction");
        assert_eq!(t.live_bytes(), 32 * 4);
        assert_eq!(t.compact(), 32 * 4);
        assert_eq!(t.bytes(), 32 * 4);
        assert!(t.get(&k).unwrap().iter().all(|&x| x == 2.0), "survives compaction");
        assert_eq!(t.compact(), 0, "nothing dead: no-op");
        t.check_invariants().unwrap();
    }

    #[test]
    fn budget_evicts_oldest_and_never_exceeds() {
        // budget fits exactly 4 records; the 5th evicts the oldest
        let floats = 32;
        let rec = floats * 4;
        let mut t = TierStore::new(4 * rec);
        for i in 0..5u32 {
            assert!(t.insert(key(0, &[i]), &page(i as f32, floats)));
            assert!(t.bytes() <= t.budget_bytes(), "budget exceeded at {i}");
            t.check_invariants().unwrap();
        }
        assert_eq!(t.entries(), 4);
        assert!(t.get(&key(0, &[0])).is_none(), "oldest evicted");
        assert!(t.get(&key(0, &[4])).is_some());
        assert_eq!(t.stats().evicted_pages, 1);
        // a record bigger than the whole budget is refused outright
        assert!(!t.insert(key(0, &[99]), &page(0.0, 5 * floats)));
        assert_eq!(t.stats().rejected_pages, 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn remove_marks_dead_and_compaction_reclaims_all_released() {
        // the "all referencing nodes released" lifecycle: every record
        // removed (promoted away) -> compaction returns the tier to zero
        let mut t = TierStore::new(1 << 20);
        for i in 0..8u32 {
            assert!(t.insert(key(1, &[i, i]), &page(i as f32, 16)));
        }
        let before = t.bytes();
        for i in 0..8u32 {
            assert!(t.remove(&key(1, &[i, i])));
        }
        assert!(!t.remove(&key(1, &[0, 0])), "double remove is a no-op");
        assert_eq!(t.entries(), 0);
        assert_eq!(t.live_bytes(), 0);
        assert_eq!(t.bytes(), before, "bytes retained until compaction");
        assert_eq!(t.compact(), before);
        assert_eq!(t.bytes(), 0);
        assert_eq!(t.stats().reclaimed_bytes, before as u64);
        t.check_invariants().unwrap();
    }

    #[test]
    fn recorded_paths_survive_replacement_and_compaction() {
        let mut t = TierStore::new(1 << 20);
        assert!(t.insert_path(key(0, &[1, 2]), &[1, 2], &page(1.0, 16)));
        assert!(t.insert_path(key(0, &[1, 2, 3, 4]), &[1, 2, 3, 4], &page(2.0, 16)));
        assert!(t.insert(key(9, &[8]), &page(3.0, 16)), "path-less insert still ok");
        let mut paths: Vec<Vec<u32>> =
            t.live_paths().iter().map(|&(_, _, p)| p.to_vec()).collect();
        paths.sort();
        assert_eq!(paths, vec![vec![1, 2], vec![1, 2, 3, 4]], "path-less skipped");
        assert!(t.remove(&key(0, &[1, 2])));
        assert!(t.compact() > 0);
        let paths: Vec<Vec<u32>> =
            t.live_paths().iter().map(|&(_, _, p)| p.to_vec()).collect();
        assert_eq!(paths, vec![vec![1, 2, 3, 4]], "paths track index through compaction");
        t.check_invariants().unwrap();
    }

    #[test]
    fn segments_seal_and_survive_compaction_mix() {
        // budget/8 seal target forces multiple segments; a mixed
        // live/dead population compacts into dense segments with every
        // live record still reachable
        let floats = 64;
        let mut t = TierStore::new(floats * 4 * 16);
        for i in 0..12u32 {
            assert!(t.insert(key(0, &[i, 1]), &page(i as f32, floats)));
        }
        assert!(t.segments.len() > 1, "seal target never crossed");
        for i in (0..12u32).step_by(2) {
            assert!(t.remove(&key(0, &[i, 1])));
        }
        assert!(t.compact() > 0);
        for i in (1..12u32).step_by(2) {
            assert!(
                t.get(&key(0, &[i, 1])).unwrap().iter().all(|&x| x == i as f32),
                "record {i} lost in compaction"
            );
        }
        assert_eq!(t.entries(), 6);
        t.check_invariants().unwrap();
    }
}
