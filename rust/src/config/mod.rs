//! Typed configuration for the serving engine, cache, scheduler and
//! workloads, loadable from JSON (`--config file.json`) with defaults that
//! match the paper's evaluation setup scaled to this substrate.

use crate::util::json::Json;

/// Which KV-cache sharing policy the engine runs (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// ForkKV: shared bCache + per-adapter CoW rCache (the paper).
    Disaggregated,
    /// vLLM/SGLang-style prefix caching: monolithic KV keyed by
    /// (adapter, tokens) — lossless, memory-hungry.
    UnifiedPerAdapter,
    /// Aggressive cross-adapter reuse of monolithic KV keyed by tokens
    /// only — memory-cheap, lossy (paper §7.1 "Full Reuse").
    FullReuse,
}

impl CachePolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "forkkv" | "disaggregated" => CachePolicy::Disaggregated,
            "prefix" | "unified" | "unified-per-adapter" => CachePolicy::UnifiedPerAdapter,
            "full-reuse" | "fullreuse" => CachePolicy::FullReuse,
            other => anyhow::bail!("unknown cache policy {other:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Disaggregated => "forkkv",
            CachePolicy::UnifiedPerAdapter => "prefix",
            CachePolicy::FullReuse => "full-reuse",
        }
    }
    /// Does this policy maintain a residual pool at all?
    pub fn uses_residual(&self) -> bool {
        matches!(self, CachePolicy::Disaggregated)
    }
}

#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// tokens per page (allocator + radix granularity)
    pub page_tokens: usize,
    /// total byte budget for KV state, split between the pools; this is
    /// the experiment's "GPU memory" knob that creates contention
    pub budget_bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            page_tokens: 16,
            budget_bytes: 64 << 20,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// decode batch buckets available as AOT artifacts (ascending)
    pub decode_buckets: Vec<usize>,
    /// max sequences resident (prefill + decode) before queueing
    pub max_running: usize,
    /// evict this many pages extra when under pressure (hysteresis)
    pub evict_slack_pages: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            max_running: 64,
            evict_slack_pages: 4,
        }
    }
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: CachePolicy,
    pub cache: CacheConfig,
    pub sched: SchedulerConfig,
    pub seed: u64,
    /// sample greedily (real mode); sim mode always synthesizes tokens
    pub greedy: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig::default(),
            sched: SchedulerConfig::default(),
            seed: 0,
            greedy: true,
        }
    }
}

impl EngineConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = EngineConfig::default();
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            cfg.policy = CachePolicy::parse(p)?;
        }
        if let Some(c) = j.get("cache") {
            if let Some(v) = c.get("page_tokens").and_then(Json::as_usize) {
                cfg.cache.page_tokens = v;
            }
            if let Some(v) = c.get("budget_mb").and_then(Json::as_f64) {
                cfg.cache.budget_bytes = (v * 1048576.0) as usize;
            }
        }
        if let Some(s) = j.get("sched") {
            if let Some(v) = s.get("max_running").and_then(Json::as_usize) {
                cfg.sched.max_running = v;
            }
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn policy_parsing() {
        assert_eq!(CachePolicy::parse("forkkv").unwrap(), CachePolicy::Disaggregated);
        assert_eq!(CachePolicy::parse("prefix").unwrap(), CachePolicy::UnifiedPerAdapter);
        assert_eq!(CachePolicy::parse("full-reuse").unwrap(), CachePolicy::FullReuse);
        assert!(CachePolicy::parse("bogus").is_err());
    }

    #[test]
    fn engine_config_from_json() {
        let j = json::parse(
            r#"{"policy":"prefix","cache":{"page_tokens":8,"budget_mb":16},
                "sched":{"max_running":4},"seed":7}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.policy, CachePolicy::UnifiedPerAdapter);
        assert_eq!(cfg.cache.page_tokens, 8);
        assert_eq!(cfg.cache.budget_bytes, 16 << 20);
        assert_eq!(cfg.sched.max_running, 4);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = EngineConfig::default();
        assert!(cfg.policy.uses_residual());
        assert!(cfg.cache.budget_bytes > 1 << 20);
        assert_eq!(*cfg.sched.decode_buckets.last().unwrap(), 8);
    }
}
