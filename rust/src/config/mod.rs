//! Typed configuration for the serving engine, cache, scheduler and
//! workloads, loadable from JSON (`--config file.json`) with defaults that
//! match the paper's evaluation setup scaled to this substrate.

use crate::router::RoutePolicy;
use crate::util::json::Json;

/// Which KV-cache sharing policy the engine runs (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// ForkKV: shared bCache + per-adapter CoW rCache (the paper).
    Disaggregated,
    /// vLLM/SGLang-style prefix caching: monolithic KV keyed by
    /// (adapter, tokens) — lossless, memory-hungry.
    UnifiedPerAdapter,
    /// Aggressive cross-adapter reuse of monolithic KV keyed by tokens
    /// only — memory-cheap, lossy (paper §7.1 "Full Reuse").
    FullReuse,
}

impl CachePolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "forkkv" | "disaggregated" => CachePolicy::Disaggregated,
            "prefix" | "unified" | "unified-per-adapter" => CachePolicy::UnifiedPerAdapter,
            "full-reuse" | "fullreuse" => CachePolicy::FullReuse,
            other => anyhow::bail!("unknown cache policy {other:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::Disaggregated => "forkkv",
            CachePolicy::UnifiedPerAdapter => "prefix",
            CachePolicy::FullReuse => "full-reuse",
        }
    }
    /// Does this policy maintain a residual pool at all?
    pub fn uses_residual(&self) -> bool {
        matches!(self, CachePolicy::Disaggregated)
    }
}

#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// tokens per page (allocator + radix granularity)
    pub page_tokens: usize,
    /// total byte budget for KV state, split between the pools; this is
    /// the experiment's "GPU memory" knob that creates contention. The
    /// budget is *elastic* at runtime (`Engine::set_budget_bytes`): the
    /// pool rebalancer may lend a shard budget from its cold peers.
    pub budget_bytes: usize,
    /// physical pool sizing (bytes): the page tables and backing buffers
    /// are built for this many bytes even though allocation is enforced
    /// against the (possibly smaller, elastic) `budget_bytes`. The
    /// headroom is what lets a shard actually *spend* budget lent to it
    /// by the rebalancer. 0 = size the pools to `budget_bytes` exactly
    /// (no headroom — the single-engine default, where there is no peer
    /// to borrow from).
    pub capacity_bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            page_tokens: 16,
            budget_bytes: 64 << 20,
            capacity_bytes: 0,
        }
    }
}

/// Host-memory tier-2 page store knobs (the `tier` module): evicted
/// radix pages demote here instead of being destroyed, and a returning
/// session's fork promotes them back when tier bandwidth beats
/// recompute.
#[derive(Debug, Clone, Default)]
pub struct TierConfig {
    /// byte budget of the host-memory tier (`--tier-mb`); 0 disables the
    /// tier entirely — eviction destroys pages exactly as before
    pub tier_bytes: usize,
    /// fully calibrated cost model for the promote-vs-recompute decision
    /// (the CLI loads `calibration.json` into this); None = derive the
    /// FLOP terms from the model geometry and use the default tier
    /// bandwidth
    // analyze:allow(knob_drift) loaded from calibration.json, not a user knob
    pub cost: Option<crate::exec::CostModel>,
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// decode batch buckets available as AOT artifacts (ascending)
    pub decode_buckets: Vec<usize>,
    /// max sequences resident (prefill + decode) before queueing
    pub max_running: usize,
    /// evict this many pages extra when under pressure (hysteresis)
    pub evict_slack_pages: usize,
    /// workflow-aware (gang) scheduling: admit a workflow's queued fan
    /// together (tag-grouped, warm-prefix-first admission, bounded by
    /// `max_running`) and defer evicting pages a queued fork of the tag
    /// still needs. Tag 0 (the HTTP default) is *untagged* traffic: it
    /// forms no gang and takes no fan holds, so plain deployments keep
    /// plain FCFS. Off = FCFS admission and untagged LRU for everyone —
    /// the A/B baseline (`--gang off`).
    pub gang: bool,
    /// how long (ms, virtual engine time) admission holds a fork whose
    /// declared `fan` width has not fully arrived before releasing the
    /// partial fan; a hold never stalls an otherwise idle shard (the
    /// engine fast-forwards to the deadline)
    pub gang_hold_ms: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            decode_buckets: vec![1, 2, 4, 8],
            max_running: 64,
            evict_slack_pages: 4,
            gang: true,
            gang_hold_ms: 25,
        }
    }
}

/// HTTP front-end knobs: how many connections are serviced concurrently and
/// how the hand-rolled parser protects itself. The worker pool is what lets
/// many `/generate` calls be in flight at once so the engine shards form
/// real multi-sequence decode batches (the serial accept loop it replaces
/// collapsed continuous batching to batch-size-1).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// connection worker threads (each handles one HTTP request at a time)
    pub workers: usize,
    /// accepted connections queued ahead of the pool before accept blocks
    /// (bounded hand-off channel: natural backpressure under overload)
    pub accept_backlog: usize,
    /// reject request bodies larger than this with 413 (parser guard)
    pub max_body_bytes: usize,
    /// engine-thread wakeup interval while idle; the loop otherwise blocks
    /// on the command channel instead of spinning
    pub idle_wait_ms: u64,
    /// socket read/write timeout; a silent client can otherwise occupy a
    /// connection worker forever (0 = no timeout)
    pub io_timeout_ms: u64,
    /// engine shards behind the front-end; each shard owns an independent
    /// `Engine` (pools, trees, executor) with the byte budget split N ways
    pub shards: usize,
    /// how requests are placed onto shards (`affinity` co-locates shared
    /// prefixes; `round_robin` is the placement-oblivious baseline)
    pub route_policy: RoutePolicy,
    /// affinity spill threshold: a request spills off its home shard when
    /// the home's in-flight depth exceeds `imbalance_factor * (min_depth
    /// + 1)` across the pool (the +1 keeps a near-idle pool from spilling
    /// off a depth-1 home shard). Default 1.5 — lowered from 2.0 once
    /// cross-shard migration made a spill cost bandwidth instead of a
    /// full re-prefill (see the README's "Choosing `imbalance_factor`"
    /// A/B note)
    pub imbalance_factor: f64,
    /// cross-shard page migration on spill: probe the home shard for the
    /// spilled request's cached pages and copy them to the target shard
    /// when the cost model says bandwidth beats recompute
    pub migrate: bool,
    /// migrations allowed in flight at once (the bounded migration
    /// queue): past this, a spill proceeds without migration so page
    /// copies never back up the shard decode loops
    pub migration_max_inflight: usize,
    /// assumed shard-to-shard copy bandwidth (bytes/s) for the
    /// migrate-vs-recompute decision; overridden by calibration
    pub migration_bandwidth_bytes_per_s: f64,
    /// one-to-many hot-context replication (`--replicate on|off`): when a
    /// read-mostly prefix keeps spill-missing on the same shard, copy its
    /// shared base proactively and let the router prefer replica holders
    /// over cold spill targets (see the server module's replication
    /// section). Off by default — the migration/rebalance A/B gates
    /// measure the un-replicated pool; armed explicitly per run.
    pub replicate: bool,
    /// spill-misses a prefix must take on the *same* shard before that
    /// shard earns a replica (`--replicate-miss`); the first miss is
    /// served by plain point-to-point migration
    pub replicate_miss_threshold: u32,
    /// sliding-window length (events per prefix) for the read-mostly
    /// detector (`--replicate-window`)
    pub replicate_window: usize,
    /// fork events required inside the window before a prefix can be
    /// classified read-mostly (`--replicate-min-forks`)
    pub replicate_min_forks: usize,
    /// fully calibrated cost model for the migration decision (the CLI
    /// loads `calibration.json` into this); None = derive the FLOP terms
    /// from the model geometry and use `migration_bandwidth_bytes_per_s`
    // analyze:allow(knob_drift) loaded from calibration.json, not a user knob
    pub migration_cost: Option<crate::exec::CostModel>,
    /// elastic shard budgets: a pool supervisor periodically lends free
    /// byte budget from cold shards to hot ones (see the `rebalance`
    /// module). Armed only when the pool has more than one shard.
    pub rebalance: bool,
    /// how often (wall-clock ms) the supervisor reads every shard's
    /// budget pressure and moves budget
    pub rebalance_interval_ms: u64,
    /// how much of its static slice a shard may lend out, in [0, 1]:
    /// the lend floor is `slice * (1 - lend_max_frac)` (clamped to at
    /// least 1/8 of the slice), so no shard is ever starved
    pub lend_max_frac: f64,
    /// arm the engines' host-memory tier (`--tier on`): evicted pages
    /// demote into a per-shard tier store and promote back on a
    /// returning session's fork (see the `tier` module). The tier's
    /// byte budget comes from the engine config (`--tier-mb`).
    pub tier: bool,
    /// how often (wall-clock ms) the tier compaction supervisor asks
    /// every shard to drop dead tier records (`--tier-compact-ms`);
    /// 0 = never — compaction then runs only inline under tier insert
    /// pressure
    pub tier_compact_ms: u64,
    /// arm cross-step workflow prefetch (`--prefetch on|off`): when a
    /// registered DAG's step has its predecessors running, the server
    /// pre-warms the step's known prefix on its home shard under a
    /// lease (see the server module's prefetch section)
    pub prefetch: bool,
    /// how many steps past the decoding frontier the horizon warms
    /// (`--prefetch-horizon`); 1 = only steps whose predecessors have
    /// all arrived
    pub prefetch_horizon: usize,
    /// lease abandonment timeout (`--prefetch-abandon-ms`): a warmed
    /// step that has not arrived after this many wall-clock ms gets its
    /// lease released and its pages counted as `prefetch_wasted`
    pub prefetch_abandon_ms: u64,
    /// how often the `forkkv-prefetch` supervisor retries unwarmed
    /// steps and checks abandonment; 0 parks the supervisor (tests
    /// drive `prefetch_tick` by hand)
    pub prefetch_tick_ms: u64,
    /// durable request journal (`--journal on|off`): every accepted
    /// submission appends a record, every terminal outcome retires it,
    /// and a dead shard's unretired records replay on live peers
    /// exactly once (see the `journal` module)
    pub journal: bool,
    /// directory holding the journal segments and per-shard checkpoint
    /// files (`--journal-dir`)
    pub journal_dir: String,
    /// group-commit window (`--journal-sync-ms`): appended records
    /// buffer for up to this many wall-clock ms before one fsync covers
    /// them all; 0 = strict per-append sync (slowest, zero-loss)
    pub journal_sync_ms: u64,
    /// group-commit byte threshold (`--journal-sync-bytes`): the buffer
    /// also flushes as soon as it holds this many bytes, whichever of
    /// the two thresholds trips first
    pub journal_sync_bytes: usize,
    /// journal segment rotation size (`--journal-seg-bytes`): a segment
    /// past this many bytes is sealed and a fresh one opened, so GC can
    /// delete fully-retired segments instead of rewriting one huge file
    pub journal_segment_bytes: usize,
    /// how often (wall-clock ms) the `forkkv-checkpoint` supervisor
    /// writes each shard's radix-metadata checkpoint for warm restarts
    /// (`--checkpoint-ms`); 0 = shutdown-only checkpoints
    pub checkpoint_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 16,
            accept_backlog: 64,
            max_body_bytes: 1 << 20,
            idle_wait_ms: 50,
            io_timeout_ms: 30_000,
            shards: 1,
            route_policy: RoutePolicy::Affinity,
            imbalance_factor: 1.5,
            migrate: true,
            migration_max_inflight: 4,
            migration_bandwidth_bytes_per_s: crate::exec::DEFAULT_MIGRATION_BANDWIDTH,
            migration_cost: None,
            replicate: false,
            replicate_miss_threshold: 2,
            replicate_window: 32,
            replicate_min_forks: 4,
            rebalance: true,
            rebalance_interval_ms: 50,
            lend_max_frac: 0.5,
            tier: false,
            tier_compact_ms: 250,
            prefetch: true,
            prefetch_horizon: 1,
            prefetch_abandon_ms: 1000,
            prefetch_tick_ms: 25,
            journal: false,
            journal_dir: "journal".to_string(),
            journal_sync_ms: 5,
            journal_sync_bytes: 64 << 10,
            journal_segment_bytes: 1 << 20,
            checkpoint_ms: 1000,
        }
    }
}

impl ServerConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = ServerConfig::default();
        if let Some(v) = j.get("workers").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.workers must be > 0");
            cfg.workers = v;
        }
        if let Some(v) = j.get("accept_backlog").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.accept_backlog must be > 0");
            cfg.accept_backlog = v;
        }
        if let Some(v) = j.get("max_body_bytes").and_then(Json::as_usize) {
            cfg.max_body_bytes = v;
        }
        if let Some(v) = j.get("idle_wait_ms").and_then(Json::as_usize) {
            cfg.idle_wait_ms = v as u64;
        }
        if let Some(v) = j.get("io_timeout_ms").and_then(Json::as_usize) {
            cfg.io_timeout_ms = v as u64;
        }
        if let Some(v) = j.get("shards").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.shards must be > 0");
            cfg.shards = v;
        }
        if let Some(v) = j.get("route").and_then(Json::as_str) {
            cfg.route_policy = RoutePolicy::parse(v)?;
        }
        if let Some(v) = j.get("imbalance_factor").and_then(Json::as_f64) {
            anyhow::ensure!(v >= 1.0, "server.imbalance_factor must be >= 1.0");
            cfg.imbalance_factor = v;
        }
        if let Some(v) = j.get("migrate").and_then(Json::as_bool) {
            cfg.migrate = v;
        }
        if let Some(v) = j.get("migration_max_inflight").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.migration_max_inflight must be > 0");
            cfg.migration_max_inflight = v;
        }
        if let Some(v) = j
            .get("migration_bandwidth_bytes_per_s")
            .and_then(Json::as_f64)
        {
            anyhow::ensure!(
                v > 0.0,
                "server.migration_bandwidth_bytes_per_s must be > 0"
            );
            cfg.migration_bandwidth_bytes_per_s = v;
        }
        if let Some(v) = j.get("replicate").and_then(Json::as_bool) {
            cfg.replicate = v;
        }
        if let Some(v) = j.get("replicate_miss_threshold").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.replicate_miss_threshold must be > 0");
            cfg.replicate_miss_threshold = v as u32;
        }
        if let Some(v) = j.get("replicate_window").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.replicate_window must be > 0");
            cfg.replicate_window = v;
        }
        if let Some(v) = j.get("replicate_min_forks").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.replicate_min_forks must be > 0");
            cfg.replicate_min_forks = v;
        }
        if let Some(v) = j.get("rebalance").and_then(Json::as_bool) {
            cfg.rebalance = v;
        }
        if let Some(v) = j.get("rebalance_interval_ms").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.rebalance_interval_ms must be > 0");
            cfg.rebalance_interval_ms = v as u64;
        }
        if let Some(v) = j.get("lend_max_frac").and_then(Json::as_f64) {
            anyhow::ensure!(
                (0.0..=1.0).contains(&v),
                "server.lend_max_frac must be in [0, 1]"
            );
            cfg.lend_max_frac = v;
        }
        if let Some(v) = j.get("tier").and_then(Json::as_bool) {
            cfg.tier = v;
        }
        if let Some(v) = j.get("tier_compact_ms").and_then(Json::as_usize) {
            cfg.tier_compact_ms = v as u64;
        }
        if let Some(v) = j.get("prefetch").and_then(Json::as_bool) {
            cfg.prefetch = v;
        }
        if let Some(v) = j.get("prefetch_horizon").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.prefetch_horizon must be > 0");
            cfg.prefetch_horizon = v;
        }
        if let Some(v) = j.get("prefetch_abandon_ms").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.prefetch_abandon_ms must be > 0");
            cfg.prefetch_abandon_ms = v as u64;
        }
        if let Some(v) = j.get("prefetch_tick_ms").and_then(Json::as_usize) {
            cfg.prefetch_tick_ms = v as u64;
        }
        if let Some(v) = j.get("journal").and_then(Json::as_bool) {
            cfg.journal = v;
        }
        if let Some(v) = j.get("journal_dir").and_then(Json::as_str) {
            anyhow::ensure!(!v.is_empty(), "server.journal_dir must be non-empty");
            cfg.journal_dir = v.to_string();
        }
        if let Some(v) = j.get("journal_sync_ms").and_then(Json::as_usize) {
            cfg.journal_sync_ms = v as u64;
        }
        if let Some(v) = j.get("journal_sync_bytes").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.journal_sync_bytes must be > 0");
            cfg.journal_sync_bytes = v;
        }
        if let Some(v) = j.get("journal_segment_bytes").and_then(Json::as_usize) {
            anyhow::ensure!(v > 0, "server.journal_segment_bytes must be > 0");
            cfg.journal_segment_bytes = v;
        }
        if let Some(v) = j.get("checkpoint_ms").and_then(Json::as_usize) {
            cfg.checkpoint_ms = v as u64;
        }
        Ok(cfg)
    }
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: CachePolicy,
    pub cache: CacheConfig,
    pub sched: SchedulerConfig,
    /// host-memory tier-2 page store (off unless `tier_bytes > 0`)
    pub tier: TierConfig,
    pub seed: u64,
    /// sample greedily (real mode); sim mode always synthesizes tokens
    // analyze:allow(knob_drift) fixed by the entry point, not a served knob
    pub greedy: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig::default(),
            sched: SchedulerConfig::default(),
            tier: TierConfig::default(),
            seed: 0,
            greedy: true,
        }
    }
}

impl EngineConfig {
    /// The per-shard slice of this configuration for a pool of `shards`
    /// engines: the byte budget and residency cap are split N ways (the
    /// pool as a whole spends one "GPU memory" budget) and the seed is
    /// decorrelated per shard so peer engines don't sample in lockstep.
    ///
    /// The split is **exact**: shard `i` takes `total / N` plus one unit
    /// of the remainder for `i < total % N`, so the slices sum to the
    /// configured totals (the old plain `/ N` silently dropped up to
    /// `N - 1` bytes/slots pool-wide — and the budget conservation the
    /// elastic rebalancer asserts starts from this sum).
    ///
    /// Each slice is also given physical pool **headroom** so a shard
    /// that borrows budget from cold peers can actually allocate pages
    /// against it: an explicitly configured pool `capacity_bytes`
    /// (`cache.capacity_mb`) is split N ways like the budget, otherwise
    /// each slice defaults to 2x its budget (never beyond the whole
    /// pool's budget).
    ///
    /// Degenerate splits (a total smaller than the shard count) floor
    /// every slice at 1 and therefore overshoot the sum — a pool with
    /// fewer budget bytes or running slots than shards is a
    /// misconfiguration, kept non-zero only so each engine stays
    /// constructible.
    pub fn shard_slice(&self, shard: usize, shards: usize) -> EngineConfig {
        assert!(shards > 0, "shard pool must be non-empty");
        assert!(shard < shards, "shard index {shard} out of range {shards}");
        let mut cfg = self.clone();
        let budget = self.cache.budget_bytes;
        let slice = (budget / shards + usize::from(shard < budget % shards)).max(1);
        cfg.cache.budget_bytes = slice;
        let cap = self.cache.capacity_bytes;
        cfg.cache.capacity_bytes = if cap > 0 {
            (cap / shards + usize::from(shard < cap % shards)).max(slice)
        } else {
            slice.saturating_mul(2).min(budget.max(slice))
        };
        let mr = self.sched.max_running;
        cfg.sched.max_running = (mr / shards + usize::from(shard < mr % shards)).max(1);
        // the host-memory tier budget is one pool-wide knob too, split
        // exactly — but 0 means "tier off" and must stay 0 (no floor)
        let tb = self.tier.tier_bytes;
        if tb > 0 {
            cfg.tier.tier_bytes = (tb / shards + usize::from(shard < tb % shards)).max(1);
        }
        cfg.seed = self
            .seed
            .wrapping_add((shard as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        cfg
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = EngineConfig::default();
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            cfg.policy = CachePolicy::parse(p)?;
        }
        if let Some(c) = j.get("cache") {
            if let Some(v) = c.get("page_tokens").and_then(Json::as_usize) {
                cfg.cache.page_tokens = v;
            }
            if let Some(v) = c.get("budget_mb").and_then(Json::as_f64) {
                cfg.cache.budget_bytes = (v * 1048576.0) as usize;
            }
            if let Some(v) = c.get("capacity_mb").and_then(Json::as_f64) {
                cfg.cache.capacity_bytes = (v * 1048576.0) as usize;
            }
        }
        if let Some(s) = j.get("sched") {
            if let Some(v) = s.get("max_running").and_then(Json::as_usize) {
                cfg.sched.max_running = v;
            }
            if let Some(v) = s.get("gang").and_then(Json::as_bool) {
                cfg.sched.gang = v;
            }
            if let Some(v) = s.get("gang_hold_ms").and_then(Json::as_usize) {
                cfg.sched.gang_hold_ms = v as u64;
            }
        }
        if let Some(t) = j.get("tier") {
            if let Some(v) = t.get("tier_mb").and_then(Json::as_f64) {
                cfg.tier.tier_bytes = (v * 1048576.0) as usize;
            }
        }
        if let Some(v) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = v as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn policy_parsing() {
        assert_eq!(CachePolicy::parse("forkkv").unwrap(), CachePolicy::Disaggregated);
        assert_eq!(CachePolicy::parse("prefix").unwrap(), CachePolicy::UnifiedPerAdapter);
        assert_eq!(CachePolicy::parse("full-reuse").unwrap(), CachePolicy::FullReuse);
        assert!(CachePolicy::parse("bogus").is_err());
    }

    #[test]
    fn engine_config_from_json() {
        let j = json::parse(
            r#"{"policy":"prefix","cache":{"page_tokens":8,"budget_mb":16},
                "sched":{"max_running":4,"gang":false,"gang_hold_ms":7},
                "tier":{"tier_mb":32},"seed":7}"#,
        )
        .unwrap();
        let cfg = EngineConfig::from_json(&j).unwrap();
        assert_eq!(cfg.policy, CachePolicy::UnifiedPerAdapter);
        assert_eq!(cfg.cache.page_tokens, 8);
        assert_eq!(cfg.cache.budget_bytes, 16 << 20);
        assert_eq!(cfg.sched.max_running, 4);
        assert!(!cfg.sched.gang);
        assert_eq!(cfg.sched.gang_hold_ms, 7);
        assert_eq!(cfg.tier.tier_bytes, 32 << 20);
        assert_eq!(cfg.seed, 7);
        // absent sched knobs keep the gang defaults (on, 25 ms hold);
        // the tier defaults off (0 bytes)
        let d = EngineConfig::from_json(&json::parse("{}").unwrap()).unwrap();
        assert!(d.sched.gang, "gang scheduling defaults on");
        assert_eq!(d.sched.gang_hold_ms, 25);
        assert_eq!(d.tier.tier_bytes, 0, "tier defaults off");
    }

    #[test]
    fn server_config_from_json() {
        let j = json::parse(
            r#"{"workers":4,"accept_backlog":8,"max_body_bytes":4096,
                "idle_wait_ms":5,"io_timeout_ms":1000,"shards":4,
                "route":"round_robin","imbalance_factor":3.5,
                "migrate":false,"migration_max_inflight":2,
                "migration_bandwidth_bytes_per_s":1e9,
                "replicate":true,"replicate_miss_threshold":3,
                "replicate_window":16,"replicate_min_forks":2,
                "rebalance":false,"rebalance_interval_ms":20,
                "lend_max_frac":0.25,"tier":true,"tier_compact_ms":40,
                "prefetch":false,"prefetch_horizon":2,
                "prefetch_abandon_ms":300,"prefetch_tick_ms":0}"#,
        )
        .unwrap();
        let cfg = ServerConfig::from_json(&j).unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.accept_backlog, 8);
        assert_eq!(cfg.max_body_bytes, 4096);
        assert_eq!(cfg.idle_wait_ms, 5);
        assert_eq!(cfg.io_timeout_ms, 1000);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.route_policy, RoutePolicy::RoundRobin);
        assert!((cfg.imbalance_factor - 3.5).abs() < 1e-9);
        assert!(!cfg.migrate);
        assert_eq!(cfg.migration_max_inflight, 2);
        assert!((cfg.migration_bandwidth_bytes_per_s - 1e9).abs() < 1.0);
        assert!(cfg.replicate);
        assert_eq!(cfg.replicate_miss_threshold, 3);
        assert_eq!(cfg.replicate_window, 16);
        assert_eq!(cfg.replicate_min_forks, 2);
        assert!(!cfg.rebalance);
        assert_eq!(cfg.rebalance_interval_ms, 20);
        assert!((cfg.lend_max_frac - 0.25).abs() < 1e-9);
        assert!(cfg.tier);
        assert_eq!(cfg.tier_compact_ms, 40);
        assert!(!cfg.prefetch);
        assert_eq!(cfg.prefetch_horizon, 2);
        assert_eq!(cfg.prefetch_abandon_ms, 300);
        assert_eq!(cfg.prefetch_tick_ms, 0, "0 parks the supervisor");
        // zero workers / zero shards / sub-1 imbalance are rejected,
        // absent fields keep defaults
        assert!(ServerConfig::from_json(&json::parse(r#"{"workers":0}"#).unwrap()).is_err());
        assert!(ServerConfig::from_json(&json::parse(r#"{"shards":0}"#).unwrap()).is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"imbalance_factor":0.5}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"migration_max_inflight":0}"#).unwrap()
        )
        .is_err());
        // a lend fraction outside [0, 1] is rejected
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"lend_max_frac":1.5}"#).unwrap()
        )
        .is_err());
        // degenerate replication knobs are rejected (use "replicate":
        // false to disable, not a zero threshold/window)
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"replicate_miss_threshold":0}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"replicate_window":0}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"replicate_min_forks":0}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"rebalance_interval_ms":0}"#).unwrap()
        )
        .is_err());
        // a zero horizon or abandonment window would disable prefetch
        // silently — rejected (use "prefetch": false instead)
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"prefetch_horizon":0}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"prefetch_abandon_ms":0}"#).unwrap()
        )
        .is_err());
        let d = ServerConfig::from_json(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.workers, ServerConfig::default().workers);
        assert_eq!(d.max_body_bytes, 1 << 20);
        assert_eq!(d.shards, 1);
        assert_eq!(d.route_policy, RoutePolicy::Affinity);
        // lowered from 2.0 when migration made spills cheap (README A/B)
        assert!((d.imbalance_factor - 1.5).abs() < 1e-9);
        assert!(d.migrate, "migration defaults on");
        assert_eq!(d.migration_max_inflight, 4);
        assert!(!d.replicate, "replication defaults off (armed per run)");
        assert_eq!(d.replicate_miss_threshold, 2);
        assert_eq!(d.replicate_window, 32);
        assert_eq!(d.replicate_min_forks, 4);
        assert!(d.rebalance, "elastic budgets default on");
        assert_eq!(d.rebalance_interval_ms, 50);
        assert!((d.lend_max_frac - 0.5).abs() < 1e-9);
        assert!(!d.tier, "tier defaults off");
        assert_eq!(d.tier_compact_ms, 250);
        assert!(d.prefetch, "cross-step prefetch defaults on");
        assert_eq!(d.prefetch_horizon, 1);
        assert_eq!(d.prefetch_abandon_ms, 1000);
        assert_eq!(d.prefetch_tick_ms, 25);
        assert!(!d.journal, "journal defaults off");
        assert_eq!(d.journal_dir, "journal");
        assert_eq!(d.journal_sync_ms, 5);
        assert_eq!(d.journal_sync_bytes, 64 << 10);
        assert_eq!(d.journal_segment_bytes, 1 << 20);
        assert_eq!(d.checkpoint_ms, 1000);
        // journal knobs parse, and degenerate values are rejected
        let jj = json::parse(
            r#"{"journal":true,"journal_dir":"wal","journal_sync_ms":0,
                "journal_sync_bytes":4096,"journal_segment_bytes":65536,
                "checkpoint_ms":0}"#,
        )
        .unwrap();
        let jc = ServerConfig::from_json(&jj).unwrap();
        assert!(jc.journal);
        assert_eq!(jc.journal_dir, "wal");
        assert_eq!(jc.journal_sync_ms, 0, "0 = strict per-append sync");
        assert_eq!(jc.journal_sync_bytes, 4096);
        assert_eq!(jc.journal_segment_bytes, 65536);
        assert_eq!(jc.checkpoint_ms, 0, "0 = shutdown-only checkpoints");
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"journal_dir":""}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"journal_sync_bytes":0}"#).unwrap()
        )
        .is_err());
        assert!(ServerConfig::from_json(
            &json::parse(r#"{"journal_segment_bytes":0}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn shard_slice_splits_budget_and_decorrelates_seeds() {
        let cfg = EngineConfig {
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: 64 << 20,
                capacity_bytes: 0,
            },
            seed: 42,
            ..EngineConfig::default()
        };
        let a = cfg.shard_slice(0, 4);
        let b = cfg.shard_slice(3, 4);
        assert_eq!(a.cache.budget_bytes, 16 << 20);
        assert_eq!(b.cache.budget_bytes, 16 << 20);
        assert_eq!(a.sched.max_running, cfg.sched.max_running / 4);
        assert_ne!(a.seed, b.seed);
        assert_ne!(a.seed, cfg.seed);
        // every slice has physical headroom (2x) for borrowed budget,
        // never beyond the whole pool's budget
        assert_eq!(a.cache.capacity_bytes, 32 << 20);
        // degenerate single-shard slice is the whole budget, no headroom
        // (there is no peer to borrow from)
        let whole = cfg.shard_slice(0, 1);
        assert_eq!(whole.cache.budget_bytes, 64 << 20);
        assert_eq!(whole.cache.capacity_bytes, 64 << 20);
        // an explicit pool capacity is split N ways instead of the 2x
        // default (the `cache.capacity_mb` knob survives sharding)
        let explicit = EngineConfig {
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: 64 << 20,
                capacity_bytes: 256 << 20,
            },
            ..EngineConfig::default()
        };
        assert_eq!(explicit.shard_slice(0, 4).cache.capacity_bytes, 64 << 20);
    }

    #[test]
    fn shard_slice_distributes_remainders_exactly() {
        // the old `/ N` silently dropped up to N-1 bytes (and running
        // slots) pool-wide; the slices must now sum to the configured
        // totals for awkward shard counts
        for shards in [1usize, 2, 3, 5, 7, 12] {
            for budget in [64 << 20, (64 << 20) + 1, 10_000_019, 1 << 20] {
                let cfg = EngineConfig {
                    cache: CacheConfig {
                        page_tokens: 16,
                        budget_bytes: budget,
                        capacity_bytes: 0,
                    },
                    ..EngineConfig::default()
                };
                let slices: Vec<EngineConfig> =
                    (0..shards).map(|i| cfg.shard_slice(i, shards)).collect();
                let budget_sum: usize =
                    slices.iter().map(|s| s.cache.budget_bytes).sum();
                assert_eq!(
                    budget_sum, budget,
                    "budget sum for {budget} over {shards} shards"
                );
                let running_sum: usize =
                    slices.iter().map(|s| s.sched.max_running).sum();
                assert_eq!(
                    running_sum, cfg.sched.max_running,
                    "max_running sum over {shards} shards"
                );
                // the remainder lands on the low shards, one unit each —
                // slices never differ by more than one
                let min = slices.iter().map(|s| s.cache.budget_bytes).min().unwrap();
                let max = slices.iter().map(|s| s.cache.budget_bytes).max().unwrap();
                assert!(max - min <= 1, "uneven split: {min}..{max}");
                for s in &slices {
                    assert!(s.cache.capacity_bytes >= s.cache.budget_bytes);
                    assert!(s.cache.capacity_bytes <= budget);
                }
            }
        }
        // the tier budget splits exactly too — and a disabled tier (0
        // bytes) stays disabled on every shard (no 1-byte floor)
        let tiered = EngineConfig {
            tier: TierConfig { tier_bytes: 10_000_019, cost: None },
            ..EngineConfig::default()
        };
        let sum: usize = (0..7)
            .map(|i| tiered.shard_slice(i, 7).tier.tier_bytes)
            .sum();
        assert_eq!(sum, 10_000_019, "tier budget split must be exact");
        let off = EngineConfig::default();
        for i in 0..4 {
            assert_eq!(off.shard_slice(i, 4).tier.tier_bytes, 0, "tier off stays off");
        }
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = EngineConfig::default();
        assert!(cfg.policy.uses_residual());
        assert!(cfg.cache.budget_bytes > 1 << 20);
        assert_eq!(*cfg.sched.decode_buckets.last().unwrap(), 8);
    }
}
