//! Deterministic PRNG + the distributions the workload generators need.
//!
//! xoshiro256** seeded via SplitMix64 — fast, well-distributed, and fully
//! reproducible across runs (every experiment records its seed).

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-request generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times of a Poisson
    /// process at `rate` events per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Poisson-distributed count (Knuth's method; fine for lambda <~ 30,
    /// normal approximation above that).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample a token id in [2, vocab) (0/1 reserved for pad/eos).
    pub fn token(&mut self, vocab: usize) -> u32 {
        2 + self.below(vocab - 2) as u32
    }

    pub fn tokens(&mut self, n: usize, vocab: usize) -> Vec<u32> {
        (0..n).map(|_| self.token(vocab)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut rng = Rng::seeded(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut rng = Rng::seeded(2);
        for &lam in &[0.5, 3.0, 12.0, 50.0] {
            let n = 5000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lam).abs() < lam.max(1.0) * 0.15,
                "lambda={lam} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seeded(3);
        let rate = 2.0;
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seeded(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn tokens_in_range() {
        let mut rng = Rng::seeded(5);
        for t in rng.tokens(1000, 128) {
            assert!((2..128).contains(&(t as usize)));
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::seeded(6);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
