//! Minimal property-based testing harness (proptest is unavailable in the
//! offline vendor set — DESIGN.md §3).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently-seeded
//! RNGs. On failure it panics with the failing case's seed so the exact
//! counterexample can be replayed with `check_one(seed, f)`. Shrinking is
//! intentionally out of scope: generators in this repo produce small cases
//! by construction.

use crate::util::rng::Rng;
use crate::util::{fnv1a_from, FNV_OFFSET};

pub const DEFAULT_CASES: usize = 64;

/// Run a randomized property. `f` returns Err(description) on violation.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is stable per property name so failures are reproducible
    // across runs, while distinct properties explore distinct streams.
    let base = fnv1a_from(FNV_OFFSET, name.bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property {name:?} failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_one<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::seeded(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64-roundtrip", 32, |rng| {
            let x = rng.next_u64();
            prop_assert!(x == x, "reflexivity");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failures_with_seed() {
        check("always-fails", 4, |_rng| Err("boom".to_string()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("determinism", 8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("determinism", 8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
