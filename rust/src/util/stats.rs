//! Summary statistics for metric reporting (mean / percentiles / counters).

#[derive(Debug, Clone, Default)]
pub struct Series {
    values: Vec<f64>,
    sorted: bool,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    /// Merge another series' samples (per-thread collection, then combine).
    pub fn extend_from(&mut self, other: &Series) {
        self.values.extend_from_slice(&other.values);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.sum() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by nearest-rank on the sorted data; `q` in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let rank = ((q / 100.0) * (self.values.len() as f64 - 1.0)).round() as usize;
        self.values[rank.min(self.values.len() - 1)]
    }

    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            min: if self.is_empty() { 0.0 } else { self.min() },
            p50: self.percentile(50.0),
            p90: self.percentile(90.0),
            p99: self.percentile(99.0),
            max: if self.is_empty() { 0.0 } else { self.max() },
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("mean", Json::num(self.mean)),
            ("min", Json::num(self.min)),
            ("p50", Json::num(self.p50)),
            ("p90", Json::num(self.p90)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = Series::new();
        for i in (1..=100).rev() {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 51.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn extend_from_merges() {
        let mut a = Series::new();
        a.push(1.0);
        let mut b = Series::new();
        b.push(3.0);
        a.extend_from(&b);
        assert_eq!(a.len(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_safe() {
        let mut s = Series::new();
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.summary().n, 0);
    }
}
