//! Deterministic hash tokenizer for the examples and the HTTP server.
//!
//! The sim models have a small vocab with untrained embeddings, so any
//! injective-enough text->id mapping exercises the serving stack
//! identically to a real BPE tokenizer: equal text spans map to equal
//! token-id spans (which is the property prefix caching depends on).

use crate::util::{fnv1a_from, FNV_OFFSET};

/// Reserved ids: 0 = pad, 1 = eos.
pub const PAD: u32 = 0;
pub const EOS: u32 = 1;

#[derive(Debug, Clone)]
pub struct HashTokenizer {
    vocab: usize,
}

impl HashTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > 2);
        Self { vocab }
    }

    /// Whitespace-split words, each hashed into [2, vocab).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| 2 + (fnv1a_from(FNV_OFFSET, w.bytes()) % (self.vocab as u64 - 2)) as u32)
            .collect()
    }

    /// Tokens back to a printable pseudo-text (ids, since hashing is lossy).
    pub fn decode(&self, tokens: &[u32]) -> String {
        tokens
            .iter()
            .map(|t| match *t {
                PAD => "<pad>".to_string(),
                EOS => "<eos>".to_string(),
                t => format!("t{t}"),
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_text_equal_tokens() {
        let t = HashTokenizer::new(2048);
        let a = t.encode("the quick brown fox");
        let b = t.encode("the quick brown fox");
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn shared_prefix_property() {
        let t = HashTokenizer::new(2048);
        let a = t.encode("shared context part one QUESTION alpha");
        let b = t.encode("shared context part one QUESTION beta");
        assert_eq!(a[..5], b[..5]);
        assert_ne!(a[5], b[5]);
    }

    #[test]
    fn ids_in_range() {
        let t = HashTokenizer::new(64);
        for tok in t.encode("a b c d e f g h i j k l") {
            assert!((2..64).contains(&(tok as usize)));
        }
    }
}
