//! Sampled lock-contention counters for the serving hot path.
//!
//! The submit path crosses a handful of shared locks (shard sender,
//! journal, dedup map, DAG registry). Each gets a [`LockStat`]: the
//! uncontended fast path costs one relaxed atomic increment plus a
//! `try_lock`, and only *contended* acquisitions are timed — so the
//! counters are cheap enough to stay on in production benches, and the
//! `lock_wait_us` they report makes "the journal adds no measurable
//! submit overhead" an auditable claim instead of a hope (the
//! wrongodb-style lock-stats-in-bench-artifacts discipline).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::util::json::Json;

/// Contention counters for one named lock. Timing is *sampled*: only
/// acquisitions that actually blocked (`try_lock` failed) pay an
/// `Instant` pair, so `wait_us` is the total time spent blocked, not
/// total hold time.
#[derive(Debug)]
pub struct LockStat {
    name: &'static str,
    acquisitions: AtomicU64,
    contended: AtomicU64,
    wait_us: AtomicU64,
}

impl LockStat {
    /// Fresh zeroed counters for the lock called `name`.
    pub fn new(name: &'static str) -> Self {
        LockStat {
            name,
            acquisitions: AtomicU64::new(0),
            contended: AtomicU64::new(0),
            wait_us: AtomicU64::new(0),
        }
    }

    /// The lock's report name.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Lifetime acquisition count.
    pub fn acquisitions(&self) -> u64 {
        self.acquisitions.load(Ordering::Relaxed)
    }

    /// Acquisitions that found the lock held and had to block.
    pub fn contended(&self) -> u64 {
        self.contended.load(Ordering::Relaxed)
    }

    /// Total microseconds spent blocked (contended acquisitions only).
    pub fn wait_us(&self) -> u64 {
        self.wait_us.load(Ordering::Relaxed)
    }

    fn blocked(&self, start: std::time::Instant) {
        self.contended.fetch_add(1, Ordering::Relaxed);
        self.wait_us
            .fetch_add(start.elapsed().as_micros() as u64, Ordering::Relaxed);
    }

    /// Acquire `m`, counting the acquisition and timing it only if the
    /// uncontended `try_lock` fast path misses.
    pub fn lock<'a, T>(&self, m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        if let Ok(g) = m.try_lock() {
            return g;
        }
        let start = std::time::Instant::now();
        let g = m.lock().expect("lock poisoned");
        self.blocked(start);
        g
    }

    /// Shared-acquire `l` with the same sampled-timing discipline.
    pub fn read<'a, T>(&self, l: &'a RwLock<T>) -> RwLockReadGuard<'a, T> {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        if let Ok(g) = l.try_read() {
            return g;
        }
        let start = std::time::Instant::now();
        let g = l.read().expect("lock poisoned");
        self.blocked(start);
        g
    }

    /// Exclusive-acquire `l` with the same sampled-timing discipline.
    pub fn write<'a, T>(&self, l: &'a RwLock<T>) -> RwLockWriteGuard<'a, T> {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        if let Ok(g) = l.try_write() {
            return g;
        }
        let start = std::time::Instant::now();
        let g = l.write().expect("lock poisoned");
        self.blocked(start);
        g
    }

    /// `{acquisitions, contended, lock_wait_us}` snapshot.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lock_acquisitions", Json::num(self.acquisitions() as f64)),
            ("lock_contended", Json::num(self.contended() as f64)),
            ("lock_wait_us", Json::num(self.wait_us() as f64)),
        ])
    }
}

/// Render a set of lock stats as one `{name: {...}}` object (the
/// `/metrics` `locks` section and the bench-report `locks` block).
pub fn locks_json(stats: &[&LockStat]) -> Json {
    Json::obj(
        stats
            .iter()
            .map(|s| (s.name(), s.to_json()))
            .collect::<Vec<_>>(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn uncontended_lock_counts_without_timing() {
        let stat = LockStat::new("t");
        let m = Mutex::new(0u32);
        for _ in 0..5 {
            let mut g = stat.lock(&m);
            *g += 1;
        }
        assert_eq!(stat.acquisitions(), 5);
        assert_eq!(stat.contended(), 0);
        assert_eq!(stat.wait_us(), 0);
        assert_eq!(*m.lock().unwrap(), 5);
    }

    #[test]
    fn contended_lock_records_wait() {
        let stat = Arc::new(LockStat::new("t"));
        let m = Arc::new(Mutex::new(()));
        let held = m.lock().unwrap();
        let (stat2, m2) = (stat.clone(), m.clone());
        let h = std::thread::spawn(move || {
            let _g = stat2.lock(&m2);
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        drop(held);
        h.join().unwrap();
        assert_eq!(stat.acquisitions(), 1);
        assert_eq!(stat.contended(), 1);
        assert!(stat.wait_us() >= 1_000, "blocked ~20ms, saw {}", stat.wait_us());
    }

    #[test]
    fn rwlock_paths_count() {
        let stat = LockStat::new("rw");
        let l = RwLock::new(7u32);
        assert_eq!(*stat.read(&l), 7);
        *stat.write(&l) = 9;
        assert_eq!(*stat.read(&l), 9);
        assert_eq!(stat.acquisitions(), 3);
        let j = stat.to_json();
        assert_eq!(j.at(&["lock_acquisitions"]).as_usize().unwrap(), 3);
        assert_eq!(j.at(&["lock_contended"]).as_usize().unwrap(), 0);
    }

    #[test]
    fn locks_json_names_each_lock() {
        let a = LockStat::new("journal");
        let b = LockStat::new("dags");
        a.lock(&Mutex::new(()));
        let j = locks_json(&[&a, &b]);
        assert_eq!(j.at(&["journal", "lock_acquisitions"]).as_usize().unwrap(), 1);
        assert_eq!(j.at(&["dags", "lock_acquisitions"]).as_usize().unwrap(), 0);
    }
}
