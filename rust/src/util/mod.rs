//! In-repo substrates: the offline vendor set lacks serde / rand / criterion /
//! proptest, so the building blocks they would provide are implemented here
//! (DESIGN.md §3). Each is small, tested, and tailored to what the serving
//! stack actually needs.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tokenizer;
