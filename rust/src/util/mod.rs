//! In-repo substrates: the offline vendor set lacks serde / rand / criterion /
//! proptest, so the building blocks they would provide are implemented here
//! (DESIGN.md §3). Each is small, tested, and tailored to what the serving
//! stack actually needs.

pub mod json;
pub mod lockstats;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tokenizer;

/// Standard FNV-1a offset basis (the usual starting `state` for
/// [`fnv1a_from`]).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// FNV-1a over a byte stream from an arbitrary 64-bit starting state —
/// the one hash shared by the tokenizer, the property-test seeder and the
/// router's placement fingerprint (a seeded start folds extra identity,
/// e.g. a workflow tag, into the stream without a second pass).
pub fn fnv1a_from(state: u64, bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = state;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // standard FNV-1a test vectors (64-bit)
        assert_eq!(fnv1a_from(FNV_OFFSET, *b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_from(FNV_OFFSET, *b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_from(FNV_OFFSET, *b"foobar"), 0x85944171f73967e8);
        // seeding changes the stream, chaining composes
        assert_ne!(fnv1a_from(1, *b"x"), fnv1a_from(2, *b"x"));
        assert_eq!(
            fnv1a_from(fnv1a_from(FNV_OFFSET, *b"foo"), *b"bar"),
            fnv1a_from(FNV_OFFSET, *b"foobar")
        );
    }
}
