//! Minimal JSON: recursive-descent parser + writer.
//!
//! Used for `manifest.json` (the AOT contract with the python compile path),
//! config files, calibration data and metric reports. Supports the full JSON
//! grammar except surrogate-pair escapes beyond the BMP (not needed by any
//! producer in this repo; `\uXXXX` is handled).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for p in path {
            cur = cur.get(p).unwrap_or(&Json::Null);
        }
        cur
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid usize field {key:?}"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid f64 field {key:?}"))
    }
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid str field {key:?}"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field {key:?}"))
    }

    // ---------------- builders ----------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num<T: Into<f64>>(n: T) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------------- serialization ----------------
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact JSON serialization (`format!("{j}")` / `j.to_string()`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err(self.err("bad \\u escape"));
                        }
                        let hex =
                            std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].req_str("b").unwrap(), "c");
        assert_eq!(*v.at(&["d"]), Json::Null);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aµλ""#).unwrap();
        assert_eq!(v, Json::Str("Aµλ".into()));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"hi\"there","b":[true,false,null]}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"abc", "[1]x"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn fuzz_round_trip_via_writer() {
        // randomized structural fuzz: build random values, round-trip them
        use crate::util::rng::Rng;
        let mut rng = Rng::seeded(42);
        for _ in 0..200 {
            let v = random_value(&mut rng, 0);
            let text = v.to_string();
            let back = parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(v, back, "round trip failed for {text}");
        }
    }

    fn random_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        match rng.below(if depth > 3 { 4 } else { 6 }) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2000) as f64 - 1000.0) / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
}
