//! Durable request journal: a group-committed, segmented, checksummed
//! append-only log of accepted submissions and their terminal outcomes.
//!
//! Every accepted `POST /generate` appends a **Submit** record (its
//! idempotency key, target shard, and enough of the request — tokens,
//! adapter, tag, fan, max_new — to re-execute it), and every terminal
//! outcome appends an **Outcome** record. The in-memory mirror of
//! "submits without outcomes" is the replay worklist: when a shard dies,
//! the server claims that shard's open records and re-runs each on a
//! live peer exactly once (claiming is a mutex-guarded remove, so a
//! record can never be both replayed and completed twice).
//!
//! Disk layout follows the lfkv-db WAL exemplar: numbered segment files
//! (`seg-NNNNNN.wal`) of line records `<fnv1a64-hex> <body-json>\n`,
//! rotated at `segment_bytes` and garbage-collected as soon as every
//! submit in a sealed segment has its outcome. Appends **group-commit**:
//! they buffer in memory and hit the file when the buffer crosses
//! `sync_bytes` or the periodic `sync_ms` supervisor tick fires
//! (`sync_ms == 0` = strict sync on every append, the wrongodb
//! `wal_sync_interval_ms` semantics). Recovery tolerates a torn tail
//! (the last partially-written line is truncated away) and rejects
//! corrupt lines by checksum — everything after the first bad line in a
//! segment is dropped, never misparsed.

#![warn(missing_docs)]

use std::collections::{BTreeMap, HashMap};
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Json};
use crate::util::lockstats::LockStat;
use crate::util::{fnv1a_from, FNV_OFFSET};

/// One journaled submission: everything needed to re-execute the request
/// on a different shard if its original shard dies before replying.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRecord {
    /// idempotency key (client-supplied or server-generated): the
    /// identity under which duplicates dedup and replays claim
    pub key: String,
    /// shard the submission was accepted on (replay skips it)
    pub shard: usize,
    /// workflow tag carried into the re-executed request
    pub tag: u64,
    /// LoRA adapter id
    pub adapter: u32,
    /// decode budget
    pub max_new: usize,
    /// declared gang fan width
    pub fan: usize,
    /// the full prompt token stream
    pub tokens: Vec<u32>,
}

impl SubmitRecord {
    fn to_body(&self) -> String {
        Json::obj(vec![
            ("t", Json::str("s")),
            ("k", Json::str(self.key.clone())),
            ("sh", Json::num(self.shard as f64)),
            ("tag", Json::num(self.tag as f64)),
            ("ad", Json::num(self.adapter as f64)),
            ("mn", Json::num(self.max_new as f64)),
            ("fan", Json::num(self.fan as f64)),
            (
                "toks",
                Json::arr(self.tokens.iter().map(|&t| Json::num(t as f64))),
            ),
        ])
        .to_string()
    }

    fn from_json(j: &Json) -> Option<SubmitRecord> {
        Some(SubmitRecord {
            key: j.get("k")?.as_str()?.to_string(),
            shard: j.get("sh")?.as_usize()?,
            tag: j.get("tag")?.as_f64()? as u64,
            adapter: j.get("ad")?.as_usize()? as u32,
            max_new: j.get("mn")?.as_usize()?,
            fan: j.get("fan")?.as_usize()?,
            tokens: j
                .get("toks")?
                .as_arr()?
                .iter()
                .map(|t| t.as_usize().map(|v| v as u32))
                .collect::<Option<Vec<u32>>>()?,
        })
    }
}

/// Lifetime journal counters (the `/metrics` `journal` object's
/// durability half).
#[derive(Debug, Default, Clone)]
pub struct JournalStats {
    /// Submit records appended
    pub submits: u64,
    /// Outcome records appended
    pub outcomes: u64,
    /// buffered-append flushes (each one batch of records — the group
    /// in "group commit")
    pub group_commits: u64,
    /// bytes pushed to segment files across all flushes
    pub synced_bytes: u64,
    /// segment files opened over the journal's lifetime
    pub segments_created: u64,
    /// fully-outcomed sealed segments deleted by GC
    pub segments_gced: u64,
    /// torn-tail bytes truncated away during recovery
    pub truncated_bytes: u64,
    /// checksum-rejected lines dropped during recovery
    pub corrupt_lines: u64,
    /// outcome appends refused because the key had no live submit (a
    /// second outcome for an already-retired key, or an unknown key) —
    /// nonzero means some caller bypassed the `claim` gate
    pub duplicate_outcomes: u64,
}

struct Inner {
    file: fs::File,
    active_seg: u64,
    active_bytes: usize,
    buf: Vec<u8>,
    last_sync: Instant,
    /// submits without an outcome yet — the replay worklist. A claim
    /// (completion or replay) removes the entry; whoever removed it owns
    /// appending the one outcome record.
    pending: HashMap<String, SubmitRecord>,
    /// un-outcomed key -> segment holding its submit record (outlives a
    /// claim: cleared only by the outcome append, which drives GC)
    key_seg: HashMap<String, u64>,
    /// segment -> open (un-outcomed) submit count
    seg_open: BTreeMap<u64, u64>,
    stats: JournalStats,
}

/// The durable request journal (module docs). Shared by every server
/// worker; one mutex guards the buffered writer and the pending map —
/// contention on it is exported via [`Journal::lock_stat`].
pub struct Journal {
    dir: PathBuf,
    sync_ms: u64,
    sync_bytes: usize,
    seg_bytes: usize,
    inner: Mutex<Inner>,
    lock: LockStat,
}

fn seg_path(dir: &Path, idx: u64) -> PathBuf {
    dir.join(format!("seg-{idx:06}.wal"))
}

fn record_line(body: &str) -> String {
    let h = fnv1a_from(FNV_OFFSET, body.bytes());
    format!("{h:016x} {body}\n")
}

/// Parse one checksummed line into its body JSON; `None` = corrupt.
fn parse_line(line: &str) -> Option<Json> {
    let (hash, body) = line.split_once(' ')?;
    if hash.len() != 16 {
        return None;
    }
    let want = u64::from_str_radix(hash, 16).ok()?;
    if fnv1a_from(FNV_OFFSET, body.bytes()) != want {
        return None;
    }
    json::parse(body).ok()
}

impl Journal {
    /// Open (or create) the journal under `dir`, replaying existing
    /// segments to rebuild the pending map. Records that were submitted
    /// but never outcomed by the previous process remain pending — the
    /// server replays them as orphans at startup. The torn tail of the
    /// newest segment is truncated; checksum-corrupt lines and
    /// everything after them in their segment are dropped. Appends
    /// always go to a fresh segment; sealed segments left fully
    /// outcomed are deleted on the spot.
    pub fn open(
        dir: impl Into<PathBuf>,
        sync_ms: u64,
        sync_bytes: usize,
        seg_bytes: usize,
    ) -> anyhow::Result<Journal> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let mut stats = JournalStats::default();
        let mut pending: HashMap<String, SubmitRecord> = HashMap::new();
        let mut key_seg: HashMap<String, u64> = HashMap::new();
        let mut seg_open: BTreeMap<u64, u64> = BTreeMap::new();

        let mut segs: Vec<u64> = Vec::new();
        for entry in fs::read_dir(&dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(idx) = name
                .strip_prefix("seg-")
                .and_then(|s| s.strip_suffix(".wal"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                segs.push(idx);
            }
        }
        segs.sort_unstable();
        let last = segs.last().copied();
        for &idx in &segs {
            let path = seg_path(&dir, idx);
            let raw = fs::read(&path)?;
            let text = String::from_utf8_lossy(&raw);
            let mut valid_bytes = 0usize;
            for line in text.split_inclusive('\n') {
                let complete = line.ends_with('\n');
                let body = match parse_line(line.trim_end_matches('\n')) {
                    Some(b) if complete => b,
                    _ => {
                        stats.corrupt_lines += 1;
                        break; // nothing after a bad line is trusted
                    }
                };
                valid_bytes += line.len();
                match body.get("t").and_then(Json::as_str) {
                    Some("s") => {
                        if let Some(rec) = SubmitRecord::from_json(&body) {
                            *seg_open.entry(idx).or_insert(0) += 1;
                            key_seg.insert(rec.key.clone(), idx);
                            pending.insert(rec.key.clone(), rec);
                        }
                    }
                    Some("o") => {
                        if let Some(key) = body.get("k").and_then(Json::as_str) {
                            pending.remove(key);
                            if let Some(s) = key_seg.remove(key) {
                                if let Some(n) = seg_open.get_mut(&s) {
                                    *n = n.saturating_sub(1);
                                }
                            }
                        }
                    }
                    _ => {}
                }
            }
            if valid_bytes < raw.len() && Some(idx) == last {
                // torn tail on the newest segment: a crash mid-append.
                // Physically truncate so a later reader never re-parses
                // the garbage.
                stats.truncated_bytes += (raw.len() - valid_bytes) as u64;
                let f = fs::OpenOptions::new().write(true).open(&path)?;
                f.set_len(valid_bytes as u64)?;
            }
        }
        // sealed segments whose submits all have outcomes are dead weight
        for &idx in &segs {
            if seg_open.get(&idx).copied().unwrap_or(0) == 0 {
                let _ = fs::remove_file(seg_path(&dir, idx));
                seg_open.remove(&idx);
                stats.segments_gced += 1;
            }
        }
        let active_seg = last.map_or(0, |l| l + 1);
        let file = fs::File::create(seg_path(&dir, active_seg))?;
        stats.segments_created += 1;
        seg_open.insert(active_seg, 0);
        Ok(Journal {
            dir,
            sync_ms,
            sync_bytes: sync_bytes.max(1),
            seg_bytes: seg_bytes.max(1),
            inner: Mutex::new(Inner {
                file,
                active_seg,
                active_bytes: 0,
                buf: Vec::new(),
                last_sync: Instant::now(),
                pending,
                key_seg,
                seg_open,
                stats,
            }),
            lock: LockStat::new("journal"),
        })
    }

    /// Directory the segments (and the per-shard checkpoint files) live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Contention counters for the journal mutex.
    pub fn lock_stat(&self) -> &LockStat {
        &self.lock
    }

    fn flush_locked(inner: &mut Inner) {
        if inner.buf.is_empty() {
            return;
        }
        let _ = inner.file.write_all(&inner.buf);
        let _ = inner.file.sync_data();
        inner.stats.group_commits += 1;
        inner.stats.synced_bytes += inner.buf.len() as u64;
        inner.buf.clear();
        inner.last_sync = Instant::now();
    }

    fn rotate_locked(&self, inner: &mut Inner) {
        Self::flush_locked(inner);
        let sealed = inner.active_seg;
        inner.active_seg += 1;
        let next = inner.active_seg;
        if let Ok(f) = fs::File::create(seg_path(&self.dir, next)) {
            inner.file = f;
        }
        inner.active_bytes = 0;
        inner.stats.segments_created += 1;
        inner.seg_open.entry(next).or_insert(0);
        // a segment sealed with nothing open will never see another
        // outcome — GC it now or never
        if inner.seg_open.get(&sealed).copied().unwrap_or(0) == 0 {
            let _ = fs::remove_file(seg_path(&self.dir, sealed));
            inner.seg_open.remove(&sealed);
            inner.stats.segments_gced += 1;
        }
    }

    fn append_locked(&self, inner: &mut Inner, body: &str) {
        let line = record_line(body);
        inner.active_bytes += line.len();
        inner.buf.extend_from_slice(line.as_bytes());
        if inner.active_bytes >= self.seg_bytes {
            self.rotate_locked(inner);
        } else if self.sync_ms == 0 || inner.buf.len() >= self.sync_bytes {
            Self::flush_locked(inner);
        }
    }

    /// Journal one accepted submission (call after the target shard took
    /// it). The record joins the pending (replayable) set.
    pub fn append_submit(&self, rec: &SubmitRecord) {
        let body = rec.to_body();
        let mut guard = self.lock.lock(&self.inner);
        let inner = &mut *guard;
        inner.stats.submits += 1;
        *inner.seg_open.entry(inner.active_seg).or_insert(0) += 1;
        inner.key_seg.insert(rec.key.clone(), inner.active_seg);
        inner.pending.insert(rec.key.clone(), rec.clone());
        self.append_locked(inner, &body);
    }

    /// Journal a terminal outcome for `key`, closing its submit. Drives
    /// GC: a sealed segment whose last open submit this was is flushed
    /// past (so the outcome is durable first) and deleted.
    pub fn append_outcome(&self, key: &str, ok: bool) {
        let body = Json::obj(vec![
            ("t", Json::str("o")),
            ("k", Json::str(key)),
            ("ok", Json::Bool(ok)),
        ])
        .to_string();
        let mut guard = self.lock.lock(&self.inner);
        let inner = &mut *guard;
        let Some(seg) = inner.key_seg.remove(key) else {
            // no live submit for this key: appending would create a
            // duplicate outcome record (someone bypassed the `claim`
            // gate, or retried an already-retired key) — refuse and
            // count the attempt instead
            inner.stats.duplicate_outcomes += 1;
            return;
        };
        inner.stats.outcomes += 1;
        inner.pending.remove(key);
        self.append_locked(inner, &body);
        let n = inner.seg_open.entry(seg).or_insert(1);
        *n = n.saturating_sub(1);
        let closed = *n == 0;
        if closed && seg != inner.active_seg {
            // the outcome that freed the segment must be durable
            // before the submit it closes disappears
            Self::flush_locked(inner);
            let _ = fs::remove_file(seg_path(&self.dir, seg));
            inner.seg_open.remove(&seg);
            inner.stats.segments_gced += 1;
        }
    }

    /// Atomically take `key`'s pending record — the exactly-once gate.
    /// Exactly one caller (the original completion path or a dead-shard
    /// replayer) gets `Some`; that caller owns appending the outcome.
    pub fn claim(&self, key: &str) -> Option<SubmitRecord> {
        self.lock.lock(&self.inner).pending.remove(key)
    }

    /// Atomically claim every pending record submitted to `shard` (the
    /// dead-shard replay worklist).
    pub fn claim_shard(&self, shard: usize) -> Vec<SubmitRecord> {
        let mut inner = self.lock.lock(&self.inner);
        let keys: Vec<String> = inner
            .pending
            .iter()
            .filter(|(_, r)| r.shard == shard)
            .map(|(k, _)| k.clone())
            .collect();
        keys.iter()
            .filter_map(|k| inner.pending.remove(k))
            .collect()
    }

    /// Atomically claim every pending record regardless of shard
    /// (startup orphan recovery).
    pub fn claim_all(&self) -> Vec<SubmitRecord> {
        let mut inner = self.lock.lock(&self.inner);
        inner.pending.drain().map(|(_, r)| r).collect()
    }

    /// Submits currently without an outcome.
    pub fn pending_len(&self) -> usize {
        self.lock.lock(&self.inner).pending.len()
    }

    /// Flush buffered appends to the active segment now.
    pub fn sync(&self) {
        let mut inner = self.lock.lock(&self.inner);
        Self::flush_locked(&mut inner);
    }

    /// Flush iff the group-commit interval has elapsed since the last
    /// flush (the `forkkv-journal` supervisor's tick body; public for
    /// deterministic tests).
    pub fn maybe_sync(&self) {
        let mut inner = self.lock.lock(&self.inner);
        if !inner.buf.is_empty()
            && inner.last_sync.elapsed().as_millis() as u64 >= self.sync_ms
        {
            Self::flush_locked(&mut inner);
        }
    }

    /// Lifetime counters snapshot.
    pub fn stats(&self) -> JournalStats {
        self.lock.lock(&self.inner).stats.clone()
    }

    /// Live segment files on disk (tests / GC assertions).
    pub fn segment_files(&self) -> Vec<PathBuf> {
        let mut out: Vec<PathBuf> = fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.file_name()
                            .and_then(|n| n.to_str())
                            .is_some_and(|n| n.starts_with("seg-") && n.ends_with(".wal"))
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_dir(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "forkkv-journal-{tag}-{}-{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn rec(key: &str, shard: usize, n: usize) -> SubmitRecord {
        SubmitRecord {
            key: key.to_string(),
            shard,
            tag: 7,
            adapter: 3,
            max_new: 16,
            fan: 2,
            tokens: (0..n as u32).collect(),
        }
    }

    #[test]
    fn submit_outcome_round_trip_survives_reopen() {
        let dir = tmp_dir("rt");
        {
            let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
            j.append_submit(&rec("a", 0, 8));
            j.append_submit(&rec("b", 1, 4));
            j.append_outcome("a", true);
        }
        let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        assert_eq!(j.pending_len(), 1);
        let got = j.claim("b").expect("b still pending");
        assert_eq!(got, rec("b", 1, 4));
        assert!(j.claim("a").is_none(), "outcomed submit must not replay");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_thresholds_buffer_until_bytes_or_sync() {
        let dir = tmp_dir("gc");
        let j = Journal::open(&dir, 10_000, 4096, 1 << 20).unwrap();
        j.append_submit(&rec("a", 0, 4));
        assert_eq!(j.stats().group_commits, 0, "small append buffers");
        j.sync();
        let s = j.stats();
        assert_eq!(s.group_commits, 1);
        assert!(s.synced_bytes > 0);
        // byte threshold forces a flush without an explicit sync
        let j2 = Journal::open(tmp_dir("gc2"), 10_000, 64, 1 << 20).unwrap();
        j2.append_submit(&rec("big", 0, 64));
        assert!(j2.stats().group_commits >= 1, "64-byte threshold crossed");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn maybe_sync_respects_interval() {
        let dir = tmp_dir("ms");
        let j = Journal::open(&dir, 60_000, 1 << 20, 1 << 20).unwrap();
        j.append_submit(&rec("a", 0, 4));
        j.maybe_sync();
        assert_eq!(j.stats().group_commits, 0, "interval not elapsed");
        let j = Journal::open(tmp_dir("ms2"), 0, 1 << 20, 1 << 20).unwrap();
        j.append_submit(&rec("a", 0, 4));
        assert_eq!(j.stats().group_commits, 1, "sync_ms=0 is strict");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_truncates_and_keeps_valid_prefix() {
        let dir = tmp_dir("torn");
        {
            let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
            j.append_submit(&rec("keep", 0, 8));
            j.append_submit(&rec("alsokeep", 0, 8));
        }
        // crash mid-append: chop the newest segment mid-record
        let seg = seg_path(&dir, 0);
        let mut raw = fs::read(&seg).unwrap();
        let cut = raw.len() - 10;
        raw.truncate(cut);
        fs::write(&seg, &raw).unwrap();
        let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        let s = j.stats();
        assert!(s.truncated_bytes > 0, "tail was truncated");
        assert_eq!(j.pending_len(), 1, "only the intact record survives");
        assert!(j.claim("keep").is_some());
        assert!(fs::read(&seg).unwrap().len() < cut, "file physically truncated");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_rejects_corrupt_line_and_everything_after() {
        let dir = tmp_dir("crc");
        {
            let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
            j.append_submit(&rec("first", 0, 8));
            j.append_submit(&rec("second", 0, 8));
            j.append_submit(&rec("third", 0, 8));
        }
        let seg = seg_path(&dir, 0);
        let mut raw = fs::read(&seg).unwrap();
        // flip a byte inside the second record's body
        let mid = raw.len() / 2;
        raw[mid] ^= 0x40;
        fs::write(&seg, &raw).unwrap();
        let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        assert!(j.stats().corrupt_lines >= 1);
        assert!(j.claim("first").is_some(), "prefix before corruption kept");
        assert!(
            j.claim("third").is_none(),
            "records after a corrupt line are untrusted"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_deletes_only_fully_outcomed_sealed_segments() {
        let dir = tmp_dir("gc3");
        // tiny segments: every submit seals a segment quickly
        let j = Journal::open(&dir, 0, 1, 64).unwrap();
        j.append_submit(&rec("a", 0, 16));
        j.append_submit(&rec("b", 0, 16));
        j.append_submit(&rec("c", 0, 16));
        let before = j.segment_files().len();
        assert!(before > 1, "rotation produced sealed segments");
        // an un-outcomed record's segment must survive any amount of GC
        j.append_outcome("a", true);
        j.append_outcome("c", true);
        assert!(j.claim("b").is_some(), "b never lost while un-outcomed");
        let s = j.stats();
        assert!(s.segments_gced >= 1, "a's fully-closed segment collected");
        // after b's outcome, reopen collects everything sealed
        j.append_outcome("b", false);
        drop(j);
        let j = Journal::open(&dir, 0, 1, 64).unwrap();
        assert_eq!(j.pending_len(), 0);
        assert_eq!(
            j.segment_files().len(),
            1,
            "only the fresh active segment remains"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn claim_is_exactly_once_per_key_and_shard_scoped() {
        let dir = tmp_dir("claim");
        let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        j.append_submit(&rec("x", 0, 4));
        j.append_submit(&rec("y", 1, 4));
        j.append_submit(&rec("z", 1, 4));
        let dead = j.claim_shard(1);
        assert_eq!(dead.len(), 2);
        assert!(j.claim_shard(1).is_empty(), "second sweep finds nothing");
        assert!(j.claim("x").is_some());
        assert!(j.claim("x").is_none(), "claim is exactly-once");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_outcome_for_a_key_is_refused_and_counted() {
        let dir = tmp_dir("dup");
        let j = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        j.append_submit(&rec("k", 0, 4));
        j.append_outcome("k", true);
        j.append_outcome("k", false); // duplicate: must not append
        j.append_outcome("ghost", true); // never submitted: same refusal
        let s = j.stats();
        assert_eq!(s.outcomes, 1, "exactly one outcome record appended");
        assert_eq!(s.duplicate_outcomes, 2);
        // reopen sees one submit + one outcome, nothing pending
        drop(j);
        let j2 = Journal::open(&dir, 0, 1, 1 << 20).unwrap();
        assert_eq!(j2.pending_len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fuzz_recovery_never_resurrects_outcomed_submits() {
        // random interleavings of submit/outcome; after reopen the
        // pending set must be exactly the un-outcomed submits
        let mut rng = crate::util::rng::Rng::seeded(0x6a6f);
        for round in 0..8u64 {
            let dir = tmp_dir("fuzz");
            let mut open: Vec<String> = Vec::new();
            let mut expect: std::collections::HashSet<String> =
                std::collections::HashSet::new();
            {
                let j = Journal::open(&dir, 0, 1, 256).unwrap();
                for i in 0..40u64 {
                    if !open.is_empty() && rng.below(3) == 0 {
                        let k = open.swap_remove(rng.below(open.len()));
                        expect.remove(&k);
                        j.append_outcome(&k, true);
                    } else {
                        let k = format!("r{round}k{i}");
                        j.append_submit(&rec(&k, (i % 3) as usize, 8));
                        expect.insert(k.clone());
                        open.push(k);
                    }
                }
            }
            let j = Journal::open(&dir, 0, 1, 256).unwrap();
            let got: std::collections::HashSet<String> =
                j.claim_all().into_iter().map(|r| r.key).collect();
            assert_eq!(got, expect, "round {round}");
            let _ = fs::remove_dir_all(&dir);
        }
    }
}
