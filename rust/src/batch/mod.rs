//! Gather/scatter between the paged pools and the padded kernel inputs.
//!
//! The AOT artifacts take dense, padded cache slabs (`[L, S, width]` per
//! sequence). Each running sequence keeps a persistent host `SeqSlab` that
//! mirrors its logical cache: loaded once from inherited pages at fork time
//! and appended incrementally afterwards, so the per-step cost is O(new
//! tokens), not O(S). On a real accelerator the kernel would read the pages
//! directly; on this CPU substrate the slab is the transient reconstruction
//! buffer (DESIGN.md §2) — the *persistent* state remains the shared pages.

use crate::kvcache::BlockPool;
use crate::kvcache::PageId;
use crate::runtime::{DecodeOut, PrefillOut};

/// Geometry of one sequence's padded slabs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlabSpec {
    pub n_layers: usize,
    pub s_max: usize,
    /// base width per token per layer (= n_kv_heads * head_dim)
    pub base_width: usize,
    /// residual width per token per layer (= rank_max)
    pub res_width: usize,
}

/// Per-sequence padded cache mirror: kb/vb `[L, S, base_width]`,
/// kr/vr `[L, S, res_width]`.
#[derive(Debug, Clone)]
pub struct SeqSlab {
    pub spec: SlabSpec,
    pub kb: Vec<f32>,
    pub vb: Vec<f32>,
    pub kr: Vec<f32>,
    pub vr: Vec<f32>,
    /// tokens materialized so far
    pub filled: usize,
}

impl SeqSlab {
    pub fn new(spec: SlabSpec) -> Self {
        let nb = spec.n_layers * spec.s_max * spec.base_width;
        let nr = spec.n_layers * spec.s_max * spec.res_width;
        SeqSlab {
            spec,
            kb: vec![0.0; nb],
            vb: vec![0.0; nb],
            kr: vec![0.0; nr],
            vr: vec![0.0; nr],
            filled: 0,
        }
    }

    #[inline]
    fn row_base(&self, layer: usize, pos: usize) -> usize {
        (layer * self.spec.s_max + pos) * self.spec.base_width
    }

    #[inline]
    fn row_res(&self, layer: usize, pos: usize) -> usize {
        (layer * self.spec.s_max + pos) * self.spec.res_width
    }

    /// Fill positions `[0, n_tokens)` of the base component from pages
    /// (fork inheritance of bCache, or of merged KV for the baselines).
    pub fn load_base_pages(&mut self, pool: &BlockPool, pages: &[PageId], n_tokens: usize) {
        let pt = pool.spec().page_tokens;
        let w = self.spec.base_width;
        assert_eq!(pool.spec().width, w, "pool/slab base width mismatch");
        assert!(n_tokens <= pages.len() * pt);
        for l in 0..self.spec.n_layers {
            for (pi, &page) in pages.iter().enumerate() {
                let start = pi * pt;
                if start >= n_tokens {
                    break;
                }
                let take = (n_tokens - start).min(pt);
                let src_k = pool.kv_slice(page, l, 0);
                let src_v = pool.kv_slice(page, l, 1);
                let dst = self.row_base(l, start);
                self.kb[dst..dst + take * w].copy_from_slice(&src_k[..take * w]);
                self.vb[dst..dst + take * w].copy_from_slice(&src_v[..take * w]);
            }
        }
        self.filled = self.filled.max(n_tokens);
    }

    /// Fill positions `[0, n_tokens)` of the residual component from pages.
    /// The pool stores only `rank_effective` floats per row (honest memory
    /// accounting, paper Eq. 3); the slab rows are `rank_max` wide with a
    /// zero tail, so rows are copied individually.
    ///
    /// Contract: unlike [`SeqSlab::load_base_pages`], this **never
    /// advances `filled`**. The two inherited coverages are independent —
    /// a fork can match more residual than base pages (or vice versa) —
    /// and `filled` must end up at the *joint* coverage the decode path
    /// may attend over, which only the caller knows. `Engine::admit_fork`
    /// therefore loads both components and then sets `filled` explicitly
    /// to `min(base_cached, res_cached)`; if this method bumped `filled`
    /// to `n_tokens`, a residual-heavy fork would attend over base rows
    /// that were never materialized.
    pub fn load_res_pages(&mut self, pool: &BlockPool, pages: &[PageId], n_tokens: usize) {
        let pt = pool.spec().page_tokens;
        let wp = pool.spec().width;
        let ws = self.spec.res_width;
        assert!(wp <= ws, "pool res width exceeds slab rank_max");
        assert!(n_tokens <= pages.len() * pt, "residual pages cover n_tokens");
        let filled_before = self.filled;
        for l in 0..self.spec.n_layers {
            for (pi, &page) in pages.iter().enumerate() {
                let start = pi * pt;
                if start >= n_tokens {
                    break;
                }
                let take = (n_tokens - start).min(pt);
                let src_k = pool.kv_slice(page, l, 0);
                let src_v = pool.kv_slice(page, l, 1);
                for t in 0..take {
                    let dst = self.row_res(l, start + t);
                    self.kr[dst..dst + wp].copy_from_slice(&src_k[t * wp..(t + 1) * wp]);
                    self.vr[dst..dst + wp].copy_from_slice(&src_v[t * wp..(t + 1) * wp]);
                }
            }
        }
        debug_assert_eq!(
            self.filled, filled_before,
            "load_res_pages must not advance filled (joint coverage is the caller's call)"
        );
    }

    /// Append a prefill chunk's outputs at `start` (= cache_len of the
    /// call). `use_merged` selects km/vm instead of kb/vb for the base
    /// component (unified baselines store + attend over merged KV) and
    /// skips the residual lanes, which must remain zero so the kernel
    /// reduces to standard attention over the merged cache.
    pub fn append_prefill(&mut self, out: &PrefillOut, start: usize, n: usize,
                          chunk: usize, use_merged: bool) {
        let (wb, wr) = (self.spec.base_width, self.spec.res_width);
        let (kb_src, vb_src) = if use_merged {
            (&out.km, &out.vm)
        } else {
            (&out.kb, &out.vb)
        };
        for l in 0..self.spec.n_layers {
            let src = (l * chunk) * wb;
            let dst = self.row_base(l, start);
            self.kb[dst..dst + n * wb].copy_from_slice(&kb_src[src..src + n * wb]);
            self.vb[dst..dst + n * wb].copy_from_slice(&vb_src[src..src + n * wb]);
            if !use_merged {
                let src_r = (l * chunk) * wr;
                let dst_r = self.row_res(l, start);
                self.kr[dst_r..dst_r + n * wr]
                    .copy_from_slice(&out.kr[src_r..src_r + n * wr]);
                self.vr[dst_r..dst_r + n * wr]
                    .copy_from_slice(&out.vr[src_r..src_r + n * wr]);
            }
        }
        self.filled = self.filled.max(start + n);
    }

    /// Append one decoded token's KV (row `row` of a decode output) at
    /// position `pos`. `use_merged` as in `append_prefill`.
    pub fn append_decode(&mut self, out: &DecodeOut, row: usize, pos: usize,
                         n_rows: usize, use_merged: bool) {
        let (wb, wr) = (self.spec.base_width, self.spec.res_width);
        let l_total = self.spec.n_layers;
        let (kb_src, vb_src) = if use_merged {
            (&out.km, &out.vm)
        } else {
            (&out.kb, &out.vb)
        };
        debug_assert_eq!(kb_src.len(), n_rows * l_total * wb);
        for l in 0..l_total {
            let src = (row * l_total + l) * wb;
            let dst = self.row_base(l, pos);
            self.kb[dst..dst + wb].copy_from_slice(&kb_src[src..src + wb]);
            self.vb[dst..dst + wb].copy_from_slice(&vb_src[src..src + wb]);
            if !use_merged {
                let src_r = (row * l_total + l) * wr;
                let dst_r = self.row_res(l, pos);
                self.kr[dst_r..dst_r + wr].copy_from_slice(&out.kr[src_r..src_r + wr]);
                self.vr[dst_r..dst_r + wr].copy_from_slice(&out.vr[src_r..src_r + wr]);
            }
        }
        self.filled = self.filled.max(pos + 1);
    }

    /// Zero the residual component (a sequence forked onto merged-KV pages
    /// must not attend over stale residuals).
    pub fn clear_res(&mut self) {
        self.kr.fill(0.0);
        self.vr.fill(0.0);
    }
}

// ---------------------------------------------------------------------------
// scatter: persist computed KV into pool pages
// ---------------------------------------------------------------------------

/// Write `n` token rows from a prefill chunk (layout `[L, chunk, src_width]`)
/// into `pages`, starting at absolute token position `start`, persisting
/// only the pool-width prefix of each row (the residual pool stores
/// `rank_effective` of `rank_max` — honest Eq. 3 accounting). Pages must
/// cover positions `[start, start+n)`; `pages[i]` holds tokens
/// `[i*pt, (i+1)*pt)`.
pub fn scatter_chunk(
    pool: &mut BlockPool,
    pages: &[PageId],
    start: usize,
    n: usize,
    chunk: usize,
    src_width: usize,
    k_src: &[f32],
    v_src: &[f32],
) {
    let pt = pool.spec().page_tokens;
    let w = pool.spec().width;
    let n_layers = pool.spec().n_layers;
    assert!(w <= src_width, "pool width exceeds source row width");
    debug_assert!(k_src.len() >= n_layers * chunk * src_width);
    for l in 0..n_layers {
        for t in 0..n {
            let pos = start + t;
            let page = pages[pos / pt];
            let slot = pos % pt;
            let src = (l * chunk + t) * src_width;
            let dst = slot * w;
            pool.kv_slice_mut(page, l, 0)[dst..dst + w]
                .copy_from_slice(&k_src[src..src + w]);
            pool.kv_slice_mut(page, l, 1)[dst..dst + w]
                .copy_from_slice(&v_src[src..src + w]);
        }
    }
}

/// Write one decoded token's KV (row `row` of `[B, L, src_width]`) into the
/// page covering absolute position `pos`.
pub fn scatter_token(
    pool: &mut BlockPool,
    page: PageId,
    pos: usize,
    row: usize,
    n_layers: usize,
    src_width: usize,
    k_src: &[f32],
    v_src: &[f32],
) {
    let pt = pool.spec().page_tokens;
    let w = pool.spec().width;
    assert!(w <= src_width, "pool width exceeds source row width");
    let slot = pos % pt;
    for l in 0..n_layers {
        let src = (row * n_layers + l) * src_width;
        let dst = slot * w;
        pool.kv_slice_mut(page, l, 0)[dst..dst + w]
            .copy_from_slice(&k_src[src..src + w]);
        pool.kv_slice_mut(page, l, 1)[dst..dst + w]
            .copy_from_slice(&v_src[src..src + w]);
    }
}

/// Concatenate row slabs into the batched `[B, L, S, width]` upload buffer.
pub fn stack_slabs<'a>(
    rows: impl Iterator<Item = &'a [f32]>,
    row_len: usize,
    bucket: usize,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(bucket * row_len, 0.0);
    for (i, row) in rows.enumerate() {
        debug_assert_eq!(row.len(), row_len);
        out[i * row_len..(i + 1) * row_len].copy_from_slice(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PoolSpec;

    fn mk_pool() -> BlockPool {
        BlockPool::new(PoolSpec { n_pages: 8, page_tokens: 4, n_layers: 2, width: 3 })
    }

    fn spec() -> SlabSpec {
        SlabSpec { n_layers: 2, s_max: 16, base_width: 3, res_width: 2 }
    }

    #[test]
    fn scatter_then_gather_round_trips() {
        let mut pool = mk_pool();
        let pages: Vec<PageId> = (0..2).map(|_| pool.alloc().unwrap()).collect();
        // fabricate a "prefill chunk" of 6 tokens, chunk capacity 8
        let chunk = 8;
        let w = 3;
        let nl = 2;
        let k: Vec<f32> = (0..nl * chunk * w).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..nl * chunk * w).map(|i| 1000.0 + i as f32).collect();
        scatter_chunk(&mut pool, &pages, 0, 6, chunk, w, &k, &v);

        let mut slab = SeqSlab::new(spec());
        slab.load_base_pages(&pool, &pages, 6);
        for l in 0..nl {
            for t in 0..6 {
                let src = (l * chunk + t) * w;
                let dst = (l * 16 + t) * w;
                assert_eq!(&slab.kb[dst..dst + w], &k[src..src + w], "l{l} t{t}");
                assert_eq!(&slab.vb[dst..dst + w], &v[src..src + w]);
            }
        }
        assert_eq!(slab.filled, 6);
    }

    #[test]
    fn scatter_token_places_by_slot() {
        let mut pool = mk_pool();
        let p0 = pool.alloc().unwrap();
        let p1 = pool.alloc().unwrap();
        let nl = 2;
        let w = 3;
        // token at absolute position 5 -> page 1, slot 1 (page_tokens=4)
        let k: Vec<f32> = (0..2 * nl * w).map(|i| i as f32).collect(); // B=2 rows
        let v = k.clone();
        scatter_token(&mut pool, p1, 5, 1, nl, w, &k, &v);
        let got = pool.kv_slice(p1, 0, 0);
        let src = nl * w; // row 1, layer 0
        assert_eq!(&got[w..2 * w], &k[src..src + w]);
        let _ = p0;
    }

    #[test]
    fn fork_inheriting_residual_pages_leaves_filled_to_the_caller() {
        // Regression for the load_res_pages contract: a fork that inherits
        // MORE residual than base coverage must not see `filled` jump to
        // the residual coverage — base rows beyond `filled` were never
        // materialized. Mirrors Engine::admit_fork: load base (4 tokens),
        // load residual (8 tokens), then the caller pins `filled` to the
        // joint coverage min(4, 8) = 4.
        let base_pool = {
            let mut p = mk_pool();
            let pages = vec![p.alloc().unwrap()];
            let k: Vec<f32> = (0..2 * 8 * 3).map(|i| i as f32).collect();
            scatter_chunk(&mut p, &pages, 0, 4, 8, 3, &k, &k);
            (p, pages)
        };
        // slab residual rows are res_width=2 wide; the pool stores only
        // width 1 (rank_effective < rank_max), exercising the zero tail
        let mut res_pool =
            BlockPool::new(PoolSpec { n_pages: 8, page_tokens: 4, n_layers: 2, width: 1 });
        let res_pages: Vec<PageId> = (0..2).map(|_| res_pool.alloc().unwrap()).collect();
        let kr: Vec<f32> = (0..2 * 8).map(|i| 500.0 + i as f32).collect();
        scatter_chunk(&mut res_pool, &res_pages, 0, 8, 8, 1, &kr, &kr);

        let mut slab = SeqSlab::new(spec());
        let (bpool, bpages) = &base_pool;
        slab.load_base_pages(bpool, bpages, 4);
        assert_eq!(slab.filled, 4, "base load advances filled");
        slab.load_res_pages(&res_pool, &res_pages, 8);
        assert_eq!(slab.filled, 4, "residual load must NOT advance filled");
        // residual rows materialized for all 8 inherited tokens, with the
        // rank tail beyond the pool width still zero
        let s = spec();
        for t in 0..8 {
            let dst = (s.s_max + t) * s.res_width; // layer 1, token t
            let src = (8 + t) as f32; // layer 1 stride in the 8-token chunk
            assert_eq!(slab.kr[dst], 500.0 + src, "layer 1 token {t}");
            assert_eq!(slab.kr[dst + 1], 0.0, "rank tail must stay zero");
        }
        // the engine then pins filled to the joint coverage
        slab.filled = 4.min(8);
        assert_eq!(slab.filled, 4);
    }

    #[test]
    fn append_prefill_writes_rows_and_advances_fill() {
        let chunk = 8;
        let s = spec();
        let mut slab = SeqSlab::new(s);
        let nb = s.n_layers * chunk * s.base_width;
        let nr = s.n_layers * chunk * s.res_width;
        let out = PrefillOut {
            logits: vec![],
            kb: (0..nb).map(|i| i as f32).collect(),
            vb: (0..nb).map(|i| 10_000.0 + i as f32).collect(),
            kr: (0..nr).map(|i| 20_000.0 + i as f32).collect(),
            vr: (0..nr).map(|i| 30_000.0 + i as f32).collect(),
            km: vec![7.0; nb],
            vm: vec![8.0; nb],
            xs: vec![],
        };
        slab.append_prefill(&out, 4, 5, chunk, false);
        assert_eq!(slab.filled, 9);
        // layer 1, token 2 of the chunk lands at position 6
        let dst = (s.s_max + 6) * s.base_width;
        let src = (chunk + 2) * s.base_width;
        assert_eq!(slab.kb[dst], out.kb[src]);
        let dst_r = (s.s_max + 6) * s.res_width;
        let src_r = (chunk + 2) * s.res_width;
        assert_eq!(slab.kr[dst_r], out.kr[src_r]);

        // merged variant routes km/vm into the base lanes
        let mut slab2 = SeqSlab::new(s);
        slab2.append_prefill(&out, 0, 3, chunk, true);
        assert!(slab2.kb[..3 * s.base_width].iter().all(|&x| x == 7.0));
    }

    #[test]
    fn append_decode_single_row() {
        let s = spec();
        let mut slab = SeqSlab::new(s);
        let b = 4;
        let out = DecodeOut {
            logits: vec![],
            kb: (0..b * s.n_layers * s.base_width).map(|i| i as f32).collect(),
            vb: vec![1.0; b * s.n_layers * s.base_width],
            kr: (0..b * s.n_layers * s.res_width).map(|i| i as f32).collect(),
            vr: vec![2.0; b * s.n_layers * s.res_width],
            km: vec![9.0; b * s.n_layers * s.base_width],
            vm: vec![9.5; b * s.n_layers * s.base_width],
        };
        slab.append_decode(&out, 2, 7, b, false);
        let dst = 7 * s.base_width; // layer 0, position 7
        let src = 2 * s.n_layers * s.base_width; // row 2, layer 0
        assert_eq!(slab.kb[dst], out.kb[src]);
        assert_eq!(slab.filled, 8);
    }

    #[test]
    fn stack_slabs_pads_bucket() {
        let rows: Vec<Vec<f32>> = vec![vec![1.0; 4], vec![2.0; 4]];
        let mut out = Vec::new();
        stack_slabs(rows.iter().map(|r| r.as_slice()), 4, 4, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!(&out[..4], &[1.0; 4]);
        assert_eq!(&out[4..8], &[2.0; 4]);
        assert!(out[8..].iter().all(|&x| x == 0.0));
    }
}
