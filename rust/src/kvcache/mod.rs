//! Paged KV-cache pools with copy-on-write refcounting.
//!
//! The disaggregated layout (paper §5.1) is realized as *two* pools with
//! identical paging machinery but different widths:
//!   - the **base pool** stores bCache pages: per token per layer,
//!     `kv_width = n_kv_heads * head_dim` floats for K and again for V
//!     (K rows are stored post-RoPE);
//!   - the **residual pool** stores rCache pages: `rank_max` floats for
//!     K_res and V_res each — `r/n` of the base width (Eq. 3).
//!
//! A page holds `page_tokens` consecutive tokens across *all* layers, laid
//! out `[layer][k|v][slot][width]` so gather/scatter move one contiguous
//! `page_tokens * width` run per (page, layer, k|v).
//!
//! "Copy-on-write" here is the fork discipline of the paper: pages are
//! refcounted and shared read-only between the radix trees and any number
//! of running sequences; a fork *retains* (never copies), and divergence
//! materializes as freshly allocated tail pages. No shared page is ever
//! written after publication.

#![warn(missing_docs)]

/// Index of a page within its pool (dense, recycled via the free list).
pub type PageId = u32;

/// Geometry of one paged pool (see module docs for the layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSpec {
    /// pages in the pool (sizes the page table and backing buffer)
    pub n_pages: usize,
    /// consecutive tokens per page (allocator + radix granularity)
    pub page_tokens: usize,
    /// transformer layers stored per page
    pub n_layers: usize,
    /// floats per token per layer for each of K and V
    pub width: usize,
}

impl PoolSpec {
    /// f32 slots one page occupies (`[layer][k|v][slot][width]`).
    pub fn floats_per_page(&self) -> usize {
        self.n_layers * 2 * self.page_tokens * self.width
    }
    /// Bytes one page occupies (4 bytes per float).
    pub fn bytes_per_page(&self) -> usize {
        self.floats_per_page() * 4
    }
    /// bytes of KV state per cached token (both K and V, all layers)
    pub fn bytes_per_token(&self) -> usize {
        self.n_layers * 2 * self.width * 4
    }
}

/// One refcounted paged KV pool (base or residual; see module docs).
#[derive(Debug)]
pub struct BlockPool {
    spec: PoolSpec,
    data: Vec<f32>,
    refcount: Vec<u32>,
    free: Vec<PageId>,
    used: usize,
    high_water: usize,
    total_allocs: u64,
    alloc_failures: u64,
}

impl BlockPool {
    /// Pool with every page free and its backing buffer zeroed.
    pub fn new(spec: PoolSpec) -> Self {
        let free: Vec<PageId> = (0..spec.n_pages as u32).rev().collect();
        BlockPool {
            data: vec![0.0; spec.n_pages * spec.floats_per_page()],
            refcount: vec![0; spec.n_pages],
            free,
            used: 0,
            high_water: 0,
            total_allocs: 0,
            alloc_failures: 0,
            spec,
        }
    }

    /// The pool's immutable geometry.
    pub fn spec(&self) -> &PoolSpec {
        &self.spec
    }

    /// Allocate a page with refcount 1. None when the pool is exhausted
    /// (the engine then evicts from the radix trees and retries).
    pub fn alloc(&mut self) -> Option<PageId> {
        match self.free.pop() {
            Some(p) => {
                debug_assert_eq!(self.refcount[p as usize], 0);
                self.refcount[p as usize] = 1;
                self.used += 1;
                self.high_water = self.high_water.max(self.used);
                self.total_allocs += 1;
                Some(p)
            }
            None => {
                self.alloc_failures += 1;
                None
            }
        }
    }

    /// Share an existing page (fork semantics: "map the parent's page").
    pub fn retain(&mut self, page: PageId) {
        let rc = &mut self.refcount[page as usize];
        assert!(*rc > 0, "retain of free page {page}");
        *rc += 1;
    }

    /// Drop one reference; the page returns to the free list at zero.
    pub fn release(&mut self, page: PageId) {
        let rc = &mut self.refcount[page as usize];
        assert!(*rc > 0, "release of free page {page}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
            self.used -= 1;
        }
    }

    /// Current reference count of `page` (0 = free).
    pub fn refcount(&self, page: PageId) -> u32 {
        self.refcount[page as usize]
    }

    #[inline]
    fn kv_offset(&self, page: PageId, layer: usize, kv: usize) -> usize {
        debug_assert!(layer < self.spec.n_layers && kv < 2);
        page as usize * self.spec.floats_per_page()
            + (layer * 2 + kv) * self.spec.page_tokens * self.spec.width
    }

    /// Contiguous `[slot][width]` run for one (page, layer, K|V).
    pub fn kv_slice(&self, page: PageId, layer: usize, kv: usize) -> &[f32] {
        let off = self.kv_offset(page, layer, kv);
        &self.data[off..off + self.spec.page_tokens * self.spec.width]
    }

    /// Mutable variant of [`BlockPool::kv_slice`] (CoW discipline: only
    /// call on pages with refcount 1).
    pub fn kv_slice_mut(&mut self, page: PageId, layer: usize, kv: usize) -> &mut [f32] {
        let off = self.kv_offset(page, layer, kv);
        let len = self.spec.page_tokens * self.spec.width;
        &mut self.data[off..off + len]
    }

    /// One page's full contiguous run (`[layer][k|v][slot][width]`,
    /// `floats_per_page` floats) — the unit cross-shard migration
    /// snapshots and restores.
    pub fn page_data(&self, page: PageId) -> &[f32] {
        let fpp = self.spec.floats_per_page();
        let off = page as usize * fpp;
        &self.data[off..off + fpp]
    }

    /// Mutable variant of [`BlockPool::page_data`] (migration restore).
    pub fn page_data_mut(&mut self, page: PageId) -> &mut [f32] {
        let fpp = self.spec.floats_per_page();
        let off = page as usize * fpp;
        &mut self.data[off..off + fpp]
    }

    // ---------------- accounting ----------------
    /// Pages with refcount > 0.
    pub fn used_pages(&self) -> usize {
        self.used
    }
    /// Pages on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }
    /// Peak concurrent `used_pages` over the pool's lifetime.
    pub fn high_water_pages(&self) -> usize {
        self.high_water
    }
    /// Bytes currently held by used pages.
    pub fn used_bytes(&self) -> usize {
        self.used * self.spec.bytes_per_page()
    }
    /// Total bytes the pool could hold if every page were used.
    pub fn capacity_bytes(&self) -> usize {
        self.spec.n_pages * self.spec.bytes_per_page()
    }
    /// Lifetime successful allocations.
    pub fn total_allocs(&self) -> u64 {
        self.total_allocs
    }
    /// Lifetime allocations that found the pool exhausted.
    pub fn alloc_failures(&self) -> u64 {
        self.alloc_failures
    }
    /// Test/debug invariant: used + free covers all pages exactly once.
    pub fn check_invariants(&self) -> Result<(), String> {
        let rc_used = self.refcount.iter().filter(|&&r| r > 0).count();
        if rc_used != self.used {
            return Err(format!("used={} but {} pages have rc>0", self.used, rc_used));
        }
        if self.used + self.free.len() != self.spec.n_pages {
            return Err(format!(
                "used {} + free {} != pages {}",
                self.used,
                self.free.len(),
                self.spec.n_pages
            ));
        }
        let mut seen = vec![false; self.spec.n_pages];
        for &p in &self.free {
            if seen[p as usize] {
                return Err(format!("page {p} twice in free list"));
            }
            if self.refcount[p as usize] != 0 {
                return Err(format!("free page {p} has rc>0"));
            }
            seen[p as usize] = true;
        }
        Ok(())
    }
}

/// Pages needed to hold `tokens` at `page_tokens` granularity.
pub fn pages_for(tokens: usize, page_tokens: usize) -> usize {
    tokens.div_ceil(page_tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop;

    fn spec() -> PoolSpec {
        PoolSpec { n_pages: 16, page_tokens: 4, n_layers: 2, width: 8 }
    }

    #[test]
    fn alloc_release_cycle() {
        let mut pool = BlockPool::new(spec());
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(pool.used_pages(), 2);
        pool.retain(a);
        pool.release(a);
        assert_eq!(pool.used_pages(), 2); // still one ref on a
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.used_pages(), 0);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut pool = BlockPool::new(spec());
        let pages: Vec<_> = (0..16).map(|_| pool.alloc().unwrap()).collect();
        assert!(pool.alloc().is_none());
        assert_eq!(pool.alloc_failures(), 1);
        for p in pages {
            pool.release(p);
        }
        assert!(pool.alloc().is_some());
    }

    #[test]
    fn kv_slices_are_disjoint_and_writable() {
        let mut pool = BlockPool::new(spec());
        let p = pool.alloc().unwrap();
        for layer in 0..2 {
            for kv in 0..2 {
                let val = (layer * 2 + kv) as f32 + 1.0;
                pool.kv_slice_mut(p, layer, kv).fill(val);
            }
        }
        for layer in 0..2 {
            for kv in 0..2 {
                let val = (layer * 2 + kv) as f32 + 1.0;
                assert!(pool.kv_slice(p, layer, kv).iter().all(|&x| x == val));
            }
        }
    }

    #[test]
    fn page_data_covers_every_kv_slice_once() {
        // the migration snapshot unit must be exactly the page's kv
        // slices laid end to end, in (layer, k|v) order
        let mut pool = BlockPool::new(spec());
        let p = pool.alloc().unwrap();
        for layer in 0..2 {
            for kv in 0..2 {
                pool.kv_slice_mut(p, layer, kv).fill((layer * 2 + kv) as f32);
            }
        }
        let data: Vec<f32> = pool.page_data(p).to_vec();
        assert_eq!(data.len(), pool.spec().floats_per_page());
        let run = pool.spec().page_tokens * pool.spec().width;
        for layer in 0..2 {
            for kv in 0..2 {
                let off = (layer * 2 + kv) * run;
                assert!(data[off..off + run]
                    .iter()
                    .all(|&x| x == (layer * 2 + kv) as f32));
            }
        }
        // restoring into a different page round-trips
        let q = pool.alloc().unwrap();
        pool.page_data_mut(q).copy_from_slice(&data);
        for layer in 0..2 {
            for kv in 0..2 {
                assert_eq!(pool.kv_slice(q, layer, kv), pool.kv_slice(p, layer, kv));
            }
        }
        pool.release(p);
        pool.release(q);
    }

    #[test]
    fn geometry_math() {
        let s = PoolSpec { n_pages: 2, page_tokens: 16, n_layers: 4, width: 128 };
        assert_eq!(s.floats_per_page(), 4 * 2 * 16 * 128);
        assert_eq!(s.bytes_per_token(), 4 * 2 * 128 * 4);
    }

    #[test]
    fn prop_no_leaks_no_double_free() {
        // random interleavings of alloc / retain / release never break the
        // used+free partition or refcount bookkeeping
        prop::check("pool-alloc-fuzz", 64, |rng| {
            let mut pool = BlockPool::new(PoolSpec {
                n_pages: 8,
                page_tokens: 2,
                n_layers: 1,
                width: 4,
            });
            let mut live: Vec<PageId> = Vec::new(); // one entry per reference
            for _ in 0..200 {
                match rng.below(3) {
                    0 => {
                        if let Some(p) = pool.alloc() {
                            live.push(p);
                        } else {
                            prop_assert!(!live.is_empty(), "alloc failed on empty pool");
                        }
                    }
                    1 if !live.is_empty() => {
                        let p = live[rng.below(live.len())];
                        pool.retain(p);
                        live.push(p);
                    }
                    2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        let p = live.swap_remove(i);
                        pool.release(p);
                    }
                    _ => {}
                }
                pool.check_invariants().map_err(|e| e.to_string())?;
                // refcounts must equal outstanding references
                for p in 0..8u32 {
                    let expected = live.iter().filter(|&&q| q == p).count() as u32;
                    prop_assert!(
                        pool.refcount(p) == expected,
                        "page {p}: rc {} != refs {expected}",
                        pool.refcount(p)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pages_for_rounding() {
        assert_eq!(pages_for(0, 16), 0);
        assert_eq!(pages_for(1, 16), 1);
        assert_eq!(pages_for(16, 16), 1);
        assert_eq!(pages_for(17, 16), 2);
    }
}
