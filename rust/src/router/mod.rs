//! Request placement across the engine shard pool.
//!
//! With N independent engine shards, *where* a request lands determines
//! whether it can fork from cached pages: the DualRadixTree is shard-local,
//! so two agents sharing a context only reuse KV if they are co-located.
//! KVFlow (workflow-aware prefix caching) and TokenDance (collective KV
//! sharing across agents) both observe that placement, not capacity, is
//! what bounds the hit rate in multi-agent serving — this module encodes
//! that observation as a routing policy.
//!
//! Policies:
//!   - `Affinity` (default): hash a fingerprint of the request's shared
//!     prefix — the first `page_tokens`-aligned window of the prompt —
//!     mixed with the workflow `tag` onto a shard, so every agent forking
//!     the same context lands on the shard that already holds its bCache
//!     pages. When the affinity shard's queue grows past
//!     `imbalance_factor * (least-loaded depth + 1)`, the request spills
//!     to the least-loaded shard (capacity beats affinity under overload;
//!     the spilled request recomputes its prefix there).
//!   - `RoundRobin`: the placement-oblivious baseline — even load, no
//!     cache locality. Kept so benchmarks can isolate the affinity win.
//!
//! The *affinity function* is intentionally stateless about cache
//! contents: it never asks a shard what it holds. Affinity is a pure
//! function of the request, which keeps placement O(window) and makes
//! identical prompts land on the same shard across the whole process
//! lifetime.
//!
//! Layered on top of that pure function, the router hosts two small
//! pieces of *replication* state (owned and fed by the server, never
//! consulted by `place`/`place_spill` themselves):
//!   - [`ReplicaMap`]: prefix fingerprint → the set of shards known to
//!     hold a warm replica of that prefix, with an invalidation epoch
//!     that is bumped whenever the parent context grows. Fed by
//!     migration imports, replications, prefetch pins, and shard
//!     death/restart events. [`Router::place_spill_replicated`] uses it
//!     to prefer a warm replica holder over a cold least-loaded shard
//!     when a request must spill off its overloaded home.
//!   - [`ReadMostly`]: a per-prefix sliding window classifying a context
//!     as read-mostly (many forks, few extends) — the precondition for
//!     one-to-many replication, since a context that keeps growing would
//!     invalidate its replicas as fast as they are made.

#![warn(missing_docs)]

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::{fnv1a_from, FNV_OFFSET};

/// Bound on distinct prefixes tracked by [`ReplicaMap`] and
/// [`ReadMostly`]: both are advisory caches keyed by content
/// fingerprint, so forgetting a cold prefix costs at most one extra
/// replication round-trip later — never correctness.
const MAX_TRACKED_PREFIXES: usize = 4096;

/// How the server maps a request onto an engine shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle over shards regardless of content (baseline).
    RoundRobin,
    /// Prefix-affinity hashing with least-queue-depth spill.
    Affinity,
}

impl RoutePolicy {
    /// Parse a CLI/JSON policy name (`affinity`, `round_robin`/`rr`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "round-robin" | "round_robin" | "rr" => RoutePolicy::RoundRobin,
            "affinity" => RoutePolicy::Affinity,
            other => anyhow::bail!("unknown route policy {other:?}"),
        })
    }

    /// Canonical name as reported by `/metrics` (`route` field).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::Affinity => "affinity",
        }
    }
}

/// Places requests onto `shards` engine shards (see module docs).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    shards: usize,
    /// affinity fingerprint window (one cache page of tokens): requests
    /// that would share their first bCache page share their home shard
    page_tokens: usize,
    /// spill threshold: the request leaves its affinity shard once that
    /// shard's in-flight depth exceeds `imbalance_factor * (min_depth + 1)`
    imbalance_factor: f64,
    rr: AtomicUsize,
}

impl Router {
    /// Router over `shards` peer shards. `page_tokens` sizes the affinity
    /// fingerprint window; `imbalance_factor` (≥ 1) sets the spill rule.
    pub fn new(
        policy: RoutePolicy,
        shards: usize,
        page_tokens: usize,
        imbalance_factor: f64,
    ) -> Self {
        assert!(shards > 0, "router needs at least one shard");
        assert!(page_tokens > 0, "page_tokens must be > 0");
        assert!(
            imbalance_factor >= 1.0,
            "imbalance_factor < 1 would spill even from an idle shard"
        );
        Router {
            policy,
            shards,
            page_tokens,
            imbalance_factor,
            rr: AtomicUsize::new(0),
        }
    }

    /// Content fingerprint: FNV-1a over the first `page_tokens` prompt
    /// tokens (the first page-aligned window — exactly the granularity at
    /// which the radix trees share pages) mixed with the workflow tag.
    /// Prompts that fork the same context agree on this window, so they
    /// agree on the fingerprint; divergence later in the prompt (agent
    /// instructions, prior outputs) does not scatter the workflow.
    pub fn fingerprint(&self, tokens: &[u32], tag: u64) -> u64 {
        let window = &tokens[..tokens.len().min(self.page_tokens)];
        fnv1a_from(
            FNV_OFFSET ^ tag.wrapping_mul(0x9E3779B97F4A7C15),
            window.iter().flat_map(|t| t.to_le_bytes()),
        )
    }

    /// The shard this request's prefix hashes to, ignoring load.
    pub fn affinity_shard(&self, tokens: &[u32], tag: u64) -> usize {
        (self.fingerprint(tokens, tag) % self.shards as u64) as usize
    }

    /// Successor home-shard resolution for cross-step prefetch: the
    /// shard an affinity-routed request with this known prefix (and this
    /// workflow tag) will land on, so its pages can be pre-warmed there
    /// before the request exists. `None` under round-robin, where
    /// placement ignores content and there is no home worth warming.
    pub fn prefetch_home(&self, tokens: &[u32], tag: u64) -> Option<usize> {
        match self.policy {
            RoutePolicy::Affinity => Some(self.affinity_shard(tokens, tag)),
            RoutePolicy::RoundRobin => None,
        }
    }

    /// Place one request. `depths[i]` is shard i's current in-flight
    /// request count (the server's load signal).
    pub fn place(&self, tokens: &[u32], tag: u64, depths: &[usize]) -> usize {
        self.place_spill(tokens, tag, depths).shard
    }

    /// Like [`Router::place`], but reports *why*: when an affinity
    /// request spills off an overloaded home shard, `spilled_from` names
    /// the home — the shard that (probably) holds the request's cached
    /// pages, and therefore the source the migration subsystem should
    /// probe. Round-robin placement never reports a spill (there is no
    /// home to migrate from).
    // analyze:allow(panic_path, fn) home comes from affinity_shard (mod self.shards) and depths.len() == self.shards per the debug_assert contract
    pub fn place_spill(&self, tokens: &[u32], tag: u64, depths: &[usize]) -> Placement {
        debug_assert_eq!(depths.len(), self.shards);
        match self.policy {
            RoutePolicy::RoundRobin => Placement {
                shard: self.rr.fetch_add(1, Ordering::Relaxed) % self.shards,
                spilled_from: None,
            },
            RoutePolicy::Affinity => {
                let home = self.affinity_shard(tokens, tag);
                let min = depths.iter().copied().min().unwrap_or(0);
                // the +1 keeps the rule meaningful when the pool is idle:
                // a depth-1 home shard is never "overloaded" vs depth 0
                if (depths[home] as f64) > self.imbalance_factor * (min as f64 + 1.0) {
                    let shard = depths
                        .iter()
                        .enumerate()
                        .min_by_key(|&(_, &d)| d)
                        .map(|(i, _)| i)
                        .unwrap_or(home);
                    Placement {
                        shard,
                        spilled_from: (shard != home).then_some(home),
                    }
                } else {
                    Placement { shard: home, spilled_from: None }
                }
            }
        }
    }

    /// Like [`Router::place_spill`], but when the request must spill off
    /// its overloaded home shard, prefer a shard from `holders` — the
    /// replica holders of this prefix per the server's [`ReplicaMap`] —
    /// over the cold least-loaded shard. A holder is eligible only if it
    /// is not the home itself and its own depth is under the spill
    /// threshold (a holder more overloaded than the rule allows is no
    /// refuge); ties break to the least-loaded eligible holder, then the
    /// lowest index. With no eligible holder the plain spill decision
    /// stands. Non-spill placements (and round-robin) are returned
    /// unchanged: replicas only ever redirect load that was already
    /// leaving home.
    // analyze:allow(panic_path, fn) every depths[h] is behind the h < depths.len() filter in the same chain
    pub fn place_spill_replicated(
        &self,
        tokens: &[u32],
        tag: u64,
        depths: &[usize],
        holders: &[usize],
    ) -> Placement {
        let p = self.place_spill(tokens, tag, depths);
        let Some(home) = p.spilled_from else { return p };
        let min = depths.iter().copied().min().unwrap_or(0);
        let limit = self.imbalance_factor * (min as f64 + 1.0);
        let best = holders
            .iter()
            .copied()
            .filter(|&h| h < depths.len() && h != home && (depths[h] as f64) <= limit)
            .min_by_key(|&h| (depths[h], h));
        match best {
            Some(shard) => Placement { shard, spilled_from: Some(home) },
            None => p,
        }
    }
}

/// A routing decision plus its spill provenance (see
/// [`Router::place_spill`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// the shard this request should be submitted to
    pub shard: usize,
    /// the overloaded home shard this request was spilled away from
    pub spilled_from: Option<usize>,
}

#[derive(Debug, Default, Clone)]
struct ReplicaEntry {
    /// bumped on every invalidation; a bumped epoch means any replica
    /// shipped under the previous epoch is stale (parent context grew)
    epoch: u64,
    holders: BTreeSet<usize>,
}

/// Prefix fingerprint → set of shards believed to hold a warm replica.
///
/// Purely advisory book-keeping: the authoritative truth about what a
/// shard holds stays inside that shard's engine, and every routing
/// decision taken from this map is verified against the target shard
/// (a probe) before the migration step is skipped. The map therefore
/// only has to be *conservative about liveness* — a dead shard must
/// never appear in a resident set — while staleness about contents is
/// tolerated and repaired on use.
///
/// Invariants (checked by [`ReplicaMap::check_invariants`], exercised by
/// the `replica-map-invariants` property test):
///   - no dead shard appears in any resident set
///   - an invalidated (epoch-bumped) prefix has an empty resident set
///     until something re-registers under the new epoch
///   - [`ReplicaMap::unregister`] is idempotent
///   - every resident set's size is ≤ the number of live shards
#[derive(Debug)]
pub struct ReplicaMap {
    shards: usize,
    live: Vec<bool>,
    entries: HashMap<u64, ReplicaEntry>,
    /// first-insertion order, for bounded-size eviction
    order: VecDeque<u64>,
}

impl ReplicaMap {
    /// Empty map over `shards` peer shards, all initially live.
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0, "replica map needs at least one shard");
        ReplicaMap {
            shards,
            live: vec![true; shards],
            entries: HashMap::new(),
            order: VecDeque::new(),
        }
    }

    fn entry_mut(&mut self, fp: u64) -> &mut ReplicaEntry {
        if !self.entries.contains_key(&fp) {
            if self.entries.len() >= MAX_TRACKED_PREFIXES {
                // forget the oldest tracked prefix; each fp appears in
                // `order` exactly once (pushed on first insert only)
                if let Some(old) = self.order.pop_front() {
                    self.entries.remove(&old);
                }
            }
            self.order.push_back(fp);
        }
        self.entries.entry(fp).or_default()
    }

    /// Record that `shard` now holds a warm replica of `fp`. No-op for
    /// an out-of-range or dead shard (a registration racing a crash must
    /// lose: the death event has already stripped the shard).
    // analyze:allow(panic_path, fn) live[shard] sits behind the shard >= self.shards early return; live.len() == self.shards by construction
    pub fn register(&mut self, fp: u64, shard: usize) {
        if shard >= self.shards || !self.live[shard] {
            return;
        }
        self.entry_mut(fp).holders.insert(shard);
    }

    /// Drop `shard` from `fp`'s resident set (replica evicted or demoted
    /// off-device). Idempotent: unregistering an absent pair is a no-op.
    pub fn unregister(&mut self, fp: u64, shard: usize) {
        if let Some(e) = self.entries.get_mut(&fp) {
            e.holders.remove(&shard);
        }
    }

    /// The parent context grew (an extend event): every replica of the
    /// old, shorter prefix is now stale. Clears the resident set, bumps
    /// the epoch, and returns how many holders were invalidated.
    pub fn invalidate(&mut self, fp: u64) -> usize {
        let e = self.entry_mut(fp);
        let cleared = e.holders.len();
        e.holders.clear();
        e.epoch += 1;
        cleared
    }

    /// Current invalidation epoch for `fp` (0 if never tracked).
    pub fn epoch(&self, fp: u64) -> u64 {
        self.entries.get(&fp).map_or(0, |e| e.epoch)
    }

    /// Shards currently believed to hold a warm replica of `fp`,
    /// ascending. Empty when untracked or invalidated.
    pub fn holders(&self, fp: u64) -> Vec<usize> {
        self.entries
            .get(&fp)
            .map(|e| e.holders.iter().copied().collect())
            .unwrap_or_default()
    }

    /// `shard` died (poisoned/crashed): mark it dead and strip it from
    /// every resident set. Until [`ReplicaMap::shard_restarted`], any
    /// [`ReplicaMap::register`] for it is refused.
    // analyze:allow(panic_path, fn) live[shard] sits behind the shard >= self.shards early return; live.len() == self.shards by construction
    pub fn shard_dead(&mut self, shard: usize) {
        if shard >= self.shards {
            return;
        }
        self.live[shard] = false;
        for e in self.entries.values_mut() {
            e.holders.remove(&shard);
        }
    }

    /// `shard` came back from a restart: live again, but holding nothing
    /// (a restarted shard restores session metadata, not replica pages —
    /// replicas must be re-shipped and re-registered).
    // analyze:allow(panic_path, fn) live[shard] sits behind the shard >= self.shards early return; live.len() == self.shards by construction
    pub fn shard_restarted(&mut self, shard: usize) {
        if shard >= self.shards {
            return;
        }
        self.live[shard] = true;
        // defensive: death already stripped it, but restart must never
        // resurrect holders from a pre-death registration
        for e in self.entries.values_mut() {
            e.holders.remove(&shard);
        }
    }

    /// How many tracked prefixes each shard currently holds a replica
    /// of — the rebalancer's "hot replica" weight per shard.
    // analyze:allow(panic_path, fn) register() refuses out-of-range shards, so every resident holder is < self.shards == counts.len()
    pub fn holder_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shards];
        for e in self.entries.values() {
            for &s in &e.holders {
                counts[s] += 1;
            }
        }
        counts
    }

    /// Number of prefixes currently tracked (registered or invalidated).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no prefix is tracked.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Verify the structural invariants listed in the type docs.
    /// Returns a description of the first violation found.
    // analyze:allow(panic_path, fn) live[s] is only reached after the s >= self.shards violation check above it
    pub fn check_invariants(&self) -> Result<(), String> {
        let live_count = self.live.iter().filter(|&&l| l).count();
        for (fp, e) in &self.entries {
            for &s in &e.holders {
                if s >= self.shards {
                    return Err(format!("fp {fp:#x}: holder {s} out of range"));
                }
                if !self.live[s] {
                    return Err(format!("fp {fp:#x}: dead shard {s} in resident set"));
                }
            }
            if e.holders.len() > live_count {
                return Err(format!(
                    "fp {fp:#x}: {} holders > {live_count} live shards",
                    e.holders.len()
                ));
            }
        }
        if self.entries.len() > MAX_TRACKED_PREFIXES {
            return Err(format!("{} entries exceed the tracking cap", self.entries.len()));
        }
        Ok(())
    }
}

#[derive(Debug, Default)]
struct ReadMostlyEntry {
    /// sliding window of events, `true` = extend (context grew)
    events: VecDeque<bool>,
    /// longest prompt length seen for this prefix so far
    hi_len: usize,
}

/// Per-prefix fork-vs-extend classifier over a sliding window.
///
/// A workflow's shared context is worth replicating only if it is
/// *read-mostly*: many agents fork from it (same length, divergent
/// tails) while the parent rarely grows. Each observed request is
/// classified as an **extend** when its prompt is more than `slack`
/// tokens longer than the longest previously seen for the prefix
/// (`slack` absorbs the agents' small unique suffixes — one page of
/// tokens in practice), else a **fork**. A prefix is read-mostly once
/// its window holds at least `min_forks` forks and extends are at most
/// a quarter of the window.
#[derive(Debug)]
pub struct ReadMostly {
    window: usize,
    min_forks: usize,
    slack: usize,
    entries: HashMap<u64, ReadMostlyEntry>,
    order: VecDeque<u64>,
}

impl ReadMostly {
    /// Classifier with a per-prefix window of `window` events, requiring
    /// `min_forks` forks, treating growth ≤ `slack` tokens as noise.
    pub fn new(window: usize, min_forks: usize, slack: usize) -> Self {
        assert!(window > 0, "read-mostly window must be > 0");
        ReadMostly {
            window,
            min_forks,
            slack,
            entries: HashMap::new(),
            order: VecDeque::new(),
        }
    }

    /// Record one request against prefix `fp` with prompt length
    /// `token_len`. Returns `true` when the event is an **extend** —
    /// the caller's cue to invalidate replicas of the old prefix.
    pub fn observe(&mut self, fp: u64, token_len: usize) -> bool {
        if !self.entries.contains_key(&fp) {
            if self.entries.len() >= MAX_TRACKED_PREFIXES {
                if let Some(old) = self.order.pop_front() {
                    self.entries.remove(&old);
                }
            }
            self.order.push_back(fp);
        }
        let window = self.window;
        let slack = self.slack;
        let e = self.entries.entry(fp).or_default();
        let extend = e.hi_len > 0 && token_len > e.hi_len + slack;
        e.hi_len = e.hi_len.max(token_len);
        e.events.push_back(extend);
        while e.events.len() > window {
            e.events.pop_front();
        }
        extend
    }

    /// Is `fp` currently classified read-mostly? (See type docs for the
    /// rule.) Unknown prefixes are not.
    pub fn is_read_mostly(&self, fp: u64) -> bool {
        let Some(e) = self.entries.get(&fp) else { return false };
        let extends = e.events.iter().filter(|&&x| x).count();
        let forks = e.events.len() - extends;
        forks >= self.min_forks && extends * 4 <= e.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affinity(shards: usize) -> Router {
        Router::new(RoutePolicy::Affinity, shards, 16, 2.0)
    }

    #[test]
    fn policy_parsing_and_names() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            RoutePolicy::parse("round-robin").unwrap(),
            RoutePolicy::RoundRobin
        );
        assert_eq!(RoutePolicy::parse("affinity").unwrap(), RoutePolicy::Affinity);
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::Affinity.name(), "affinity");
        assert_eq!(RoutePolicy::RoundRobin.name(), "round_robin");
    }

    #[test]
    fn round_robin_cycles_evenly() {
        let r = Router::new(RoutePolicy::RoundRobin, 3, 16, 2.0);
        let depths = [0usize; 3];
        let seq: Vec<usize> = (0..6).map(|_| r.place(&[1, 2, 3], 0, &depths)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn prop_identical_prompts_always_colocate_under_affinity() {
        // the affinity invariant: placement is a pure function of
        // (prefix window, tag) whenever no shard is overloaded — the
        // round-robin counter, prompt tail, and balanced queue depths
        // must all be irrelevant
        crate::util::prop::check("router-affinity-stable", 64, |rng| {
            let shards = 2 + rng.below(7);
            let r = affinity(shards);
            let len = 1 + rng.below(200);
            let tokens = rng.tokens(len, 2048);
            let tag = rng.next_u64() % 32;
            let depth = rng.below(4);
            let depths = vec![depth; shards];
            let first = r.place(&tokens, tag, &depths);
            // same prompt, different tail beyond the fingerprint window
            let mut longer = tokens.clone();
            longer.extend(rng.tokens(1 + rng.below(50), 2048));
            for _ in 0..8 {
                let again = r.place(&tokens, tag, &depths);
                if again != first {
                    return Err(format!("placement moved {first} -> {again}"));
                }
            }
            if tokens.len() >= 16 {
                let tail = r.place(&longer, tag, &depths);
                if tail != first {
                    return Err(format!(
                        "tail divergence changed placement {first} -> {tail}"
                    ));
                }
            }
            // a fresh router agrees: no hidden state in the fingerprint
            let r2 = affinity(shards);
            if r2.place(&tokens, tag, &depths) != first {
                return Err("fresh router disagrees with original".into());
            }
            Ok(())
        });
    }

    #[test]
    fn overload_spills_to_least_loaded_shard() {
        let r = affinity(4);
        let tokens: Vec<u32> = (10..40).collect();
        let home = r.affinity_shard(&tokens, 7);
        // balanced: stays home
        assert_eq!(r.place(&tokens, 7, &[1, 1, 1, 1]), home);
        // mildly imbalanced (within factor 2 of min+1): still home
        let mut depths = [0usize; 4];
        depths[home] = 2;
        assert_eq!(r.place(&tokens, 7, &depths), home);
        // overloaded: spills to the least-loaded shard, not just "not home"
        let mut depths = [5usize, 6, 7, 8];
        depths[home] = 20;
        let spilled = r.place(&tokens, 7, &depths);
        assert_ne!(spilled, home);
        assert_eq!(
            depths[spilled],
            *depths
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != home)
                .map(|(_, d)| d)
                .min()
                .unwrap()
        );
        // the spill-aware variant agrees on the shard and names the home
        let p = r.place_spill(&tokens, 7, &depths);
        assert_eq!(p.shard, spilled);
        assert_eq!(p.spilled_from, Some(home));
        // a balanced pool reports no spill
        let p = r.place_spill(&tokens, 7, &[1, 1, 1, 1]);
        assert_eq!(p, Placement { shard: home, spilled_from: None });
        // round-robin never has a home to spill from
        let rr = Router::new(RoutePolicy::RoundRobin, 4, 16, 2.0);
        assert_eq!(rr.place_spill(&tokens, 7, &depths).spilled_from, None);
    }

    #[test]
    fn prop_spill_only_when_home_is_overloaded() {
        crate::util::prop::check("router-spill-rule", 64, |rng| {
            let shards = 2 + rng.below(6);
            let r = affinity(shards);
            let tokens = rng.tokens(1 + rng.below(64), 2048);
            let tag = rng.next_u64();
            let depths: Vec<usize> = (0..shards).map(|_| rng.below(12)).collect();
            let home = r.affinity_shard(&tokens, tag);
            let min = *depths.iter().min().unwrap();
            let placed = r.place(&tokens, tag, &depths);
            let overloaded = depths[home] as f64 > 2.0 * (min as f64 + 1.0);
            if overloaded {
                if depths[placed] != min {
                    return Err(format!(
                        "overloaded home {home} (depth {}) spilled to {placed} \
                         (depth {}) which is not least-loaded (min {min})",
                        depths[home], depths[placed]
                    ));
                }
            } else if placed != home {
                return Err(format!(
                    "home {home} (depth {}, min {min}) not overloaded but \
                     request went to {placed}",
                    depths[home]
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn distinct_tags_separate_identical_prefixes() {
        // tag participates in the fingerprint: two workflows that happen
        // to share opening tokens can still be spread apart
        let r = affinity(8);
        let tokens = Rng::seeded(3).tokens(32, 2048);
        let spread: std::collections::HashSet<usize> =
            (0..32).map(|tag| r.affinity_shard(&tokens, tag)).collect();
        assert!(spread.len() > 1, "all 32 tags landed on one shard");
    }

    #[test]
    fn spill_prefers_replica_holder_over_cold_target() {
        let r = affinity(4);
        let tokens: Vec<u32> = (10..40).collect();
        let home = r.affinity_shard(&tokens, 7);
        let mut depths = [2usize, 3, 2, 3];
        depths[home] = 20; // forced spill
        let plain = r.place_spill(&tokens, 7, &depths);
        assert_eq!(plain.spilled_from, Some(home));
        // a holder that is not the cold least-loaded shard: pick it
        let holder = (0..4).find(|&s| s != home && s != plain.shard).unwrap();
        let p = r.place_spill_replicated(&tokens, 7, &depths, &[holder]);
        assert_eq!(p, Placement { shard: holder, spilled_from: Some(home) });
        // the home itself as the only holder is useless: fall back
        let p = r.place_spill_replicated(&tokens, 7, &depths, &[home]);
        assert_eq!(p, plain);
        // a holder that is itself past the spill threshold is no refuge
        let mut hot = depths;
        hot[holder] = 25;
        let p = r.place_spill_replicated(&tokens, 7, &hot, &[holder]);
        assert_eq!(p.shard, r.place_spill(&tokens, 7, &hot).shard);
        // no spill means holders are irrelevant (affinity stays sticky)
        let p = r.place_spill_replicated(&tokens, 7, &[1, 1, 1, 1], &[holder]);
        assert_eq!(p, Placement { shard: home, spilled_from: None });
        // least-loaded eligible holder wins among several
        let mut depths = [4usize, 4, 4, 4];
        depths[home] = 30;
        let others: Vec<usize> = (0..4).filter(|&s| s != home).collect();
        let mut uneven = depths;
        uneven[others[1]] = 1;
        let p = r.place_spill_replicated(&tokens, 7, &uneven, &others);
        assert_eq!(p.shard, others[1]);
    }

    #[test]
    fn replica_map_register_invalidate_death_cycle() {
        let mut m = ReplicaMap::new(4);
        assert!(m.is_empty());
        m.register(0xBEEF, 1);
        m.register(0xBEEF, 2);
        m.register(0xBEEF, 2); // duplicate registration is a no-op
        m.register(0xBEEF, 9); // out of range: refused
        assert_eq!(m.holders(0xBEEF), vec![1, 2]);
        assert_eq!(m.holder_counts(), vec![0, 1, 1, 0]);
        assert_eq!(m.len(), 1);

        // parent context grew: epoch bump clears every holder
        assert_eq!(m.epoch(0xBEEF), 0);
        assert_eq!(m.invalidate(0xBEEF), 2);
        assert_eq!(m.epoch(0xBEEF), 1);
        assert!(m.holders(0xBEEF).is_empty());

        // death strips the shard everywhere and refuses re-registration
        m.register(0xBEEF, 3);
        m.register(0xF00D, 3);
        m.shard_dead(3);
        assert!(m.holders(0xBEEF).is_empty());
        assert!(m.holders(0xF00D).is_empty());
        m.register(0xF00D, 3); // dead: refused
        assert!(m.holders(0xF00D).is_empty());

        // restart: live again but holding nothing until re-registered
        m.shard_restarted(3);
        assert!(m.holders(0xF00D).is_empty());
        m.register(0xF00D, 3);
        assert_eq!(m.holders(0xF00D), vec![3]);

        // unregister is idempotent
        m.unregister(0xF00D, 3);
        m.unregister(0xF00D, 3);
        m.unregister(0xDEAD, 0); // never-tracked prefix: no-op
        assert!(m.holders(0xF00D).is_empty());
        m.check_invariants().unwrap();
    }

    #[test]
    fn replica_map_tracking_is_bounded() {
        let mut m = ReplicaMap::new(2);
        for fp in 0..(MAX_TRACKED_PREFIXES as u64 + 100) {
            m.register(fp, 1);
        }
        assert_eq!(m.len(), MAX_TRACKED_PREFIXES);
        // oldest forgotten, newest retained
        assert!(m.holders(0).is_empty());
        assert_eq!(m.holders(MAX_TRACKED_PREFIXES as u64 + 99), vec![1]);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prop_replica_map_invariants_under_random_events() {
        // satellite: random register/invalidate/unregister/shard-death/
        // restart sequences must preserve the documented invariants
        crate::util::prop::check("replica-map-invariants", 128, |rng| {
            let shards = 2 + rng.below(7);
            let mut m = ReplicaMap::new(shards);
            let mut live = vec![true; shards];
            let fps: Vec<u64> = (0..(1 + rng.below(8))).map(|_| rng.next_u64()).collect();
            for _ in 0..200 {
                let fp = fps[rng.below(fps.len())];
                let shard = rng.below(shards + 1); // sometimes out of range
                match rng.below(6) {
                    0 | 1 => m.register(fp, shard),
                    2 => {
                        m.unregister(fp, shard);
                        let snap = m.holders(fp);
                        m.unregister(fp, shard); // idempotent
                        if m.holders(fp) != snap {
                            return Err("second unregister changed the set".into());
                        }
                    }
                    3 => {
                        let before = m.epoch(fp);
                        m.invalidate(fp);
                        if m.epoch(fp) != before + 1 {
                            return Err("invalidate did not bump the epoch".into());
                        }
                        if !m.holders(fp).is_empty() {
                            return Err("invalidated prefix kept holders".into());
                        }
                    }
                    4 => {
                        if shard < shards {
                            live[shard] = false;
                        }
                        m.shard_dead(shard);
                    }
                    _ => {
                        if shard < shards {
                            live[shard] = true;
                        }
                        m.shard_restarted(shard);
                    }
                }
                m.check_invariants()?;
                // mirror-model check: no dead shard in any resident set
                for &f in &fps {
                    for h in m.holders(f) {
                        if !live[h] {
                            return Err(format!("dead shard {h} resident for {f:#x}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn read_mostly_detector_classifies_forks_vs_extends() {
        let mut d = ReadMostly::new(32, 4, 16);
        let fp = 0xABu64;
        // first sight is never an extend, and too few forks yet
        assert!(!d.observe(fp, 200));
        assert!(!d.is_read_mostly(fp));
        // agents forking: same base length, small unique tails (≤ slack)
        for i in 0..5 {
            assert!(!d.observe(fp, 200 + i), "fork misread as extend");
        }
        assert!(d.is_read_mostly(fp), "5 forks, 0 extends must qualify");
        // the parent context grows past the slack: an extend
        assert!(d.observe(fp, 400));
        assert!(d.is_read_mostly(fp), "1 extend in 7 events still ≤ 25%");
        // a write-heavy prefix never qualifies
        let wr = 0xCDu64;
        for i in 0..10 {
            d.observe(wr, 100 + i * 50);
        }
        assert!(!d.is_read_mostly(wr), "every event an extend");
        // unknown prefixes are not read-mostly
        assert!(!d.is_read_mostly(0xEF));
        // window slides: ancient extends age out
        let mut d = ReadMostly::new(4, 2, 16);
        let fp = 0x11u64;
        d.observe(fp, 100);
        d.observe(fp, 500); // extend
        for _ in 0..4 {
            d.observe(fp, 500); // forks push the extend out of the window
        }
        assert!(d.is_read_mostly(fp));
    }
}
