//! Request placement across the engine shard pool.
//!
//! With N independent engine shards, *where* a request lands determines
//! whether it can fork from cached pages: the DualRadixTree is shard-local,
//! so two agents sharing a context only reuse KV if they are co-located.
//! KVFlow (workflow-aware prefix caching) and TokenDance (collective KV
//! sharing across agents) both observe that placement, not capacity, is
//! what bounds the hit rate in multi-agent serving — this module encodes
//! that observation as a routing policy.
//!
//! Policies:
//!   - `Affinity` (default): hash a fingerprint of the request's shared
//!     prefix — the first `page_tokens`-aligned window of the prompt —
//!     mixed with the workflow `tag` onto a shard, so every agent forking
//!     the same context lands on the shard that already holds its bCache
//!     pages. When the affinity shard's queue grows past
//!     `imbalance_factor * (least-loaded depth + 1)`, the request spills
//!     to the least-loaded shard (capacity beats affinity under overload;
//!     the spilled request recomputes its prefix there).
//!   - `RoundRobin`: the placement-oblivious baseline — even load, no
//!     cache locality. Kept so benchmarks can isolate the affinity win.
//!
//! The router is intentionally stateless about cache *contents*: it never
//! asks a shard what it holds. Affinity is a pure function of the request,
//! which keeps placement O(window) and makes identical prompts land on the
//! same shard across the whole process lifetime.

#![warn(missing_docs)]

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::{fnv1a_from, FNV_OFFSET};

/// How the server maps a request onto an engine shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle over shards regardless of content (baseline).
    RoundRobin,
    /// Prefix-affinity hashing with least-queue-depth spill.
    Affinity,
}

impl RoutePolicy {
    /// Parse a CLI/JSON policy name (`affinity`, `round_robin`/`rr`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "round-robin" | "round_robin" | "rr" => RoutePolicy::RoundRobin,
            "affinity" => RoutePolicy::Affinity,
            other => anyhow::bail!("unknown route policy {other:?}"),
        })
    }

    /// Canonical name as reported by `/metrics` (`route` field).
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::Affinity => "affinity",
        }
    }
}

/// Places requests onto `shards` engine shards (see module docs).
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    shards: usize,
    /// affinity fingerprint window (one cache page of tokens): requests
    /// that would share their first bCache page share their home shard
    page_tokens: usize,
    /// spill threshold: the request leaves its affinity shard once that
    /// shard's in-flight depth exceeds `imbalance_factor * (min_depth + 1)`
    imbalance_factor: f64,
    rr: AtomicUsize,
}

impl Router {
    /// Router over `shards` peer shards. `page_tokens` sizes the affinity
    /// fingerprint window; `imbalance_factor` (≥ 1) sets the spill rule.
    pub fn new(
        policy: RoutePolicy,
        shards: usize,
        page_tokens: usize,
        imbalance_factor: f64,
    ) -> Self {
        assert!(shards > 0, "router needs at least one shard");
        assert!(page_tokens > 0, "page_tokens must be > 0");
        assert!(
            imbalance_factor >= 1.0,
            "imbalance_factor < 1 would spill even from an idle shard"
        );
        Router {
            policy,
            shards,
            page_tokens,
            imbalance_factor,
            rr: AtomicUsize::new(0),
        }
    }

    /// Content fingerprint: FNV-1a over the first `page_tokens` prompt
    /// tokens (the first page-aligned window — exactly the granularity at
    /// which the radix trees share pages) mixed with the workflow tag.
    /// Prompts that fork the same context agree on this window, so they
    /// agree on the fingerprint; divergence later in the prompt (agent
    /// instructions, prior outputs) does not scatter the workflow.
    pub fn fingerprint(&self, tokens: &[u32], tag: u64) -> u64 {
        let window = &tokens[..tokens.len().min(self.page_tokens)];
        fnv1a_from(
            FNV_OFFSET ^ tag.wrapping_mul(0x9E3779B97F4A7C15),
            window.iter().flat_map(|t| t.to_le_bytes()),
        )
    }

    /// The shard this request's prefix hashes to, ignoring load.
    pub fn affinity_shard(&self, tokens: &[u32], tag: u64) -> usize {
        (self.fingerprint(tokens, tag) % self.shards as u64) as usize
    }

    /// Successor home-shard resolution for cross-step prefetch: the
    /// shard an affinity-routed request with this known prefix (and this
    /// workflow tag) will land on, so its pages can be pre-warmed there
    /// before the request exists. `None` under round-robin, where
    /// placement ignores content and there is no home worth warming.
    pub fn prefetch_home(&self, tokens: &[u32], tag: u64) -> Option<usize> {
        match self.policy {
            RoutePolicy::Affinity => Some(self.affinity_shard(tokens, tag)),
            RoutePolicy::RoundRobin => None,
        }
    }

    /// Place one request. `depths[i]` is shard i's current in-flight
    /// request count (the server's load signal).
    pub fn place(&self, tokens: &[u32], tag: u64, depths: &[usize]) -> usize {
        self.place_spill(tokens, tag, depths).shard
    }

    /// Like [`Router::place`], but reports *why*: when an affinity
    /// request spills off an overloaded home shard, `spilled_from` names
    /// the home — the shard that (probably) holds the request's cached
    /// pages, and therefore the source the migration subsystem should
    /// probe. Round-robin placement never reports a spill (there is no
    /// home to migrate from).
    pub fn place_spill(&self, tokens: &[u32], tag: u64, depths: &[usize]) -> Placement {
        debug_assert_eq!(depths.len(), self.shards);
        match self.policy {
            RoutePolicy::RoundRobin => Placement {
                shard: self.rr.fetch_add(1, Ordering::Relaxed) % self.shards,
                spilled_from: None,
            },
            RoutePolicy::Affinity => {
                let home = self.affinity_shard(tokens, tag);
                let min = depths.iter().copied().min().unwrap_or(0);
                // the +1 keeps the rule meaningful when the pool is idle:
                // a depth-1 home shard is never "overloaded" vs depth 0
                if (depths[home] as f64) > self.imbalance_factor * (min as f64 + 1.0) {
                    let shard = depths
                        .iter()
                        .enumerate()
                        .min_by_key(|&(_, &d)| d)
                        .map(|(i, _)| i)
                        .unwrap_or(home);
                    Placement {
                        shard,
                        spilled_from: (shard != home).then_some(home),
                    }
                } else {
                    Placement { shard: home, spilled_from: None }
                }
            }
        }
    }
}

/// A routing decision plus its spill provenance (see
/// [`Router::place_spill`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// the shard this request should be submitted to
    pub shard: usize,
    /// the overloaded home shard this request was spilled away from
    pub spilled_from: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affinity(shards: usize) -> Router {
        Router::new(RoutePolicy::Affinity, shards, 16, 2.0)
    }

    #[test]
    fn policy_parsing_and_names() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(
            RoutePolicy::parse("round-robin").unwrap(),
            RoutePolicy::RoundRobin
        );
        assert_eq!(RoutePolicy::parse("affinity").unwrap(), RoutePolicy::Affinity);
        assert!(RoutePolicy::parse("random").is_err());
        assert_eq!(RoutePolicy::Affinity.name(), "affinity");
        assert_eq!(RoutePolicy::RoundRobin.name(), "round_robin");
    }

    #[test]
    fn round_robin_cycles_evenly() {
        let r = Router::new(RoutePolicy::RoundRobin, 3, 16, 2.0);
        let depths = [0usize; 3];
        let seq: Vec<usize> = (0..6).map(|_| r.place(&[1, 2, 3], 0, &depths)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn prop_identical_prompts_always_colocate_under_affinity() {
        // the affinity invariant: placement is a pure function of
        // (prefix window, tag) whenever no shard is overloaded — the
        // round-robin counter, prompt tail, and balanced queue depths
        // must all be irrelevant
        crate::util::prop::check("router-affinity-stable", 64, |rng| {
            let shards = 2 + rng.below(7);
            let r = affinity(shards);
            let len = 1 + rng.below(200);
            let tokens = rng.tokens(len, 2048);
            let tag = rng.next_u64() % 32;
            let depth = rng.below(4);
            let depths = vec![depth; shards];
            let first = r.place(&tokens, tag, &depths);
            // same prompt, different tail beyond the fingerprint window
            let mut longer = tokens.clone();
            longer.extend(rng.tokens(1 + rng.below(50), 2048));
            for _ in 0..8 {
                let again = r.place(&tokens, tag, &depths);
                if again != first {
                    return Err(format!("placement moved {first} -> {again}"));
                }
            }
            if tokens.len() >= 16 {
                let tail = r.place(&longer, tag, &depths);
                if tail != first {
                    return Err(format!(
                        "tail divergence changed placement {first} -> {tail}"
                    ));
                }
            }
            // a fresh router agrees: no hidden state in the fingerprint
            let r2 = affinity(shards);
            if r2.place(&tokens, tag, &depths) != first {
                return Err("fresh router disagrees with original".into());
            }
            Ok(())
        });
    }

    #[test]
    fn overload_spills_to_least_loaded_shard() {
        let r = affinity(4);
        let tokens: Vec<u32> = (10..40).collect();
        let home = r.affinity_shard(&tokens, 7);
        // balanced: stays home
        assert_eq!(r.place(&tokens, 7, &[1, 1, 1, 1]), home);
        // mildly imbalanced (within factor 2 of min+1): still home
        let mut depths = [0usize; 4];
        depths[home] = 2;
        assert_eq!(r.place(&tokens, 7, &depths), home);
        // overloaded: spills to the least-loaded shard, not just "not home"
        let mut depths = [5usize, 6, 7, 8];
        depths[home] = 20;
        let spilled = r.place(&tokens, 7, &depths);
        assert_ne!(spilled, home);
        assert_eq!(
            depths[spilled],
            *depths
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != home)
                .map(|(_, d)| d)
                .min()
                .unwrap()
        );
        // the spill-aware variant agrees on the shard and names the home
        let p = r.place_spill(&tokens, 7, &depths);
        assert_eq!(p.shard, spilled);
        assert_eq!(p.spilled_from, Some(home));
        // a balanced pool reports no spill
        let p = r.place_spill(&tokens, 7, &[1, 1, 1, 1]);
        assert_eq!(p, Placement { shard: home, spilled_from: None });
        // round-robin never has a home to spill from
        let rr = Router::new(RoutePolicy::RoundRobin, 4, 16, 2.0);
        assert_eq!(rr.place_spill(&tokens, 7, &depths).spilled_from, None);
    }

    #[test]
    fn prop_spill_only_when_home_is_overloaded() {
        crate::util::prop::check("router-spill-rule", 64, |rng| {
            let shards = 2 + rng.below(6);
            let r = affinity(shards);
            let tokens = rng.tokens(1 + rng.below(64), 2048);
            let tag = rng.next_u64();
            let depths: Vec<usize> = (0..shards).map(|_| rng.below(12)).collect();
            let home = r.affinity_shard(&tokens, tag);
            let min = *depths.iter().min().unwrap();
            let placed = r.place(&tokens, tag, &depths);
            let overloaded = depths[home] as f64 > 2.0 * (min as f64 + 1.0);
            if overloaded {
                if depths[placed] != min {
                    return Err(format!(
                        "overloaded home {home} (depth {}) spilled to {placed} \
                         (depth {}) which is not least-loaded (min {min})",
                        depths[home], depths[placed]
                    ));
                }
            } else if placed != home {
                return Err(format!(
                    "home {home} (depth {}, min {min}) not overloaded but \
                     request went to {placed}",
                    depths[home]
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn distinct_tags_separate_identical_prefixes() {
        // tag participates in the fingerprint: two workflows that happen
        // to share opening tokens can still be spread apart
        let r = affinity(8);
        let tokens = Rng::seeded(3).tokens(32, 2048);
        let spread: std::collections::HashSet<usize> =
            (0..32).map(|tag| r.affinity_shard(&tokens, tag)).collect();
        assert!(spread.len() > 1, "all 32 tags landed on one shard");
    }
}
