//! Agentic workloads: the paper's evaluation harness (§7.1).
//!
//! Synthesizes the two workflow paradigms over dataset geometries scaled
//! from the paper's Table 1 to this substrate's context window:
//!   - **ReAct**: a sequential chain of agents; agent k+1's prompt is the
//!     full transcript so far (shared static context + previous outputs +
//!     tool observations), under a distinct LoRA adapter.
//!   - **MapReduce**: n mappers fork the shared context in parallel (each
//!     with its own adapter + instruction); a reducer joins their outputs.
//!
//! Workflows arrive as a Poisson process; every workflow owns a distinct
//! static context (sharing happens *within* a workflow, across its agents
//! — exactly the structure Figs. 2/11–13 measure). Tool calls inject a
//! fixed latency and a burst of fresh tokens, mirroring the paper's setup
//! (0.1 s + 100 random tokens, scaled).

use std::collections::HashMap;

use crate::engine::{Driver, Request};
use crate::metrics::FinishedRequest;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Series;

/// Dataset geometry (tokens), scaled ~1/100 from the paper's Table 1 while
/// preserving the static:dynamic asymmetry and the cross-dataset ordering
/// LooGLE < NarrativeQA < APIGen.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub static_len: usize,
    pub dynamic_len: usize,
    pub tool_tokens: usize,
}

pub fn dataset(name: &str) -> anyhow::Result<DatasetSpec> {
    Ok(match name {
        "loogle" => DatasetSpec { name: "loogle", static_len: 288, dynamic_len: 16, tool_tokens: 12 },
        "narrativeqa" => DatasetSpec { name: "narrativeqa", static_len: 384, dynamic_len: 12, tool_tokens: 12 },
        "apigen" => DatasetSpec { name: "apigen", static_len: 448, dynamic_len: 16, tool_tokens: 12 },
        // quality benchmark (Table 2) — multi-hop QA geometry
        "hotpotqa" => DatasetSpec { name: "hotpotqa", static_len: 320, dynamic_len: 20, tool_tokens: 12 },
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

pub const DATASETS: [&str; 3] = ["loogle", "narrativeqa", "apigen"];

/// Paper-scale geometry (Table 1 divided by 10; dynamic lengths and the
/// 100-token tool bursts are the paper's own numbers). Only runnable on
/// the sim backend (the AOT artifacts are compiled for s_max=768).
pub fn paper_dataset(name: &str) -> anyhow::Result<DatasetSpec> {
    Ok(match name {
        "loogle" => DatasetSpec { name: "loogle", static_len: 3274, dynamic_len: 24, tool_tokens: 100 },
        "narrativeqa" => DatasetSpec { name: "narrativeqa", static_len: 4912, dynamic_len: 12, tool_tokens: 100 },
        "apigen" => DatasetSpec { name: "apigen", static_len: 6491, dynamic_len: 23, tool_tokens: 100 },
        "hotpotqa" => DatasetSpec { name: "hotpotqa", static_len: 3200, dynamic_len: 20, tool_tokens: 100 },
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

/// Sim context window that fits every paper-scale workflow.
pub const PAPER_S_MAX: usize = 10240;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkflowKind {
    ReAct { n_agents: usize },
    MapReduce { n_mappers: usize },
}

impl WorkflowKind {
    pub fn name(&self) -> &'static str {
        match self {
            WorkflowKind::ReAct { .. } => "react",
            WorkflowKind::MapReduce { .. } => "mapreduce",
        }
    }
    pub fn tasks_per_workflow(&self) -> usize {
        match *self {
            WorkflowKind::ReAct { n_agents } => n_agents,
            WorkflowKind::MapReduce { n_mappers } => n_mappers + 1, // + reducer
        }
    }
}

#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub dataset: DatasetSpec,
    pub kind: WorkflowKind,
    /// number of persistent agent pipelines (each over its own context)
    pub n_workflows: usize,
    /// total user requests streamed through the pipelines
    pub n_requests: usize,
    /// workflow arrivals per (virtual) second
    pub arrival_rate: f64,
    pub output_len: usize,
    pub tool_latency_us: u64,
    pub vocab: usize,
    /// context capacity; the spec asserts its geometry fits
    pub s_max: usize,
    pub seed: u64,
}

impl WorkloadSpec {
    /// The paper's standard setup scaled down: 4-agent ReAct chains or
    /// 6-mapper MapReduce fans, 2 workflows/s, 24-token outputs.
    pub fn standard(dataset_name: &str, kind: WorkflowKind, n_workflows: usize) -> Self {
        let ds = dataset(dataset_name).expect("dataset");
        let spec = WorkloadSpec {
            dataset: ds,
            kind,
            n_workflows,
            n_requests: n_workflows * 3,
            arrival_rate: 2.0,
            output_len: 24,
            tool_latency_us: 100_000,
            vocab: 2048,
            s_max: 768,
            seed: 42,
        };
        spec.validate();
        spec
    }

    pub fn react4(dataset_name: &str, n_workflows: usize) -> Self {
        Self::standard(dataset_name, WorkflowKind::ReAct { n_agents: 4 }, n_workflows)
    }

    pub fn mapreduce6(dataset_name: &str, n_workflows: usize) -> Self {
        Self::standard(
            dataset_name,
            WorkflowKind::MapReduce { n_mappers: 6 },
            n_workflows,
        )
    }

    /// Paper-scale workload (§7.1 scaled /10): 8-agent-step workflows,
    /// 256-token outputs, 100-token tool bursts, 2 requests/s. Sim only.
    pub fn paper(dataset_name: &str, kind: WorkflowKind, n_workflows: usize,
                 n_requests: usize) -> Self {
        let ds = paper_dataset(dataset_name).expect("dataset");
        let spec = WorkloadSpec {
            dataset: ds,
            kind,
            n_workflows,
            n_requests,
            arrival_rate: 2.0,
            output_len: 256,
            tool_latency_us: 100_000,
            vocab: 2048,
            s_max: PAPER_S_MAX,
            seed: 42,
        };
        spec.validate();
        spec
    }

    pub fn paper_react4(dataset_name: &str, n_workflows: usize, n_requests: usize) -> Self {
        Self::paper(dataset_name, WorkflowKind::ReAct { n_agents: 4 }, n_workflows, n_requests)
    }

    pub fn paper_mapreduce6(dataset_name: &str, n_workflows: usize, n_requests: usize) -> Self {
        Self::paper(dataset_name, WorkflowKind::MapReduce { n_mappers: 6 }, n_workflows, n_requests)
    }

    /// Peak prompt+output length across the workflow (must fit the window).
    pub fn peak_context(&self) -> usize {
        let d = &self.dataset;
        match self.kind {
            WorkflowKind::ReAct { n_agents } => {
                d.static_len
                    + n_agents * (d.dynamic_len + self.output_len + d.tool_tokens)
            }
            WorkflowKind::MapReduce { n_mappers } => {
                let mapper = d.static_len + d.dynamic_len + self.output_len;
                let reducer = d.static_len
                    + n_mappers * self.output_len
                    + d.dynamic_len
                    + self.output_len;
                mapper.max(reducer)
            }
        }
    }

    pub fn validate(&self) {
        assert!(
            self.peak_context() <= self.s_max,
            "workload peak context {} exceeds s_max {}",
            self.peak_context(),
            self.s_max
        );
    }
}

// ---------------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------------

struct WorkflowState {
    /// the workflow's massive static context (its "codebase")
    static_ctx: Vec<u32>,
}

struct ActiveRequest {
    workflow: usize,
    /// transcript so far: static ctx + per-step (instr + output + tool)
    transcript: Vec<u32>,
    map_outputs: Vec<Vec<u32>>,
    arrival_us: u64,
}

/// Drives a Poisson stream of end-user requests through `n_workflows`
/// persistent agent pipelines (the paper's serving scenario: long-lived
/// specialized agents over fixed shared contexts, sustained request load).
/// Implements `engine::Driver` so the same code runs on sim and PJRT.
pub struct WorkflowDriver {
    pub spec: WorkloadSpec,
    rng: Rng,
    workflows: Vec<WorkflowState>,
    requests: Vec<ActiveRequest>,
    /// engine request id -> (user request, step)
    inflight: HashMap<u64, (usize, usize)>,
    next_req_id: u64,
    released: bool,
    tasks_done: usize,
    requests_done: usize,
    last_finish_us: u64,
    first_arrival_us: u64,
    pub ttft_us: Series,
    pub task_latency_us: Series,
    pub request_latency_us: Series,
    pub hit_full_tokens: u64,
    pub hit_partial_tokens: u64,
    pub prompt_tokens: u64,
}

impl WorkflowDriver {
    pub fn new(spec: WorkloadSpec) -> Self {
        spec.validate();
        let mut rng = Rng::seeded(spec.seed);
        let workflows = (0..spec.n_workflows)
            .map(|w| {
                let mut r = rng.fork(w as u64 + 1000);
                WorkflowState {
                    static_ctx: r.tokens(spec.dataset.static_len, spec.vocab),
                }
            })
            .collect();
        // Poisson arrivals of user requests, round-robin over workflows
        let mut requests = Vec::with_capacity(spec.n_requests);
        let mut t = 0f64;
        for i in 0..spec.n_requests {
            let w = i % spec.n_workflows;
            requests.push(ActiveRequest {
                workflow: w,
                transcript: Vec::new(), // filled on release
                map_outputs: Vec::new(),
                arrival_us: (t * 1e6) as u64,
            });
            t += rng.exponential(spec.arrival_rate);
        }
        let first_arrival_us = requests.first().map_or(0, |r| r.arrival_us);
        WorkflowDriver {
            spec,
            rng,
            workflows,
            requests,
            inflight: HashMap::new(),
            next_req_id: 1,
            released: false,
            tasks_done: 0,
            requests_done: 0,
            last_finish_us: 0,
            first_arrival_us,
            ttft_us: Series::new(),
            task_latency_us: Series::new(),
            request_latency_us: Series::new(),
            hit_full_tokens: 0,
            hit_partial_tokens: 0,
            prompt_tokens: 0,
        }
    }

    /// Agents are persistent per (workflow, pipeline step): the same
    /// adapter serves every request — this is what makes its rCache (or
    /// per-adapter unified cache) reusable across requests.
    fn adapter_for(&self, workflow: usize, step: usize) -> u32 {
        (workflow * 16 + step) as u32
    }

    fn dispatch(&mut self, rid: usize, step: usize, prompt: Vec<u32>, arrival_us: u64) -> Request {
        let id = self.next_req_id;
        self.next_req_id += 1;
        self.inflight.insert(id, (rid, step));
        let workflow = self.requests[rid].workflow;
        // declared fan width for the gang scheduler: a MapReduce map step
        // is an n_mappers-wide fan (they all carry this request's tag);
        // ReAct steps and the reducer are single-file
        let fan = match self.spec.kind {
            WorkflowKind::MapReduce { n_mappers } if step < n_mappers => n_mappers,
            _ => 1,
        };
        Request {
            id,
            // tags are 1-based: tag 0 is reserved for untagged traffic,
            // which the gang scheduler deliberately ignores
            tag: rid as u64 + 1,
            adapter: self.adapter_for(workflow, step),
            tokens: prompt,
            max_new: self.spec.output_len,
            arrival_us,
            ignore_eos: true,
            fan,
        }
    }

    fn instr(&mut self, rid: usize, step: usize) -> Vec<u32> {
        let mut r = self
            .rng
            .fork(((rid as u64) << 24) | ((step as u64) << 8) | 1);
        r.tokens(self.spec.dataset.dynamic_len, self.spec.vocab)
    }

    fn initial_requests(&mut self, rid: usize) -> Vec<Request> {
        let w = self.requests[rid].workflow;
        let static_ctx = self.workflows[w].static_ctx.clone();
        let arrival = self.requests[rid].arrival_us;
        match self.spec.kind {
            WorkflowKind::ReAct { .. } => {
                let mut prompt = static_ctx;
                prompt.extend(self.instr(rid, 0));
                self.requests[rid].transcript = prompt.clone();
                vec![self.dispatch(rid, 0, prompt, arrival)]
            }
            WorkflowKind::MapReduce { n_mappers } => (0..n_mappers)
                .map(|k| {
                    let mut prompt = static_ctx.clone();
                    prompt.extend(self.instr(rid, k));
                    self.dispatch(rid, k, prompt, arrival)
                })
                .collect(),
        }
    }

    fn on_finished(&mut self, fin: &FinishedRequest, now: u64) -> Vec<Request> {
        let Some((rid, step)) = self.inflight.remove(&fin.id) else {
            return Vec::new();
        };
        self.tasks_done += 1;
        self.last_finish_us = self.last_finish_us.max(fin.finish_us);
        self.ttft_us.push(fin.ttft_us() as f64);
        self.task_latency_us.push(fin.latency_us() as f64);
        self.hit_full_tokens += fin.hit_full as u64;
        self.hit_partial_tokens += fin.hit_partial as u64;
        self.prompt_tokens += fin.prompt_len as u64;

        let mut out = Vec::new();
        match self.spec.kind {
            WorkflowKind::ReAct { n_agents } => {
                let next = step + 1;
                if next < n_agents {
                    // transcript += output + tool observation + next instr
                    let mut t = std::mem::take(&mut self.requests[rid].transcript);
                    t.extend(fin.generated.iter().copied());
                    let mut r = self
                        .rng
                        .fork(((rid as u64) << 24) | ((next as u64) << 8) | 2);
                    t.extend(r.tokens(self.spec.dataset.tool_tokens, self.spec.vocab));
                    t.extend(self.instr(rid, next));
                    self.requests[rid].transcript = t.clone();
                    let arrival = now.max(fin.finish_us) + self.spec.tool_latency_us;
                    out.push(self.dispatch(rid, next, t, arrival));
                } else {
                    self.finish_request(rid, fin.finish_us);
                }
            }
            WorkflowKind::MapReduce { n_mappers } => {
                if step < n_mappers {
                    self.requests[rid].map_outputs.push(fin.generated.clone());
                    if self.requests[rid].map_outputs.len() == n_mappers {
                        let w = self.requests[rid].workflow;
                        let mut prompt = self.workflows[w].static_ctx.clone();
                        for o in &self.requests[rid].map_outputs {
                            prompt.extend(o.iter().copied());
                        }
                        prompt.extend(self.instr(rid, n_mappers));
                        let arrival = now.max(fin.finish_us) + self.spec.tool_latency_us;
                        out.push(self.dispatch(rid, n_mappers, prompt, arrival));
                    }
                } else {
                    self.finish_request(rid, fin.finish_us);
                }
            }
        }
        out
    }

    fn finish_request(&mut self, rid: usize, finish_us: u64) {
        self.requests_done += 1;
        self.request_latency_us
            .push(finish_us.saturating_sub(self.requests[rid].arrival_us) as f64);
    }

    pub fn tasks_done(&self) -> usize {
        self.tasks_done
    }
    pub fn requests_done(&self) -> usize {
        self.requests_done
    }

    /// Measured span from first arrival to last completion.
    pub fn makespan_us(&self) -> u64 {
        self.last_finish_us.saturating_sub(self.first_arrival_us)
    }

    pub fn throughput_tasks_per_s(&self) -> f64 {
        self.tasks_done as f64 / (self.makespan_us() as f64 / 1e6).max(1e-9)
    }

    pub fn shared_fraction(&self) -> f64 {
        (self.hit_full_tokens + self.hit_partial_tokens) as f64
            / (self.prompt_tokens as f64).max(1.0)
    }

    pub fn report(&mut self) -> Json {
        let secs = (self.makespan_us() as f64 / 1e6).max(1e-9);
        Json::obj(vec![
            ("workflow", Json::str(self.spec.kind.name())),
            ("dataset", Json::str(self.spec.dataset.name)),
            ("n_workflows", Json::num(self.spec.n_workflows as f64)),
            ("n_requests", Json::num(self.spec.n_requests as f64)),
            ("tasks_done", Json::num(self.tasks_done as f64)),
            ("requests_done", Json::num(self.requests_done as f64)),
            ("duration_s", Json::num(secs)),
            ("throughput_tasks_per_s", Json::num(self.tasks_done as f64 / secs)),
            ("ttft_us", self.ttft_us.summary().to_json()),
            ("task_latency_us", self.task_latency_us.summary().to_json()),
            ("request_latency_us", self.request_latency_us.summary().to_json()),
        ])
    }
}

impl Driver for WorkflowDriver {
    fn poll(&mut self, now: u64, finished: &[FinishedRequest]) -> Vec<Request> {
        let mut out = Vec::new();
        if !self.released {
            self.released = true;
            for rid in 0..self.spec.n_requests {
                out.extend(self.initial_requests(rid));
            }
        }
        for fin in finished {
            out.extend(self.on_finished(fin, now));
        }
        out
    }

    fn done(&self) -> bool {
        self.requests_done == self.spec.n_requests
    }
}

// ---------------------------------------------------------------------------
// closed-loop HTTP load (exercises the real socket -> worker pool -> engine
// path rather than the in-process Driver interface)
// ---------------------------------------------------------------------------

/// Closed-loop multi-client HTTP scenario: `clients` threads each issue
/// `requests_per_client` sequential `POST /generate` calls with zero think
/// time. Prompts share a static context (so the cache layer sees the
/// paper's reuse pattern) plus a small per-request unique suffix. This is
/// the measurement harness for front-end concurrency: with a serial accept
/// loop the engine's decode occupancy pins at 1; with the worker pool the
/// clients co-batch.
#[derive(Debug, Clone)]
pub struct HttpLoadSpec {
    pub clients: usize,
    pub requests_per_client: usize,
    /// words in the shared static context prefix
    pub shared_words: usize,
    /// per-request unique words appended after the shared prefix
    pub unique_words: usize,
    pub max_new: usize,
    /// adapters are assigned round-robin over clients
    pub adapters: usize,
}

impl Default for HttpLoadSpec {
    fn default() -> Self {
        HttpLoadSpec {
            clients: 8,
            requests_per_client: 4,
            shared_words: 160,
            unique_words: 4,
            max_new: 32,
            adapters: 8,
        }
    }
}

/// Run the closed-loop load against a serving address; returns a JSON
/// report (counts, client-side wall latency summary, throughput).
pub fn run_http_load(addr: &str, spec: &HttpLoadSpec) -> anyhow::Result<Json> {
    anyhow::ensure!(spec.clients > 0, "need at least one client");
    anyhow::ensure!(spec.requests_per_client > 0, "need at least one request per client");
    let shared: String = (0..spec.shared_words)
        .map(|i| format!("ctx{i}"))
        .collect::<Vec<_>>()
        .join(" ");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..spec.clients {
        let addr = addr.to_string();
        let shared = shared.clone();
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let mut latency = Series::new();
            let (mut ok, mut errors) = (0usize, 0usize);
            for r in 0..spec.requests_per_client {
                let unique: String = (0..spec.unique_words)
                    .map(|w| format!("u{c}x{r}x{w}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                let body = Json::obj(vec![
                    ("prompt", Json::str(format!("{shared} {unique}"))),
                    ("adapter", Json::num((c % spec.adapters.max(1)) as f64)),
                    ("max_new", Json::num(spec.max_new as f64)),
                ])
                .to_string();
                let start = std::time::Instant::now();
                match crate::server::http_post(&addr, "/generate", &body) {
                    Ok((200, _)) => {
                        ok += 1;
                        latency.push(start.elapsed().as_micros() as f64);
                    }
                    Ok(_) | Err(_) => errors += 1,
                }
            }
            (latency, ok, errors)
        }));
    }
    let mut latency = Series::new();
    let (mut ok, mut errors) = (0usize, 0usize);
    for h in handles {
        let (l, o, e) = h
            .join()
            .map_err(|_| anyhow::anyhow!("http load client panicked"))?;
        latency.extend_from(&l);
        ok += o;
        errors += e;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(Json::obj(vec![
        ("clients", Json::num(spec.clients as f64)),
        (
            "requests",
            Json::num((spec.clients * spec.requests_per_client) as f64),
        ),
        ("ok", Json::num(ok as f64)),
        ("errors", Json::num(errors as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_req_per_s", Json::num(ok as f64 / wall_s)),
        ("latency_us", latency.summary().to_json()),
    ]))
}

// ---------------------------------------------------------------------------
// multi-workflow HTTP load (the router's measurement harness)
// ---------------------------------------------------------------------------

/// K workflows of M agents, each workflow forking its own large shared
/// context: the placement-sensitive scenario behind the engine shard pool.
/// Every workflow runs on its own closed-loop client thread and issues its
/// agents **sequentially** (agent k+1 starts after agent k finished, the
/// ReAct shape), tagging each request with the workflow id. Under
/// `affinity` routing all of a workflow's agents land on the shard that
/// already holds the context's bCache pages; under `round_robin` they
/// scatter and every shard recomputes the context from scratch — the gap
/// shows up directly in the pool's `matched_rate`.
#[derive(Debug, Clone)]
pub struct MultiWorkflowHttpSpec {
    /// K: concurrent workflows, one client thread each
    pub workflows: usize,
    /// M: agents per workflow, issued sequentially within the workflow
    /// (or as a declared fan — see `parallel`)
    pub agents_per_workflow: usize,
    /// words in each workflow's private shared context
    pub shared_words: usize,
    /// per-agent unique words appended after the shared context
    pub unique_words: usize,
    pub max_new: usize,
    /// MapReduce shape instead of ReAct: agent 0 still runs first (it
    /// primes the workflow's shared context), but agents 1..M then fan
    /// out as a *parallel* burst, each declaring `fan: M-1` on submit so
    /// the home shard's gang scheduler co-admits the step
    pub parallel: bool,
}

impl Default for MultiWorkflowHttpSpec {
    fn default() -> Self {
        MultiWorkflowHttpSpec {
            workflows: 8,
            agents_per_workflow: 3,
            shared_words: 120,
            unique_words: 4,
            max_new: 24,
            parallel: false,
        }
    }
}

/// The prompt text agent `agent` of workflow `workflow` submits: the
/// workflow's shared context plus a small agent-unique suffix. Public so
/// in-process tests can issue the identical token streams without HTTP.
pub fn multi_workflow_prompt(
    spec: &MultiWorkflowHttpSpec,
    workflow: usize,
    agent: usize,
) -> String {
    let mut words: Vec<String> = (0..spec.shared_words)
        .map(|i| format!("wf{workflow}ctx{i}"))
        .collect();
    words.extend((0..spec.unique_words).map(|k| format!("wf{workflow}a{agent}u{k}")));
    words.join(" ")
}

/// POST one workflow agent's request; returns its client-side latency in
/// microseconds on success, None on any failure.
fn post_workflow_agent(
    addr: &str,
    spec: &MultiWorkflowHttpSpec,
    w: usize,
    a: usize,
    fan: usize,
) -> Option<f64> {
    let body = Json::obj(vec![
        ("prompt", Json::str(multi_workflow_prompt(spec, w, a))),
        (
            "adapter",
            Json::num(((w * spec.agents_per_workflow + a) % 64) as f64),
        ),
        ("max_new", Json::num(spec.max_new as f64)),
        // 1-based: tag 0 means untagged and would opt workflow 0 out of
        // gang scheduling
        ("tag", Json::num((w + 1) as f64)),
        ("fan", Json::num(fan as f64)),
    ])
    .to_string();
    let start = std::time::Instant::now();
    match crate::server::http_post(addr, "/generate", &body) {
        Ok((200, _)) => Some(start.elapsed().as_micros() as f64),
        Ok(_) | Err(_) => None,
    }
}

/// Run the multi-workflow scenario against a serving address; returns a
/// JSON report (counts, client-side latency summary, throughput).
pub fn run_multi_workflow_load(
    addr: &str,
    spec: &MultiWorkflowHttpSpec,
) -> anyhow::Result<Json> {
    anyhow::ensure!(spec.workflows > 0, "need at least one workflow");
    anyhow::ensure!(spec.agents_per_workflow > 0, "need at least one agent per workflow");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for w in 0..spec.workflows {
        let addr = addr.to_string();
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let mut results: Vec<Option<f64>> = Vec::new();
            if spec.parallel && spec.agents_per_workflow > 1 {
                // MapReduce shape: agent 0 primes the shared context,
                // then the remaining agents fan out in parallel, each
                // declaring the step's fan width for gang admission
                results.push(post_workflow_agent(&addr, &spec, w, 0, 1));
                let fan = spec.agents_per_workflow - 1;
                let mut burst = Vec::new();
                for a in 1..spec.agents_per_workflow {
                    let addr = addr.clone();
                    let spec = spec.clone();
                    burst.push(std::thread::spawn(move || {
                        post_workflow_agent(&addr, &spec, w, a, fan)
                    }));
                }
                for b in burst {
                    results.push(b.join().unwrap_or(None));
                }
            } else {
                // ReAct shape: agents run single-file (fan 1 = no hold)
                for a in 0..spec.agents_per_workflow {
                    results.push(post_workflow_agent(&addr, &spec, w, a, 1));
                }
            }
            let mut latency = Series::new();
            let (mut ok, mut errors) = (0usize, 0usize);
            for l in results {
                match l {
                    Some(us) => {
                        ok += 1;
                        latency.push(us);
                    }
                    None => errors += 1,
                }
            }
            (latency, ok, errors)
        }));
    }
    let mut latency = Series::new();
    let (mut ok, mut errors) = (0usize, 0usize);
    for h in handles {
        let (l, o, e) = h
            .join()
            .map_err(|_| anyhow::anyhow!("workflow client panicked"))?;
        latency.extend_from(&l);
        ok += o;
        errors += e;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(Json::obj(vec![
        ("workflows", Json::num(spec.workflows as f64)),
        ("agents_per_workflow", Json::num(spec.agents_per_workflow as f64)),
        ("parallel", Json::Bool(spec.parallel)),
        (
            "requests",
            Json::num((spec.workflows * spec.agents_per_workflow) as f64),
        ),
        ("ok", Json::num(ok as f64)),
        ("errors", Json::num(errors as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_req_per_s", Json::num(ok as f64 / wall_s)),
        ("latency_us", latency.summary().to_json()),
    ]))
}

// ---------------------------------------------------------------------------
// fault injection (the durability measurement harness)
// ---------------------------------------------------------------------------

/// Spawn the bench-http fault injector: a thread that sleeps `after_ms`,
/// then POSTs `/admin/kill_shard` asking the server to crash `shard` —
/// waiting (up to `wait_ms`) until the victim holds at least `min_depth`
/// in-flight requests, so the kill reliably strands work for the journal
/// to replay. Returns the endpoint's response JSON (`None` if the post
/// failed or the server refused the kill), which bench-http folds into
/// its report as the `fault` object.
pub fn spawn_http_shard_killer(
    addr: &str,
    shard: usize,
    after_ms: u64,
    min_depth: usize,
    wait_ms: u64,
) -> std::thread::JoinHandle<Option<Json>> {
    let addr = addr.to_string();
    std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(after_ms));
        let body = Json::obj(vec![
            ("shard", Json::num(shard as f64)),
            ("min_depth", Json::num(min_depth as f64)),
            ("wait_ms", Json::num(wait_ms as f64)),
        ])
        .to_string();
        match crate::server::http_post(&addr, "/admin/kill_shard", &body) {
            Ok((200, resp)) => crate::util::json::parse(&resp).ok(),
            Ok(_) | Err(_) => None,
        }
    })
}

// ---------------------------------------------------------------------------
// skewed hot-workflow HTTP load (the migration measurement harness)
// ---------------------------------------------------------------------------

/// One *hot* workflow whose agents arrive in a parallel burst, plus a few
/// cold background workflows: the spill-forcing scenario behind
/// cross-shard page migration. All hot agents share one tag
/// ([`SkewedWorkflowHttpSpec::HOT_TAG`]), the same
/// shared context AND the same adapter (one specialized agent role
/// fanned out, the MapReduce-mapper shape) — so affinity routes them to
/// one home shard where both their bCache and rCache coverage live. The
/// burst drives that shard's in-flight depth past `imbalance_factor` and
/// the later agents spill. Without migration every spilled agent
/// re-prefills the hot context on its target shard; with `--migrate` the
/// matched pages travel instead, and the savings show up as
/// `migrated_pages` / `recompute_tokens_saved` in the engine aggregate.
#[derive(Debug, Clone)]
pub struct SkewedWorkflowHttpSpec {
    /// parallel agents of the hot workflow (the burst)
    pub hot_agents: usize,
    /// per-agent submit stagger: agent `k` waits `k * stagger_ms` so the
    /// router sees the home shard's depth ramp (a simultaneous burst
    /// could outrun the depth signal and never spill)
    pub stagger_ms: u64,
    /// cold background workflows (one sequential agent each, own tags)
    pub cold_workflows: usize,
    /// words in the hot workflow's shared context
    pub shared_words: usize,
    /// extra shared-context words appended to the *hot* workflow only
    /// (cold workflows keep `shared_words`): inflates every hot agent's
    /// footprint past a single shard's static budget slice without
    /// touching the cold background — the elastic-budget A/B's pressure
    /// shape (hot requests need lent budget; cold shards stay lendable)
    pub hot_pad_words: usize,
    /// per-agent unique words appended after the shared context
    pub unique_words: usize,
    pub max_new: usize,
    /// sequential repetitions of the whole hot burst (each wave joins
    /// before the next starts). Wave k replays the *same* per-agent
    /// prompts, so a later wave measures how much of the hot working set
    /// survived the pressure of the earlier ones — the elastic-budget
    /// A/B's signal: with rebalance on, the home shard keeps (or is lent
    /// room for) the agents' paths and wave 2 lands warm; with the
    /// static split they were evicted or the agents dropped.
    pub waves: usize,
}

impl Default for SkewedWorkflowHttpSpec {
    fn default() -> Self {
        SkewedWorkflowHttpSpec {
            hot_agents: 8,
            stagger_ms: 4,
            cold_workflows: 3,
            shared_words: 160,
            hot_pad_words: 0,
            unique_words: 4,
            max_new: 24,
            waves: 1,
        }
    }
}

impl SkewedWorkflowHttpSpec {
    /// The adapter every hot agent serves under (shared: the joint
    /// bCache+rCache coverage is what makes a spill migratable).
    pub const HOT_ADAPTER: usize = 7;

    /// The hot workflow's tag. Nonzero (tag 0 = untagged, which the
    /// gang scheduler ignores) and far above any cold workflow's tag
    /// (those are 1..=cold_workflows), so the hot fan both gangs and
    /// never collides with a cold tag.
    pub const HOT_TAG: u64 = 0xF00D;

    /// The hot workflow's shared-context prompt for burst agent `agent`
    /// (reuses the multi-workflow prompt shape: workflow id 0 is hot).
    /// The hot context carries `hot_pad_words` extra shared words that
    /// cold workflows do not.
    pub fn hot_prompt(&self, agent: usize) -> String {
        let mut m = self.as_multi();
        m.shared_words = self.shared_words + self.hot_pad_words;
        multi_workflow_prompt(&m, 0, agent)
    }

    /// Cold workflow `w` (1-based ids so they never collide with hot).
    pub fn cold_prompt(&self, w: usize) -> String {
        multi_workflow_prompt(&self.as_multi(), w, 0)
    }

    fn as_multi(&self) -> MultiWorkflowHttpSpec {
        MultiWorkflowHttpSpec {
            workflows: self.cold_workflows + 1,
            // large enough that every hot agent (plus the primer) gets a
            // distinct suffix index
            agents_per_workflow: self.hot_agents + 1,
            shared_words: self.shared_words,
            unique_words: self.unique_words,
            max_new: self.max_new,
            parallel: false, // prompt-shape helper only; never driven
        }
    }
}

/// Run the skewed scenario against a serving address. A *primer* request
/// (hot agent index `hot_agents`) runs to completion first so the home
/// shard has the hot context cached and published before the burst —
/// otherwise the spilled agents' probes would race the initial prefill.
/// Returns a JSON report (counts, latency summary, throughput).
pub fn run_skewed_workflow_load(
    addr: &str,
    spec: &SkewedWorkflowHttpSpec,
) -> anyhow::Result<Json> {
    anyhow::ensure!(spec.hot_agents > 0, "need at least one hot agent");
    let post = |prompt: String, adapter: usize, tag: usize, max_new: usize| {
        let body = Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("adapter", Json::num((adapter % 64) as f64)),
            ("max_new", Json::num(max_new as f64)),
            ("tag", Json::num(tag as f64)),
        ])
        .to_string();
        crate::server::http_post(addr, "/generate", &body)
    };
    let t0 = std::time::Instant::now();
    // prime the home shard with the hot context (same adapter as the
    // burst, so both cache components are published before any spill).
    // Under a deliberately starved budget (the elastic-budget A/B's
    // rebalance-off arm) the engine may 503-drop the primer — that is a
    // measured outcome of the scenario, counted as an error, not a
    // harness failure. Transport-level failures still bail.
    let (status, _body) = post(
        spec.hot_prompt(spec.hot_agents),
        SkewedWorkflowHttpSpec::HOT_ADAPTER,
        SkewedWorkflowHttpSpec::HOT_TAG as usize,
        spec.max_new,
    )?;
    let mut latency = Series::new();
    let (mut ok, mut errors) = if status == 200 { (1usize, 0usize) } else { (0, 1) };

    // cold background workflows run once, concurrent with the first wave
    let mut cold_handles = Vec::new();
    for w in 1..=spec.cold_workflows {
        let addr = addr.to_string();
        let spec = spec.clone();
        cold_handles.push(std::thread::spawn(move || {
            let body = Json::obj(vec![
                ("prompt", Json::str(spec.cold_prompt(w))),
                ("adapter", Json::num((w % 64) as f64)),
                ("max_new", Json::num(spec.max_new as f64)),
                ("tag", Json::num(w as f64)),
            ])
            .to_string();
            let start = std::time::Instant::now();
            match crate::server::http_post(&addr, "/generate", &body) {
                Ok((200, _)) => (Some(start.elapsed().as_micros() as f64), 1usize, 0usize),
                Ok(_) | Err(_) => (None, 0, 1),
            }
        }));
    }
    // hot waves run sequentially (each joins before the next starts);
    // every wave replays the same per-agent prompts
    for _wave in 0..spec.waves.max(1) {
        let mut handles = Vec::new();
        for a in 0..spec.hot_agents {
            let addr = addr.to_string();
            let spec = spec.clone();
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(
                    a as u64 * spec.stagger_ms,
                ));
                let body = Json::obj(vec![
                    ("prompt", Json::str(spec.hot_prompt(a))),
                    (
                        "adapter",
                        Json::num(SkewedWorkflowHttpSpec::HOT_ADAPTER as f64),
                    ),
                    ("max_new", Json::num(spec.max_new as f64)),
                    (
                        "tag",
                        Json::num(SkewedWorkflowHttpSpec::HOT_TAG as f64),
                    ),
                ])
                .to_string();
                let start = std::time::Instant::now();
                match crate::server::http_post(&addr, "/generate", &body) {
                    Ok((200, _)) => {
                        (Some(start.elapsed().as_micros() as f64), 1usize, 0usize)
                    }
                    Ok(_) | Err(_) => (None, 0, 1),
                }
            }));
        }
        for h in handles {
            let (l, o, e) = h
                .join()
                .map_err(|_| anyhow::anyhow!("skewed load client panicked"))?;
            if let Some(us) = l {
                latency.push(us);
            }
            ok += o;
            errors += e;
        }
    }
    for h in cold_handles {
        let (l, o, e) = h
            .join()
            .map_err(|_| anyhow::anyhow!("skewed load client panicked"))?;
        if let Some(us) = l {
            latency.push(us);
        }
        ok += o;
        errors += e;
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    // per-prefix spill attribution from the router's counters: the
    // pool-wide `spills` total conflates the hot context with the cold
    // background, so the replication A/B reads the hot fingerprint's
    // share (every hot request carries HOT_TAG) from this breakdown
    let mut spills_by_prefix = Json::Obj(std::collections::BTreeMap::new());
    let mut hot_prefix_spills = 0.0;
    if let Ok((200, body)) = crate::server::http_get(addr, "/metrics") {
        if let Ok(m) = crate::util::json::parse(&body) {
            let by_prefix = m.at(&["router", "spills_by_prefix"]);
            if let Json::Obj(map) = by_prefix {
                hot_prefix_spills = map
                    .values()
                    .filter(|e| {
                        e.at(&["tag"]).as_f64()
                            == Some(SkewedWorkflowHttpSpec::HOT_TAG as f64)
                    })
                    .filter_map(|e| e.at(&["spills"]).as_f64())
                    .sum();
                spills_by_prefix = by_prefix.clone();
            }
        }
    }
    Ok(Json::obj(vec![
        ("hot_agents", Json::num(spec.hot_agents as f64)),
        ("cold_workflows", Json::num(spec.cold_workflows as f64)),
        ("waves", Json::num(spec.waves.max(1) as f64)),
        (
            "requests",
            Json::num(
                (1 + spec.waves.max(1) * spec.hot_agents + spec.cold_workflows) as f64,
            ),
        ),
        ("ok", Json::num(ok as f64)),
        ("errors", Json::num(errors as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_req_per_s", Json::num(ok as f64 / wall_s)),
        ("latency_us", latency.summary().to_json()),
        ("hot_prefix_spills", Json::num(hot_prefix_spills)),
        ("spills_by_prefix", spills_by_prefix),
    ]))
}

// ---------------------------------------------------------------------------
// returning-sessions HTTP load (the host-tier measurement harness)
// ---------------------------------------------------------------------------

/// N sessions, each with its own large private context, visiting the
/// server in round-robin order (session 0..N-1, then session 0 again)
/// for `visits` rounds. Between a session's visits the other N-1
/// working sets push its pages out of the pool budget, so every return
/// visit finds its context evicted. Without the host tier the eviction
/// threw the pages away and the return re-prefills the whole context;
/// with `--tier on` the eviction demoted them and the return *promotes*
/// them back — the gap shows up as `promoted_pages` / `tier_hits` and a
/// strictly lower `computed_prompt_tokens` in the engine aggregate at
/// equal seed.
#[derive(Debug, Clone)]
pub struct ReturningSessionsHttpSpec {
    /// N: sessions with distinct private contexts
    pub sessions: usize,
    /// round-robin visit rounds over all sessions
    pub visits: usize,
    /// words in each session's private context
    pub session_words: usize,
    /// per-visit unique words appended after the session context (each
    /// visit forks the context rather than replaying it byte-identical)
    pub unique_words: usize,
    pub max_new: usize,
    /// adapters are assigned round-robin over sessions
    pub adapters: usize,
}

impl Default for ReturningSessionsHttpSpec {
    fn default() -> Self {
        ReturningSessionsHttpSpec {
            sessions: 8,
            visits: 3,
            session_words: 160,
            unique_words: 4,
            max_new: 8,
            adapters: 4,
        }
    }
}

impl ReturningSessionsHttpSpec {
    /// Session `s`'s prompt for visit `v`: the session's private context
    /// plus a small visit-unique suffix.
    pub fn prompt(&self, s: usize, v: usize) -> String {
        let mut words: Vec<String> =
            (0..self.session_words).map(|i| format!("s{s}w{i}")).collect();
        words.extend((0..self.unique_words).map(|w| format!("s{s}v{v}u{w}")));
        words.join(" ")
    }
}

/// Run the returning-sessions scenario against a serving address. The
/// visits are issued **sequentially on one client** — the scenario
/// measures cache-tier behavior across visits, and a deterministic
/// arrival order is what makes the tier-on/tier-off A/B exact at equal
/// seed. Returns a JSON report (counts, client-observed hit tokens,
/// latency summary, throughput).
pub fn run_returning_sessions_load(
    addr: &str,
    spec: &ReturningSessionsHttpSpec,
) -> anyhow::Result<Json> {
    anyhow::ensure!(spec.sessions > 0, "need at least one session");
    anyhow::ensure!(spec.visits > 0, "need at least one visit round");
    let t0 = std::time::Instant::now();
    let mut latency = Series::new();
    let (mut ok, mut errors) = (0usize, 0usize);
    let (mut prompt_tokens, mut hit_tokens) = (0usize, 0usize);
    // client-observed hit tokens on return visits only (visit 0 is the
    // cold prime; any hits there come from luck, not the tier)
    let mut return_hit_tokens = 0usize;
    for v in 0..spec.visits {
        for s in 0..spec.sessions {
            let body = Json::obj(vec![
                ("prompt", Json::str(spec.prompt(s, v))),
                ("adapter", Json::num((s % spec.adapters.max(1)) as f64)),
                ("max_new", Json::num(spec.max_new as f64)),
                ("tag", Json::num((s + 1) as f64)),
            ])
            .to_string();
            let start = std::time::Instant::now();
            match crate::server::http_post(addr, "/generate", &body) {
                Ok((200, resp)) => {
                    ok += 1;
                    latency.push(start.elapsed().as_micros() as f64);
                    if let Ok(j) = crate::util::json::parse(&resp) {
                        let p = j.at(&["prompt_tokens"]).as_usize().unwrap_or(0);
                        let h = j.at(&["hit_tokens"]).as_usize().unwrap_or(0);
                        prompt_tokens += p;
                        hit_tokens += h;
                        if v > 0 {
                            return_hit_tokens += h;
                        }
                    }
                }
                Ok(_) | Err(_) => errors += 1,
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(Json::obj(vec![
        ("sessions", Json::num(spec.sessions as f64)),
        ("visits", Json::num(spec.visits as f64)),
        ("requests", Json::num((spec.sessions * spec.visits) as f64)),
        ("ok", Json::num(ok as f64)),
        ("errors", Json::num(errors as f64)),
        ("prompt_tokens", Json::num(prompt_tokens as f64)),
        ("hit_tokens", Json::num(hit_tokens as f64)),
        ("return_hit_tokens", Json::num(return_hit_tokens as f64)),
        ("wall_s", Json::num(wall_s)),
        ("throughput_req_per_s", Json::num(ok as f64 / wall_s)),
        ("latency_us", latency.summary().to_json()),
    ]))
}

// ---------------------------------------------------------------------------
// DAG workflow HTTP load (the cross-step prefetch measurement harness)
// ---------------------------------------------------------------------------

/// Which steps-to-execute shape a DAG workflow declares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DagTopology {
    /// `width` mappers fan out over the shared context, then one reducer
    /// (its own routing tag, so it homes on its own shard) joins them —
    /// the reducer's prefix is declared literally up front
    MapReduce,
    /// a sequential chain under one tag; step k+1's prompt extends step
    /// k's (`prefix_from` provenance), the ReAct transcript shape
    React,
    /// a sequential chain where every stage runs under its *own* tag
    /// (stage handoff across shards); `prefix_from` the previous stage
    Pipeline,
}

impl DagTopology {
    /// Parse a CLI topology name (`mapreduce`, `react`, `pipeline`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "mapreduce" => Ok(DagTopology::MapReduce),
            "react" => Ok(DagTopology::React),
            "pipeline" => Ok(DagTopology::Pipeline),
            other => anyhow::bail!("unknown dag topology {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DagTopology::MapReduce => "mapreduce",
            DagTopology::React => "react",
            DagTopology::Pipeline => "pipeline",
        }
    }
}

/// K concurrent workflows, each declaring its steps-to-execute DAG on
/// submit (`"steps"`) and naming its node per request (`"step"`): the
/// measurement harness for cross-step prefetch. The map→reduce shape is
/// the A/B scenario — while the mappers decode, the reducer's declared
/// prefix (the shared context) is already resolvable, so with
/// `--prefetch on` the server pre-migrates and pins it on the reducer's
/// home shard before the reducer posts; the reducer's time-to-first-token
/// and the pool's `computed_prompt_tokens` drop strictly versus
/// `--prefetch off` at the same seed.
#[derive(Debug, Clone)]
pub struct DagWorkflowHttpSpec {
    pub topology: DagTopology,
    /// K: concurrent workflows, one client thread each
    pub workflows: usize,
    /// mappers per workflow (mapreduce) / chain length (react, pipeline)
    pub width: usize,
    /// words in each workflow's private shared context
    pub shared_words: usize,
    /// per-step unique words appended after the inherited prefix
    pub unique_words: usize,
    pub max_new: usize,
    /// pool geometry mirrored from the server config: the harness picks
    /// each successor step's routing tag so it *homes on a different
    /// shard* than its predecessors (the cross-shard pre-migration path
    /// is the mechanism under test, not hash luck)
    pub shards: usize,
    pub page_tokens: usize,
    pub vocab: usize,
}

impl Default for DagWorkflowHttpSpec {
    fn default() -> Self {
        DagWorkflowHttpSpec {
            topology: DagTopology::MapReduce,
            workflows: 6,
            width: 3,
            shared_words: 160,
            unique_words: 4,
            max_new: 12,
            shards: 1,
            page_tokens: 16,
            vocab: 32_768,
        }
    }
}

impl DagWorkflowHttpSpec {
    /// Workflow `w`'s shared context text (every step's common prefix).
    pub fn ctx_text(&self, w: usize) -> String {
        (0..self.shared_words)
            .map(|i| format!("wf{w}dagctx{i}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// A routing tag near `base` whose affinity home for `window`
    /// differs from `pred_home`, so the successor step deterministically
    /// lands on another shard. Single-shard pools (or a pathological
    /// hash) fall back to the first candidate — prefetch then warms in
    /// place instead of across shards.
    fn cross_shard_tag(
        &self,
        router: &crate::router::Router,
        window: &[u32],
        base: u64,
        pred_home: usize,
    ) -> u64 {
        for c in 1..=32u64 {
            let t = base + 1_000_000 * c;
            if router.affinity_shard(window, t) != pred_home {
                return t;
            }
        }
        base + 1_000_000
    }
}

/// POST one DAG step; returns the server-reported
/// (ttft_us, prompt_tokens, hit_tokens) on success.
#[allow(clippy::too_many_arguments)]
fn post_dag_step(
    addr: &str,
    prompt: &str,
    adapter: u32,
    max_new: usize,
    tag: u64,
    workflow: u64,
    step: &str,
    fan: usize,
    steps: Option<&Json>,
) -> Option<(f64, usize, usize)> {
    let mut fields = vec![
        ("prompt", Json::str(prompt)),
        ("adapter", Json::num(adapter as f64)),
        ("max_new", Json::num(max_new as f64)),
        ("tag", Json::num(tag as f64)),
        ("workflow", Json::num(workflow as f64)),
        ("step", Json::str(step)),
        ("fan", Json::num(fan as f64)),
    ];
    if let Some(s) = steps {
        fields.push(("steps", s.clone()));
    }
    let body = Json::obj(fields).to_string();
    match crate::server::http_post(addr, "/generate", &body) {
        Ok((200, resp)) => {
            let j = crate::util::json::parse(&resp).ok()?;
            Some((
                j.at(&["ttft_us"]).as_f64().unwrap_or(0.0),
                j.at(&["prompt_tokens"]).as_usize().unwrap_or(0),
                j.at(&["hit_tokens"]).as_usize().unwrap_or(0),
            ))
        }
        Ok(_) | Err(_) => None,
    }
}

/// Per-workflow results folded into the run report.
#[derive(Default)]
struct DagWorkflowResult {
    /// ttft of every non-final step
    step_ttft: Vec<f64>,
    /// ttft of the final step (the reducer / chain tail) — the
    /// prefetch-sensitive number
    final_ttft: Vec<f64>,
    /// server-reported cache hits on the final step
    final_hit_tokens: usize,
    ok: usize,
    errors: usize,
}

/// Run the DAG workflow scenario against a serving address; returns a
/// JSON report. `reduce_ttft_us` summarizes the final step of every
/// workflow (the reducer under mapreduce, the chain tail otherwise).
pub fn run_dag_load(addr: &str, spec: &DagWorkflowHttpSpec) -> anyhow::Result<Json> {
    anyhow::ensure!(spec.workflows > 0, "need at least one workflow");
    anyhow::ensure!(spec.width > 0, "need at least one step per workflow");
    let t0 = std::time::Instant::now();
    let tokenizer = crate::util::tokenizer::HashTokenizer::new(spec.vocab.max(2));
    let router = crate::router::Router::new(
        crate::router::RoutePolicy::Affinity,
        spec.shards.max(1),
        spec.page_tokens.max(1),
        1.5,
    );
    let router = std::sync::Arc::new(router);
    let tokenizer = std::sync::Arc::new(tokenizer);
    let mut handles = Vec::new();
    for w in 0..spec.workflows {
        let addr = addr.to_string();
        let spec = spec.clone();
        let router = router.clone();
        let tokenizer = tokenizer.clone();
        handles.push(std::thread::spawn(move || {
            run_dag_workflow(&addr, &spec, w, &router, &tokenizer)
        }));
    }
    let mut all = DagWorkflowResult::default();
    for h in handles {
        let r = h
            .join()
            .map_err(|_| anyhow::anyhow!("dag workflow client panicked"))?;
        all.step_ttft.extend(r.step_ttft);
        all.final_ttft.extend(r.final_ttft);
        all.final_hit_tokens += r.final_hit_tokens;
        all.ok += r.ok;
        all.errors += r.errors;
    }
    let requests = spec.workflows
        * match spec.topology {
            DagTopology::MapReduce => spec.width + 1,
            DagTopology::React | DagTopology::Pipeline => spec.width,
        };
    let mut step_ttft = Series::new();
    for v in &all.step_ttft {
        step_ttft.push(*v);
    }
    let mut final_ttft = Series::new();
    for v in &all.final_ttft {
        final_ttft.push(*v);
    }
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok(Json::obj(vec![
        ("topology", Json::str(spec.topology.name())),
        ("workflows", Json::num(spec.workflows as f64)),
        ("width", Json::num(spec.width as f64)),
        ("requests", Json::num(requests as f64)),
        ("ok", Json::num(all.ok as f64)),
        ("errors", Json::num(all.errors as f64)),
        ("final_hit_tokens", Json::num(all.final_hit_tokens as f64)),
        ("step_ttft_us", step_ttft.summary().to_json()),
        ("reduce_ttft_us", final_ttft.summary().to_json()),
        ("wall_s", Json::num(wall_s)),
        ("throughput_req_per_s", Json::num(all.ok as f64 / wall_s)),
    ]))
}

/// Drive one workflow end to end (client-side step ordering is the
/// dependency edge: a step posts only after its predecessors returned).
fn run_dag_workflow(
    addr: &str,
    spec: &DagWorkflowHttpSpec,
    w: usize,
    router: &crate::router::Router,
    tokenizer: &crate::util::tokenizer::HashTokenizer,
) -> DagWorkflowResult {
    let mut out = DagWorkflowResult::default();
    let wf_tag = (w + 1) as u64;
    let adapter = (w % 64) as u32;
    let ctx = spec.ctx_text(w);
    let window = tokenizer.encode(&ctx);
    let home = router.affinity_shard(&window, wf_tag);
    let mut record = |r: Option<(f64, usize, usize)>, is_final: bool| match r {
        Some((ttft, _p, h)) => {
            out.ok += 1;
            if is_final {
                out.final_ttft.push(ttft);
                out.final_hit_tokens += h;
            } else {
                out.step_ttft.push(ttft);
            }
        }
        None => out.errors += 1,
    };
    match spec.topology {
        DagTopology::MapReduce => {
            let reduce_tag = spec.cross_shard_tag(router, &window, wf_tag, home);
            let mut steps: Vec<Json> = (0..spec.width)
                .map(|a| Json::obj(vec![("id", Json::str(format!("map{a}")))]))
                .collect();
            steps.push(Json::obj(vec![
                ("id", Json::str("reduce")),
                (
                    "after",
                    Json::Arr(
                        (0..spec.width)
                            .map(|a| Json::str(format!("map{a}")))
                            .collect(),
                    ),
                ),
                ("prefix", Json::str(ctx.clone())),
                ("tag", Json::num(reduce_tag as f64)),
            ]));
            let steps = Json::Arr(steps);
            // the mappers fan out in parallel, each declaring the fan
            // width for gang admission and attaching the (idempotently
            // registered) DAG
            let mut burst = Vec::new();
            for a in 0..spec.width {
                let addr = addr.to_string();
                let spec = spec.clone();
                let ctx = ctx.clone();
                let steps = steps.clone();
                burst.push(std::thread::spawn(move || {
                    let unique: Vec<String> = (0..spec.unique_words)
                        .map(|k| format!("wf{w}map{a}u{k}"))
                        .collect();
                    let prompt = format!("{ctx} {}", unique.join(" "));
                    post_dag_step(
                        &addr,
                        &prompt,
                        (w % 64) as u32,
                        spec.max_new,
                        (w + 1) as u64,
                        (w + 1) as u64,
                        &format!("map{a}"),
                        spec.width,
                        Some(&steps),
                    )
                }));
            }
            for b in burst {
                record(b.join().unwrap_or(None), false);
            }
            // all mappers returned, so the server has seen every
            // predecessor finish — the reducer's prefix was prefetched
            // onto `reduce_tag`'s home shard before this post
            let unique: Vec<String> = (0..spec.unique_words)
                .map(|k| format!("wf{w}reduceu{k}"))
                .collect();
            let prompt = format!("{ctx} {}", unique.join(" "));
            record(
                post_dag_step(
                    addr, &prompt, adapter, spec.max_new, reduce_tag, wf_tag, "reduce",
                    1, None,
                ),
                true,
            );
        }
        DagTopology::React | DagTopology::Pipeline => {
            let pipeline = spec.topology == DagTopology::Pipeline;
            // stage tags: one shared tag for react; per-stage cross-shard
            // tags for pipeline (each handoff homes elsewhere)
            let mut tags = vec![wf_tag];
            let mut prev_home = home;
            for _ in 1..spec.width {
                let t = if pipeline {
                    let t = spec.cross_shard_tag(router, &window, wf_tag, prev_home);
                    prev_home = router.affinity_shard(&window, t);
                    t
                } else {
                    wf_tag
                };
                tags.push(t);
            }
            let steps = Json::Arr(
                (0..spec.width)
                    .map(|i| {
                        let mut fields = vec![("id", Json::str(format!("s{i}")))];
                        if i > 0 {
                            fields.push((
                                "after",
                                Json::Arr(vec![Json::str(format!("s{}", i - 1))]),
                            ));
                            fields.push(("prefix_from", Json::str(format!("s{}", i - 1))));
                            fields.push(("tag", Json::num(tags[i] as f64)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            );
            let mut prompt = ctx.clone();
            for i in 0..spec.width {
                let unique: Vec<String> = (0..spec.unique_words)
                    .map(|k| format!("wf{w}s{i}u{k}"))
                    .collect();
                prompt = format!("{prompt} {}", unique.join(" "));
                record(
                    post_dag_step(
                        addr,
                        &prompt,
                        adapter,
                        spec.max_new,
                        tags[i],
                        wf_tag,
                        &format!("s{i}"),
                        1,
                        (i == 0).then_some(&steps),
                    ),
                    i == spec.width - 1,
                );
            }
        }
    }
    out
}

/// Standard engine builders shared by tests, benches and the CLI.
pub mod presets {
    use crate::config::{CacheConfig, CachePolicy, EngineConfig};
    use crate::engine::Engine;
    use crate::exec::SimExecutor;

    /// Sim decode buckets: the AOT set plus the larger batches the paper's
    /// decode-batch analysis (Fig. 14c) reaches under ForkKV.
    pub const SIM_BUCKETS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

    /// Paper-scale sim engine: widened window, r/n ratio matched to the
    /// paper (rank is the *paper* rank in {8,16,32}).
    /// Virtual sustained FLOP/s used by the paper-scale sims (overridden
    /// by artifacts/calibration.json when present). Chosen so the paper's
    /// nominal 2 req/s load saturates the baseline but not ForkKV — the
    /// regime every evaluation figure operates in.
    pub const SIM_SUSTAINED_FLOPS: f64 = 150e9;

    pub fn paper_sim_engine(
        model: &str,
        policy: CachePolicy,
        budget_mb: usize,
        paper_rank: usize,
        seed: u64,
    ) -> anyhow::Result<Engine> {
        let sim = SimExecutor::new(model, SIM_BUCKETS.to_vec())?
            .with_ctx(super::PAPER_S_MAX)
            .with_rank(SimExecutor::paper_ratio_rank(paper_rank))
            .with_sustained(SIM_SUSTAINED_FLOPS);
        // NOTE: figures use the fixed virtual substrate for determinism;
        // `forkkv calibrate` + SimExecutor::try_load_calibration map
        // virtual time onto this machine's real PJRT speed when desired.
        let cfg = EngineConfig {
            policy,
            cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20, capacity_bytes: 0 },
            seed,
            ..EngineConfig::default()
        };
        Engine::new(cfg, Box::new(sim))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheConfig, CachePolicy, EngineConfig};
    use crate::engine::Engine;
    use crate::exec::SimExecutor;

    fn sim_engine(policy: CachePolicy, budget_mb: usize, seed: u64) -> Engine {
        let cfg = EngineConfig {
            policy,
            cache: CacheConfig { page_tokens: 16, budget_bytes: budget_mb << 20, capacity_bytes: 0 },
            seed,
            ..EngineConfig::default()
        };
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8, 16, 32]).unwrap();
        Engine::new(cfg, Box::new(sim)).unwrap()
    }

    #[test]
    fn geometry_fits_all_standard_workloads() {
        for ds in DATASETS {
            WorkloadSpec::react4(ds, 8).validate();
            WorkloadSpec::mapreduce6(ds, 8).validate();
        }
    }

    #[test]
    fn react_requests_complete_with_expected_task_count() {
        let spec = WorkloadSpec::react4("loogle", 3);
        let mut driver = WorkflowDriver::new(spec.clone());
        let mut engine = sim_engine(CachePolicy::Disaggregated, 32, 1);
        let fin = engine.run_driver(&mut driver).unwrap();
        assert_eq!(driver.requests_done(), spec.n_requests);
        assert_eq!(
            driver.tasks_done(),
            spec.n_requests * spec.kind.tasks_per_workflow()
        );
        assert_eq!(fin.len(), driver.tasks_done());
        engine.check_quiescent().unwrap();
        assert!(driver.throughput_tasks_per_s() > 0.0);
    }

    #[test]
    fn mapreduce_reducer_sees_all_outputs() {
        let spec = WorkloadSpec::mapreduce6("loogle", 2);
        let mut driver = WorkflowDriver::new(spec.clone());
        let mut engine = sim_engine(CachePolicy::Disaggregated, 32, 2);
        let fin = engine.run_driver(&mut driver).unwrap();
        assert_eq!(driver.requests_done(), spec.n_requests);
        assert_eq!(fin.len(), spec.n_requests * (6 + 1));
        // reducer prompts are the longest: static + 6 outputs + instr
        let max_prompt = fin.iter().map(|f| f.prompt_len).max().unwrap();
        assert_eq!(
            max_prompt,
            288 + 6 * spec.output_len + spec.dataset.dynamic_len
        );
    }

    #[test]
    fn react_transcript_grows_monotonically_per_request() {
        let mut spec = WorkloadSpec::react4("loogle", 1);
        spec.n_requests = 1;
        let mut driver = WorkflowDriver::new(spec);
        let mut engine = sim_engine(CachePolicy::Disaggregated, 32, 3);
        let fin = engine.run_driver(&mut driver).unwrap();
        let mut lens: Vec<usize> = fin.iter().map(|f| f.prompt_len).collect();
        let sorted = {
            let mut s = lens.clone();
            s.sort_unstable();
            s
        };
        assert_eq!(lens, sorted, "each ReAct step extends the transcript");
        lens.dedup();
        assert_eq!(lens.len(), 4, "four distinct steps");
    }

    #[test]
    fn repeat_requests_hit_the_static_context() {
        // second request through the same pipeline must re-use each
        // agent's cache over the static context (the paper's key reuse)
        let mut spec = WorkloadSpec::react4("loogle", 1);
        spec.n_requests = 3;
        let mut driver = WorkflowDriver::new(spec.clone());
        let mut engine = sim_engine(CachePolicy::Disaggregated, 64, 5);
        engine.run_driver(&mut driver).unwrap();
        let hit_frac = driver.hit_full_tokens as f64 / driver.prompt_tokens as f64;
        assert!(
            hit_frac > 0.3,
            "full-hit fraction {hit_frac:.2} too low for repeated pipelines"
        );
    }

    #[test]
    fn forkkv_beats_prefix_caching_under_contention() {
        // the paper's headline comparison in miniature: paper-scale
        // contexts, 8 pipelines x 4 agents, budget that fits one shared
        // bCache per workflow but not per-adapter copies (Fig. 11 regime)
        let run = |policy| {
            let spec = WorkloadSpec::paper_react4("loogle", 8, 32);
            let mut driver = WorkflowDriver::new(spec);
            let mut engine =
                presets::paper_sim_engine("llama3-8b-sim", policy, 160, 16, 4).unwrap();
            engine.run_driver(&mut driver).unwrap();
            (driver.throughput_tasks_per_s(), driver.shared_fraction())
        };
        let (fork_tps, fork_shared) = run(CachePolicy::Disaggregated);
        let (unified_tps, unified_shared) = run(CachePolicy::UnifiedPerAdapter);
        assert!(
            fork_shared > unified_shared,
            "forkkv shares {fork_shared:.2} <= unified {unified_shared:.2}"
        );
        assert!(
            fork_tps > unified_tps,
            "forkkv {fork_tps:.2} tasks/s <= prefix caching {unified_tps:.2} tasks/s"
        );
    }

    #[test]
    fn multi_workflow_prompts_share_within_and_differ_across_workflows() {
        let spec = MultiWorkflowHttpSpec::default();
        let t = crate::util::tokenizer::HashTokenizer::new(2048);
        let a0 = t.encode(&multi_workflow_prompt(&spec, 0, 0));
        let a1 = t.encode(&multi_workflow_prompt(&spec, 0, 1));
        let b0 = t.encode(&multi_workflow_prompt(&spec, 1, 0));
        // same workflow: identical shared context, distinct suffix
        assert_eq!(a0[..spec.shared_words], a1[..spec.shared_words]);
        assert_ne!(a0[spec.shared_words..], a1[spec.shared_words..]);
        // different workflow: contexts diverge from the first word,
        // so the router's first-page fingerprint separates them
        assert_ne!(a0[0], b0[0]);
    }

    #[test]
    fn skewed_prompts_share_hot_context_and_isolate_cold() {
        let spec = SkewedWorkflowHttpSpec::default();
        let t = crate::util::tokenizer::HashTokenizer::new(2048);
        let h0 = t.encode(&spec.hot_prompt(0));
        let h1 = t.encode(&spec.hot_prompt(1));
        let primer = t.encode(&spec.hot_prompt(spec.hot_agents));
        let c1 = t.encode(&spec.cold_prompt(1));
        // every hot agent (primer included) forks the same shared context
        assert_eq!(h0[..spec.shared_words], h1[..spec.shared_words]);
        assert_eq!(h0[..spec.shared_words], primer[..spec.shared_words]);
        // but has a distinct suffix (a real fork, not a repeat)
        assert_ne!(h0[spec.shared_words..], h1[spec.shared_words..]);
        assert_ne!(h0[spec.shared_words..], primer[spec.shared_words..]);
        // cold workflows diverge from the first token, so the affinity
        // fingerprint separates them from the hot home shard
        assert_ne!(h0[0], c1[0]);
    }

    #[test]
    fn deterministic_workload_generation() {
        let mk = || {
            let spec = WorkloadSpec::react4("apigen", 2);
            let mut d = WorkflowDriver::new(spec);
            d.poll(0, &[])
                .into_iter()
                .map(|r| (r.id, r.adapter, r.tokens))
                .collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }
}
