//! Cross-shard bCache page migration: spill costs bandwidth, not FLOPs.
//!
//! PR 2's affinity router keeps forked agents on the shard that already
//! caches their shared context, but a request spilled for load balance
//! used to recompute its whole prefix on the target shard — the exact
//! redundant prefill ForkKV's CoW fork exists to eliminate, reintroduced
//! at the pool layer. This module is the fix (TokenDance-style collective
//! KV sharing / KVFlow-style workflow cache management, adapted to the
//! shard pool): before a spilled request prefills, the server
//!
//!   1. **Probe**s the home shard (`Engine::migration_probe`, a read-only
//!      `RadixTree::probe_pages` walk over the page-aligned prompt
//!      window) for how many bCache/rCache pages the request would have
//!      matched there;
//!   2. asks [`MigrationPolicy::should_migrate`] whether moving those
//!      bytes beats recomputing those tokens (calibrated
//!      bandwidth-vs-FLOPs cost model, `exec::CostModel`), and probes
//!      the *target* the same way — a target already covering the home
//!      shard's match (an earlier migration of the same hot context)
//!      skips the transfer outright;
//!   3. **Export**s a snapshot of the matched pages' bytes plus their
//!      token path out of the home shard (`Engine::export_pages` — pages
//!      are leased during the copy, so the home LRU cannot evict them
//!      mid-export);
//!   4. **Import**s the snapshot into the target shard's pool and
//!      `DualRadixTree` (`Engine::import_pages`, refcount-correct
//!      insertion) ahead of the request's submission on the same FIFO
//!      command channel — so its `fork_match` hits locally and only the
//!      unmatched tail is computed.
//!
//! The payload types here are plain owned buffers: a snapshot is
//! decoupled from the source pool the moment it is taken, which is what
//! makes the export lease short (copy time, not transfer time) and lets
//! the server move the payload between shard threads without aliasing
//! either engine's memory.

#![warn(missing_docs)]

use crate::exec::CostModel;
use crate::kvcache::BlockPool;
use crate::radix::RadixTree;

/// One tree component (base or residual) of a migration snapshot: the
/// page-aligned token path plus each matched page's raw bytes, in path
/// order. `tokens.len() == pages.len() * page_tokens` always holds.
#[derive(Debug, Clone)]
pub struct ComponentExport {
    /// radix namespace the pages were exported from (and must be
    /// imported into): `base_ns(policy, adapter)` for the base tree,
    /// the adapter id for the residual tree
    pub ns: u32,
    /// the matched token path, page aligned
    pub tokens: Vec<u32>,
    /// raw page contents (`BlockPool::page_data` runs), one per page
    pub pages: Vec<Vec<f32>>,
}

impl ComponentExport {
    /// Total payload size of this component's page bytes.
    pub fn bytes(&self) -> usize {
        self.pages.iter().map(|p| p.len() * 4).sum()
    }
}

/// A full shard-to-shard page snapshot for one spilled request.
#[derive(Debug, Clone)]
pub struct MigrationPayload {
    /// page granularity of the exporting shard (importers verify it
    /// matches their own before touching their pool)
    pub page_tokens: usize,
    /// bCache component of the snapshot
    pub base: ComponentExport,
    /// present only under the disaggregated policy
    pub residual: Option<ComponentExport>,
}

impl MigrationPayload {
    /// Total bytes the snapshot would move over the inter-shard link.
    pub fn bytes(&self) -> usize {
        self.base.bytes() + self.residual.as_ref().map_or(0, ComponentExport::bytes)
    }

    /// Total pages across both components.
    pub fn pages(&self) -> usize {
        self.base.pages.len() + self.residual.as_ref().map_or(0, |r| r.pages.len())
    }

    /// Prompt tokens the importing shard will skip at admission: the
    /// *joint* coverage (fork admission skips `min(base, residual)`
    /// under the disaggregated policy, base coverage otherwise).
    pub fn tokens_saved(&self) -> usize {
        match &self.residual {
            Some(r) => self.base.tokens.len().min(r.tokens.len()),
            None => self.base.tokens.len(),
        }
    }
}

/// Snapshot one tree component's longest cached prefix of `tokens`.
///
/// Eviction safety: the matched pages are *leased* (`match_lease`) for
/// the duration of the byte copy — `RadixTree::evict` skips leased
/// nodes, so an LRU pass racing the export (in engine terms: queued
/// right behind it on the shard's command channel) can never free a
/// page mid-snapshot. The leases and pool refs are dropped before
/// returning; the result owns plain buffers with no ties to the pool.
pub fn export_component(
    tree: &mut RadixTree,
    pool: &mut BlockPool,
    ns: u32,
    tokens: &[u32],
) -> ComponentExport {
    let m = tree.match_lease(ns, tokens, pool);
    let pages: Vec<Vec<f32>> = m
        .pages
        .iter()
        .map(|&p| pool.page_data(p).to_vec())
        .collect();
    let path_tokens = tokens[..m.tokens].to_vec();
    tree.release_path(&m.path);
    for &p in &m.pages {
        pool.release(p);
    }
    ComponentExport { ns, tokens: path_tokens, pages }
}

/// What a home-shard probe found: enough to price the migration without
/// copying a byte.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrationEstimate {
    /// bCache pages the prompt matched on the probed shard
    pub base_pages: usize,
    /// rCache pages the prompt matched on the probed shard
    pub res_pages: usize,
    /// total bytes a full export of those pages would move
    pub bytes: usize,
    /// prompt tokens the import would save from recompute (joint
    /// coverage, as in [`MigrationPayload::tokens_saved`])
    pub tokens_saved: usize,
}

/// The migrate-vs-recompute decision, priced by the calibrated cost
/// model: copying `bytes` over the inter-shard link vs re-prefilling
/// `tokens_saved` tokens on the target shard.
#[derive(Debug, Clone)]
pub struct MigrationPolicy {
    /// master switch (`ServerConfig::migrate` && a multi-shard pool)
    pub enabled: bool,
    /// calibrated price list for both sides of the trade
    pub cost: CostModel,
}

impl MigrationPolicy {
    /// Policy over a (possibly calibrated) cost model.
    pub fn new(enabled: bool, cost: CostModel) -> Self {
        MigrationPolicy { enabled, cost }
    }

    /// Virtual microseconds to move the estimate's bytes between shards.
    pub fn migrate_cost_us(&self, est: &MigrationEstimate) -> u64 {
        self.cost.migrate_cost_us(est.bytes)
    }

    /// Virtual microseconds the target shard would spend recomputing the
    /// matched prefix (it sits at the front of the prompt: cache_len 0).
    pub fn recompute_cost_us(&self, est: &MigrationEstimate) -> u64 {
        self.cost.prefill_cost_us(est.tokens_saved, 0)
    }

    /// Migrate exactly when the copy is cheaper than the recompute it
    /// saves (and there is anything to save at all).
    pub fn should_migrate(&self, est: &MigrationEstimate) -> bool {
        self.enabled
            && est.tokens_saved > 0
            && self.migrate_cost_us(est) < self.recompute_cost_us(est)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::synthetic_meta;

    fn policy(bandwidth: f64) -> MigrationPolicy {
        let meta = synthetic_meta("llama3-8b-sim").unwrap();
        let mut cost = CostModel::derived(&meta);
        cost.migration_bandwidth_bytes_per_s = bandwidth;
        MigrationPolicy::new(true, cost)
    }

    fn est(pages: usize, bytes: usize, tokens: usize) -> MigrationEstimate {
        MigrationEstimate {
            base_pages: pages,
            res_pages: 0,
            bytes,
            tokens_saved: tokens,
        }
    }

    #[test]
    fn payload_accounting() {
        let base = ComponentExport {
            ns: 0,
            tokens: (0..32).collect(),
            pages: vec![vec![0.0; 64]; 2],
        };
        let res = ComponentExport {
            ns: 3,
            tokens: (0..16).collect(),
            pages: vec![vec![0.0; 8]; 1],
        };
        let p = MigrationPayload {
            page_tokens: 16,
            base: base.clone(),
            residual: Some(res),
        };
        assert_eq!(p.pages(), 3);
        assert_eq!(p.bytes(), 2 * 64 * 4 + 8 * 4);
        // joint coverage: min(32 base, 16 residual)
        assert_eq!(p.tokens_saved(), 16);
        let merged = MigrationPayload { page_tokens: 16, base, residual: None };
        assert_eq!(merged.tokens_saved(), 32);
    }

    #[test]
    fn fast_link_migrates_slow_link_recomputes() {
        // a realistic interconnect: moving ~100 KB beats re-prefilling
        // 144 tokens by orders of magnitude
        let fast = policy(8e9);
        assert!(fast.should_migrate(&est(9, 100 << 10, 144)));
        // a catastrophically slow link (1 KB/s): recompute wins
        let slow = policy(1e3);
        assert!(!slow.should_migrate(&est(9, 100 << 10, 144)));
        assert!(slow.migrate_cost_us(&est(9, 100 << 10, 144))
            > slow.recompute_cost_us(&est(9, 100 << 10, 144)));
    }

    #[test]
    fn empty_or_disabled_never_migrates() {
        let p = policy(8e9);
        assert!(!p.should_migrate(&est(0, 0, 0)), "nothing matched");
        let off = MigrationPolicy::new(false, p.cost.clone());
        assert!(!off.should_migrate(&est(9, 100 << 10, 144)), "disabled");
    }
}
