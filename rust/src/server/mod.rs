//! Minimal HTTP/1.1 JSON serving front-end (hand-rolled on std::net — the
//! offline vendor set has no hyper/axum/tokio; DESIGN.md §3).
//!
//! POST /generate {"prompt": "...", "adapter": 3, "max_new": 24}
//!   -> {"tokens": [...], "text": "...", "ttft_us": ..., "latency_us": ...}
//! GET /stats -> engine metrics JSON
//!
//! One engine thread owns the `Engine` and ticks it; connection threads
//! submit requests through a channel and wait on per-request channels.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;

use crate::engine::{Engine, Request, Tick};
use crate::metrics::FinishedRequest;
use crate::util::json::{self, Json};
use crate::util::tokenizer::HashTokenizer;

enum Cmd {
    Submit(Request, mpsc::Sender<FinishedRequest>),
    Stats(mpsc::Sender<Json>),
    Shutdown,
}

pub struct Server {
    tx: mpsc::Sender<Cmd>,
    tokenizer: HashTokenizer,
    max_ctx: usize,
}

impl Server {
    /// Spawn the engine thread; returns the submission handle.
    pub fn start(mut engine: Engine) -> (Arc<Server>, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let tokenizer = HashTokenizer::new(engine.meta().vocab);
        let max_ctx = engine.meta().s_max;
        let handle = std::thread::spawn(move || {
            let mut waiters: HashMap<u64, mpsc::Sender<FinishedRequest>> = HashMap::new();
            let mut next_id = 1u64;
            loop {
                // drain the command queue
                loop {
                    match rx.try_recv() {
                        Ok(Cmd::Submit(mut req, reply)) => {
                            req.id = next_id;
                            next_id += 1;
                            req.arrival_us = engine.now_us();
                            waiters.insert(req.id, reply);
                            engine.submit(req);
                        }
                        Ok(Cmd::Stats(reply)) => {
                            let _ = reply.send(engine.metrics.to_json());
                        }
                        Ok(Cmd::Shutdown) => return,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => return,
                    }
                }
                match engine.tick() {
                    Ok(Tick::Progress) => {
                        for fin in engine.drain_finished() {
                            if let Some(w) = waiters.remove(&fin.id) {
                                let _ = w.send(fin);
                            }
                        }
                    }
                    Ok(Tick::Idle) => {
                        // real-time serving: block briefly for new work
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(e) => {
                        eprintln!("engine error: {e:#}");
                        return;
                    }
                }
            }
        });
        (
            Arc::new(Server { tx, tokenizer, max_ctx }),
            handle,
        )
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }

    pub fn generate(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
    ) -> anyhow::Result<FinishedRequest> {
        anyhow::ensure!(!prompt_tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt_tokens.len() + max_new <= self.max_ctx,
            "prompt+output exceeds context window {}",
            self.max_ctx
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = Request {
            id: 0, // assigned by the engine thread
            tag: 0,
            adapter,
            tokens: prompt_tokens,
            max_new,
            arrival_us: 0,
            ignore_eos: false,
        };
        self.tx
            .send(Cmd::Submit(req, reply_tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("request dropped (OOM?)"))
    }

    pub fn stats(&self) -> anyhow::Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Stats(tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("engine thread gone"))
    }

    /// Blocking accept loop. `max_requests` bounds the loop for tests
    /// (None = run forever).
    pub fn serve_http(&self, addr: &str, max_requests: Option<usize>) -> anyhow::Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("forkkv serving on http://{addr}");
        let mut served = 0usize;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            if let Err(e) = self.handle_conn(stream) {
                eprintln!("conn error: {e:#}");
            }
            served += 1;
            if let Some(max) = max_requests {
                if served >= max {
                    break;
                }
            }
        }
        Ok(())
    }

    fn handle_conn(&self, mut stream: TcpStream) -> anyhow::Result<()> {
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut request_line = String::new();
        reader.read_line(&mut request_line)?;
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();

        let mut content_len = 0usize;
        loop {
            let mut line = String::new();
            reader.read_line(&mut line)?;
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some(v) = line
                .to_ascii_lowercase()
                .strip_prefix("content-length:")
                .map(|v| v.trim().to_string())
            {
                content_len = v.parse().unwrap_or(0);
            }
        }
        let mut body = vec![0u8; content_len];
        reader.read_exact(&mut body)?;
        let body = String::from_utf8_lossy(&body).to_string();

        let (status, payload) = match (method.as_str(), path.as_str()) {
            ("POST", "/generate") => match self.api_generate(&body) {
                Ok(j) => ("200 OK", j),
                Err(e) => (
                    "400 Bad Request",
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
                ),
            },
            ("GET", "/stats") => match self.stats() {
                Ok(j) => ("200 OK", j),
                Err(e) => (
                    "500 Internal Server Error",
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
                ),
            },
            ("GET", "/health") => ("200 OK", Json::obj(vec![("ok", Json::Bool(true))])),
            _ => (
                "404 Not Found",
                Json::obj(vec![("error", Json::str("not found"))]),
            ),
        };
        let body = payload.to_string();
        let resp = format!(
            "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(resp.as_bytes())?;
        Ok(())
    }

    fn api_generate(&self, body: &str) -> anyhow::Result<Json> {
        let j = json::parse(body).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
        let prompt = j.req_str("prompt")?;
        let adapter = j.get("adapter").and_then(Json::as_usize).unwrap_or(0) as u32;
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let tokens = self.tokenizer.encode(prompt);
        let fin = self.generate(tokens, adapter, max_new)?;
        Ok(Json::obj(vec![
            (
                "tokens",
                Json::Arr(fin.generated.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("text", Json::str(self.tokenizer.decode(&fin.generated))),
            ("prompt_tokens", Json::num(fin.prompt_len as f64)),
            ("hit_tokens", Json::num(fin.hit_full as f64)),
            ("ttft_us", Json::num(fin.ttft_us() as f64)),
            ("latency_us", Json::num(fin.latency_us() as f64)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheConfig, CachePolicy, EngineConfig};
    use crate::exec::SimExecutor;

    fn sim_server() -> (Arc<Server>, std::thread::JoinHandle<()>) {
        let cfg = EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig { page_tokens: 16, budget_bytes: 32 << 20 },
            ..EngineConfig::default()
        };
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
        let engine = Engine::new(cfg, Box::new(sim)).unwrap();
        Server::start(engine)
    }

    #[test]
    fn generate_round_trip_over_engine_thread() {
        let (srv, handle) = sim_server();
        let tokens: Vec<u32> = (10..90).collect();
        let fin = srv.generate(tokens, 1, 8).unwrap();
        assert_eq!(fin.generated.len(), 8);
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn http_round_trip() {
        let (srv, handle) = sim_server();
        let srv2 = srv.clone();
        let addr = "127.0.0.1:18731";
        let server_thread = {
            let srv = srv.clone();
            let addr = addr.to_string();
            std::thread::spawn(move || srv.serve_http(&addr, Some(2)).unwrap())
        };
        std::thread::sleep(std::time::Duration::from_millis(100));

        let body = r#"{"prompt": "the quick brown fox jumps over the lazy dog", "adapter": 2, "max_new": 6}"#;
        let mut conn = TcpStream::connect(addr).unwrap();
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        conn.write_all(req.as_bytes()).unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        let json_body = resp.split("\r\n\r\n").nth(1).unwrap();
        let j = json::parse(json_body).unwrap();
        assert_eq!(j.at(&["tokens"]).as_arr().unwrap().len(), 6);

        // stats endpoint
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");

        server_thread.join().unwrap();
        srv2.shutdown();
        handle.join().unwrap();
    }
}
