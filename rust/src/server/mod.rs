//! Minimal HTTP/1.1 JSON serving front-end (hand-rolled on std::net — the
//! offline vendor set has no hyper/axum/tokio; DESIGN.md §3).
//!
//! POST /generate {"prompt": "...", "adapter": 3, "max_new": 24, "tag": 0,
//!                 "fan": 0, "step": "map0", "steps": [...]}
//!   -> {"tokens": [...], "text": "...", "ttft_us": ..., "latency_us": ...}
//!
//! `tag` is the opaque workflow id (affinity routing + the shard's gang
//! scheduler group key); `fan` optionally declares how many requests of
//! the tag form one workflow step, so the shard may gang-admit them.
//! `steps` registers the workflow's steps-to-execute DAG (see below) and
//! `step` names which DAG node this request executes.
//! GET /stats   -> aggregated pool metrics JSON
//! GET /metrics -> per-shard snapshots + the same aggregate + route policy
//!
//! Concurrency model: an **engine shard pool** owns the serving core — N
//! independent `Engine` instances (each with its own executor, pools and
//! radix trees, byte budget split N ways), one event-driven thread per
//! shard. A bounded pool of connection workers (`ServerConfig::workers`)
//! parses HTTP and submits each request to the shard chosen by the
//! `router` module: `affinity` placement hashes the prompt's first
//! page-aligned window (plus the workflow tag) so agents forking a shared
//! context land on the shard that already holds its bCache pages, spilling
//! to the least-loaded shard past `imbalance_factor`; `round_robin` is the
//! placement-oblivious baseline. Because many `/generate` calls are in
//! flight at once, each shard's continuous batching forms real
//! multi-sequence decode batches.
//!
//! Reply protocol: a shard answers every submitted request with a
//! `RequestOutcome` — `Finished` (max_new or EOS) or `Dropped` (OOM
//! eviction) — so a waiter can never hang on a request the engine gave up
//! on. Each shard thread is event-driven: it blocks on its command channel
//! (`recv_timeout`) whenever the engine reports `Tick::Idle` instead of
//! spinning on a sleep loop. The per-shard in-flight count doubles as the
//! router's load signal.
//!
//! Elastic shard budgets: each shard starts with a 1/N slice of the byte
//! budget, but a skewed workflow can saturate its home slice while
//! neighbors idle. A rebalance supervisor thread (`forkkv-rebalance`)
//! periodically reads every shard's budget pressure (`Cmd::Pressure`) and
//! lends free budget from cold shards to hot ones (`Cmd::Budget`, the
//! `rebalance` module's planner) — bounded by `lend_max_frac` so no shard
//! is starved, conserving the pool total. The per-shard `budget_bytes`
//! gauge and the pool's `budget_rebalances`/`bytes_lent` counters are
//! served by `GET /metrics`.
//!
//! Tiered KV pages: when the engines run with a host-memory tier
//! (`--tier on`), evicted pages demote into per-shard `TierStore`s and
//! returning sessions promote them back at admission (see the `tier`
//! module and `Engine::promote_from_tier`). Promotion and replacement
//! leave dead records whose bytes stay retained until a compaction
//! pass, so a tier supervisor thread (`forkkv-tier`) periodically fans
//! `Cmd::TierCompact` across the shards (`tier_compact_ms`); the pool's
//! `tier_compactions`/`tier_bytes_reclaimed` counters are served by
//! `GET /metrics` under the `tier` object.
//!
//! Cross-step prefetch (the KVFlow horizon): a workflow may declare its
//! steps-to-execute DAG up front (`"steps"`: nodes with tags, dependency
//! edges, and declared prefix provenance — map→reduce fans, ReAct loops,
//! pipeline chains). While a step's predecessors are decoding, the
//! successor's known prefix is already resolvable (a literal declared
//! prefix, or the prompt a predecessor submitted), so the server pins and
//! pre-warms its pages on the successor's *home* shard before the
//! successor request ever arrives: `Cmd::Prefetch` promotes demoted pages
//! from the host tier and soft-pins the resident coverage under a
//! prefetch lease, and when the prefix lives on a different shard the
//! PR 3 migration pipeline pre-ships it, priced by the same cost model.
//! Leases are released exactly once — by the step's arrival (a
//! `prefetch_hit`) or by the `forkkv-prefetch` supervisor when the DAG is
//! abandoned (`prefetch_abandon_ms` without progress; the covered pages
//! count as `prefetch_wasted`). `prefetch_horizon` bounds how many steps
//! past the decoding frontier are warmed.
//!
//! Spill = bandwidth, not FLOPs: when the router spills a request off an
//! overloaded home shard, the worker first runs the migration pipeline
//! (`Cmd::Probe` → cost model → `Cmd::Export` → `Cmd::Import`, see
//! `try_migrate` and the `migrate` module) so the target shard holds the
//! request's cached pages before its `Cmd::Submit` arrives on the same
//! FIFO channel. Dead shards are routed around: a failed submission is
//! re-routed to the least-loaded live shard (`rerouted` in `/metrics`)
//! and `/metrics` reports `{"dead": true}` per dead shard instead of
//! failing the snapshot.
//!
//! Hot-context replication (one-to-many): point-to-point migration
//! re-ships the same read-mostly shared prefix once per spill, forever.
//! With `--replicate on` the server keeps a replica map (prefix
//! fingerprint → shards holding a warm copy, fed by migration imports,
//! replications, prefetch pins, and shard death/restart events) and a
//! per-prefix read-mostly detector (fork rate vs extend rate over a
//! sliding window). A spill is steered onto a replica holder first,
//! verified by a read-only probe — a stale entry (the holder evicted or
//! demoted the replica) unregisters on use instead of routing the fork
//! into a cold prefill. A prefix that keeps spill-missing on the same
//! shard (`replicate_miss_threshold`) while classified read-mostly earns
//! a proactive replica there: `Cmd::ReplicaWarm` re-promotes anything
//! the target's host tier still holds, then the PR 3 export/import path
//! ships the rest (leased on the source, priced against recompute,
//! bounded by the same migration queue). An *extend* of the parent
//! context bumps the prefix's invalidation epoch and clears every
//! holder; shard death strips the corpse from every resident set and a
//! restarted shard re-enters holding nothing. The rebalancer weights
//! budget toward replica holders (`BudgetPressure::hot_replicas`), and
//! `GET /metrics` serves the `replication` counters.
#![warn(missing_docs)]

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::engine::{Engine, Request, Tick};
use crate::exec::CostModel;
use crate::journal::{Journal, SubmitRecord};
use crate::metrics::{
    self, DropReason, DroppedRequest, FinishedRequest, RequestOutcome,
};
use crate::migrate::{MigrationEstimate, MigrationPayload, MigrationPolicy};
use crate::rebalance::{BudgetPressure, Rebalancer};
use crate::router::{Placement, ReadMostly, ReplicaMap, Router};
use crate::tier::TierStore;
use crate::util::json::{self, Json};
use crate::util::lockstats::{locks_json, LockStat};
use crate::util::tokenizer::HashTokenizer;

enum Cmd {
    Submit(Request, mpsc::Sender<RequestOutcome>),
    /// Migration step 1: how many cached pages would this prompt match
    /// on this shard? Read-only — prices the migration before any bytes
    /// move.
    Probe {
        adapter: u32,
        tokens: Vec<u32>,
        reply: mpsc::Sender<MigrationEstimate>,
    },
    /// Migration step 2: snapshot the matched pages (bytes + token
    /// path) out of this shard's pool, under eviction-safe leases.
    Export {
        adapter: u32,
        tokens: Vec<u32>,
        reply: mpsc::Sender<MigrationPayload>,
    },
    /// Migration step 3: adopt a peer shard's snapshot into this
    /// shard's pool + trees. Enqueued on the same FIFO channel as the
    /// spilled request's Submit, so the pages are in place by admission.
    Import(Box<MigrationPayload>),
    Stats(mpsc::Sender<Json>),
    /// Elastic budgets step 1: this shard's budget-pressure snapshot
    /// (used bytes, enforced budget, physical capacity, denial/drop
    /// counters) — cheap and read-only, what the rebalance supervisor
    /// polls every tick.
    Pressure(mpsc::Sender<BudgetPressure>),
    /// Elastic budgets step 2: set this shard's enforced byte budget.
    /// A shrink converges immediately (`Engine::set_budget_bytes` evicts
    /// cold unpinned radix pages down to the new budget); a grow takes
    /// effect at the next allocation.
    Budget(usize),
    /// Compact this shard's host-memory tier (drop dead demoted-page
    /// records, reclaim their bytes); replies with the bytes reclaimed.
    /// A no-op returning 0 when the shard runs without a tier.
    TierCompact(mpsc::Sender<usize>),
    /// Cross-step prefetch: pre-warm and pin a future step's known
    /// prefix under a lease (`Engine::prefetch_pin` — tier promotion +
    /// soft pins). Replies with the pages the lease covers; 0 means
    /// nothing was resident yet and no lease was left behind.
    Prefetch {
        lease: u64,
        adapter: u32,
        tokens: Vec<u32>,
        reply: mpsc::Sender<usize>,
    },
    /// Release a prefetch lease exactly once (`Engine::prefetch_release`):
    /// `hit` when the warmed step arrived, abandonment otherwise.
    PrefetchRelease { lease: u64, hit: bool },
    /// Hot-context replication: re-promote a (possibly demoted) replica
    /// prefix from this shard's host tier back on-device
    /// (`Engine::replica_warm` — no pins, no lease; the replica map
    /// verifies residency on use). Replies with the device-resident page
    /// coverage afterwards.
    ReplicaWarm {
        adapter: u32,
        tokens: Vec<u32>,
        reply: mpsc::Sender<usize>,
    },
    /// Snapshot this shard's warm-restart checkpoint: every live radix
    /// leaf path plus every tiered page's token path, metadata only
    /// (`Engine::checkpoint_json`).
    Checkpoint(mpsc::Sender<Json>),
    /// Fault injection: die in place. The shard hands its host-memory
    /// tier back to the supervisor (host memory survives a shard crash;
    /// GPU pool bytes do not) and exits WITHOUT the final waiter drain —
    /// in-flight waiters observe a closed reply channel exactly as they
    /// would under a real crash, and the journal replay path takes over.
    Crash { salvage: mpsc::Sender<Option<TierStore>> },
    Shutdown,
}

/// The server's handle on one engine shard: its command channel plus the
/// in-flight request count the router reads as the shard's load. The
/// sender sits behind an RwLock so a warm restart can install a fresh
/// channel in place (`restart_shard`) while concurrent submitters keep
/// cheap read access; the shared `tx_lock` stat samples its contention.
struct ShardHandle {
    tx: RwLock<mpsc::Sender<Cmd>>,
    depth: Arc<AtomicUsize>,
    tx_lock: Arc<LockStat>,
}

/// Depths at or above this mark a dead shard. A *range* rather than the
/// exact `usize::MAX` poison value, because a poisoned depth can drift:
/// the dying shard's final drain still `fetch_sub`s per outstanding
/// waiter (`MAX - k`), and a racing submitter's `fetch_add` can nudge it
/// up — any of those must still classify as dead, and real queue depths
/// (bounded by sockets/workers) never come near it.
const DEPTH_POISONED: usize = usize::MAX / 2;

impl ShardHandle {
    fn is_poisoned(&self) -> bool {
        self.depth.load(Ordering::Relaxed) >= DEPTH_POISONED
    }

    /// Send through the current channel (restart-safe: a restarted shard
    /// swapped in a fresh sender under the write lock).
    fn send(&self, cmd: Cmd) -> Result<(), mpsc::SendError<Cmd>> {
        self.tx_lock.read(&self.tx).send(cmd)
    }
}

// Pool-wide lock hierarchy, machine-checked by `forkkv analyze`'s
// lock-order pass (any nested acquisition scope must respect this order;
// the journal's internal mutex never escapes its methods, so it can
// never be held across an outcomes/replicas acquisition):
// analyze:lock-order: shard_tx < salvaged < journal < outcomes < replicas
/// The sharded serving pool: N engine shard threads behind a router,
/// plus the supervisor threads (rebalance, tier compaction, prefetch,
/// journal checkpoints) and all pool-level counters `GET /metrics`
/// serves. Built by [`Server::start_sharded`]; clients reach it through
/// the HTTP front-end (`serve`) or the in-process `generate*` helpers.
pub struct Server {
    shards: Vec<ShardHandle>,
    router: Router,
    /// migrate-vs-recompute decision for spilled requests
    migration: MigrationPolicy,
    /// migrations currently in flight (the bounded migration queue)
    mig_inflight: AtomicUsize,
    counters: RouteCounters,
    /// hot-context replication state (None = `--replicate off` or a
    /// single shard): replica map + read-mostly detector + per-prefix
    /// spill-miss tallies under one mutex, consulted only on the spill
    /// path (the common no-spill placement never takes it)
    replication: Option<Mutex<ReplicaTracker>>,
    /// pool-level replication outcome counters (`/metrics`)
    rep_counters: ReplicaCounters,
    /// per-prefix spill attribution — fingerprint → (tag, cold spills) —
    /// always on, so the bench report's `spills_by_prefix` can show the
    /// hot context specifically, replication armed or not
    spill_attr: Mutex<HashMap<u64, (u64, u64)>>,
    /// elastic-budget planner (None = rebalance off or single shard);
    /// the supervisor thread and `rebalance_tick` go through here
    rebalancer: Option<Mutex<Rebalancer>>,
    /// pool-level elastic-budget outcome counters (`/metrics`)
    reb_counters: RebalanceCounters,
    /// pool-level host-tier compaction counters (`/metrics`)
    tier_counters: TierCounters,
    /// registered workflow DAGs keyed by workflow tag (the `"steps"`
    /// payloads); the cross-step prefetch horizon walks these
    dags: Mutex<HashMap<u64, Dag>>,
    /// pool-unique prefetch lease ids (shard engines key their lease
    /// maps by these)
    lease_seq: AtomicU64,
    /// pool-level cross-step prefetch counters (`/metrics`)
    pf_counters: PrefetchCounters,
    /// tells the supervisor threads to exit (set by `shutdown`)
    stop: AtomicBool,
    /// durable request journal + replay/dedup state (None = `--journal
    /// off`: the submit hot path pays nothing)
    journal: Option<JournalState>,
    /// host-memory tiers salvaged from crashed shards, waiting for a
    /// warm restart to adopt them (`kill_shard` -> `restart_shard`)
    salvaged: Mutex<HashMap<usize, TierStore>>,
    salvaged_lock: LockStat,
    /// the shard senders' shared RwLock contention stat (one stat across
    /// the pool: what matters is whether restarts ever stall submitters)
    shard_tx_stat: Arc<LockStat>,
    tokenizer: HashTokenizer,
    max_ctx: usize,
    cfg: ServerConfig,
}

/// The bounded outcome-dedup window: terminal outcomes by idempotency
/// key plus their insertion order (for FIFO aging).
type OutcomeWindow = (HashMap<String, RequestOutcome>, VecDeque<String>);

/// Everything the durable-journal feature hangs off the server: the
/// segmented log itself, the bounded outcome window that deduplicates
/// client retries and hands replayed outcomes back to their original
/// waiters, and the replay/restart counters `GET /metrics` serves.
struct JournalState {
    journal: Journal,
    /// terminal outcomes by idempotency key — a bounded FIFO window
    /// (map + insertion order). Grows per finished request, so it must
    /// be capped: old entries age out once `OUTCOME_WINDOW` newer keys
    /// landed, which bounds how stale a dedup-able retry can be.
    outcomes: Mutex<OutcomeWindow>,
    outcomes_lock: LockStat,
    /// server-generated idempotency keys: a per-process epoch (start
    /// time, nanos) + a counter, so keys never collide across restarts
    key_epoch: u128,
    key_seq: AtomicU64,
    /// dead-shard submits re-executed on a live peer
    replayed_requests: AtomicU64,
    /// replays with no live peer left (the waiter got `ShardLost`)
    replay_failed: AtomicU64,
    /// duplicate client retries answered from the outcome window
    deduped_retries: AtomicU64,
    /// completions that lost the `claim` race to a replayer (the
    /// prevented double-journal — nonzero is fine, it means a request
    /// finished on a shard at the instant the shard was declared dead)
    replay_races: AtomicU64,
    /// journal records found pending at startup and re-executed (the
    /// previous process died holding them)
    recovered_orphans: AtomicU64,
    /// per-shard checkpoint files written (`checkpoint_tick`)
    checkpoints_written: AtomicU64,
}

/// Terminal outcomes kept for retry dedup before aging out.
const OUTCOME_WINDOW: usize = 4096;

impl JournalState {
    fn next_key(&self) -> String {
        format!(
            "srv-{:x}-{}",
            self.key_epoch,
            self.key_seq.fetch_add(1, Ordering::Relaxed)
        )
    }
}

/// Pool-level elastic-budget counters (the `rebalancer` object of
/// `GET /metrics`).
#[derive(Default)]
struct RebalanceCounters {
    /// supervisor ticks that moved at least one byte of budget
    budget_rebalances: AtomicU64,
    /// cumulative bytes of budget lent between shards (each moved byte
    /// counted once, on the donor->borrower transfer)
    bytes_lent: AtomicU64,
}

/// Pool-level host-tier compaction counters (the `tier` object of
/// `GET /metrics`).
#[derive(Default)]
struct TierCounters {
    /// supervisor ticks (or manual `tier_compact_tick`s) that reclaimed
    /// at least one byte of dead tier space
    tier_compactions: AtomicU64,
    /// cumulative tier bytes reclaimed by compaction, summed over shards
    tier_bytes_reclaimed: AtomicU64,
}

/// Pool-level cross-step prefetch counters (the `prefetch` object of
/// `GET /metrics`). Page-granular counters (`prefetched_pages`,
/// `prefetch_hits`, `prefetch_wasted`) live in the engine aggregate.
#[derive(Default)]
struct PrefetchCounters {
    /// workflow DAGs accepted into the registry (re-registrations of a
    /// live tag are idempotent and not re-counted)
    dags_registered: AtomicU64,
    /// prefetch leases issued that covered at least one resident page
    leases_issued: AtomicU64,
    /// leases released by the arrival of the step they warmed
    leases_hit: AtomicU64,
    /// leases released by the supervisor because the step never arrived
    /// (plus any still outstanding when a dead DAG was collected)
    leases_abandoned: AtomicU64,
}

/// Cap on the number of steps one workflow DAG may declare.
const MAX_DAG_NODES: usize = 64;

/// A DAG goes unreachable (and its leases are abandoned) after this many
/// `prefetch_abandon_ms` windows pass with no arrival or completion.
const DAG_GC_FACTOR: u32 = 8;

/// A registered steps-to-execute DAG: one workflow's declared future,
/// the input to the prefetch horizon.
struct Dag {
    nodes: Vec<DagNode>,
    /// last registration / arrival / completion, for abandonment GC
    touched: Instant,
}

/// One declared workflow step.
struct DagNode {
    id: String,
    /// indices into `Dag::nodes` of this step's predecessors
    after: Vec<usize>,
    /// adapter the step will decode under (prefetch warms that
    /// namespace's residual pages too)
    adapter: u32,
    /// routing tag the step will arrive under — usually the workflow
    /// tag; a declared per-step tag routes the step to its own home
    tag: u64,
    prefix: PrefixSpec,
    state: NodeState,
    /// the prompt the step actually submitted (recorded at arrival; the
    /// resolution source for successors' `prefix_from`)
    prompt: Option<Vec<u32>>,
    /// abandoned by the supervisor — never warmed again
    abandoned: bool,
    /// the live prefetch lease warming this step, if any
    lease: Option<IssuedLease>,
}

/// Declared prefix provenance of a step: where its known prefix comes
/// from before the step itself exists.
enum PrefixSpec {
    /// no declared prefix — the step is never prefetched
    None,
    /// a literal prefix string, tokenized at registration
    Literal(Vec<u32>),
    /// the prompt of another step (by index), known once that step
    /// arrives
    FromStep(usize),
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum NodeState {
    Pending,
    Running,
    Done,
}

/// A lease the server issued against a shard engine's prefetch map.
#[derive(Clone, Copy)]
struct IssuedLease {
    id: u64,
    shard: usize,
    issued: Instant,
}

impl Dag {
    /// Steps-from-the-frontier distance per node: 0 for running or done
    /// steps; a pending step is 1 + the max distance over its pending
    /// predecessors (so a root, or a step whose predecessors have all
    /// arrived, is distance 1). Registration rejects cycles, so the
    /// recursion is well-founded.
    // analyze:allow(panic_path, fn) node/edge indices validated at DAG registration; memo is sized to nodes.len()
    fn distances(&self) -> Vec<usize> {
        fn d(nodes: &[DagNode], i: usize, memo: &mut [Option<usize>]) -> usize {
            if let Some(v) = memo[i] {
                return v;
            }
            let v = match nodes[i].state {
                NodeState::Running | NodeState::Done => 0,
                NodeState::Pending => {
                    1 + nodes[i]
                        .after
                        .iter()
                        .map(|&p| d(nodes, p, memo))
                        .max()
                        .unwrap_or(0)
                }
            };
            memo[i] = Some(v);
            v
        }
        let mut memo = vec![None; self.nodes.len()];
        (0..self.nodes.len())
            .map(|i| d(&self.nodes, i, &mut memo))
            .collect()
    }

    /// The resolvable known prefix of step `i`: its declared literal, or
    /// the prompt its provenance step submitted (None until that step
    /// arrives).
    // analyze:allow(panic_path, fn) callers iterate 0..nodes.len(); FromStep indices validated at registration
    fn resolve_prefix(&self, i: usize) -> Option<Vec<u32>> {
        match &self.nodes[i].prefix {
            PrefixSpec::Literal(t) => Some(t.clone()),
            PrefixSpec::FromStep(p) => self.nodes[*p].prompt.clone(),
            PrefixSpec::None => None,
        }
    }
}

/// One planned prefetch, recorded under the registry lock and executed
/// outside it (the migration round trips must not serialize the whole
/// registry).
struct PrefetchPlan {
    tag: u64,
    node: usize,
    lease: u64,
    adapter: u32,
    tokens: Vec<u32>,
    /// the successor's home shard — where the pages must be warm
    target: usize,
    /// the prefix's provenance shard (first predecessor's home), the
    /// pre-migration source when it differs from `target`
    source: Option<usize>,
    /// the tag the successor step will arrive under — the fingerprint
    /// key a successful prefetch registers in the replica map
    route_tag: u64,
}

/// The replication subsystem's mutable state, under one server mutex.
struct ReplicaTracker {
    /// prefix fingerprint → verified-on-use resident-shard set
    map: ReplicaMap,
    /// per-prefix fork-vs-extend classifier (the replication gate)
    detector: ReadMostly,
    /// per-prefix, per-shard cold spill-misses since the last
    /// replication or invalidation — the one-to-many trigger tally
    misses: HashMap<u64, HashMap<usize, u32>>,
}

/// Pool-level hot-context replication counters (the `replication`
/// object of `GET /metrics`).
#[derive(Default)]
struct ReplicaCounters {
    /// replicas planted by the one-to-many path (zero-copy plants — the
    /// target already warm, or promoted from its host tier — included;
    /// `replica_bytes` isolates the actual copy traffic)
    replications: AtomicU64,
    /// spills served by routing onto a verified replica holder (no
    /// copy, no cold prefill)
    replica_hits: AtomicU64,
    /// replica registrations dropped — parent-context extends (each
    /// cleared holder counts) plus stale entries caught by the
    /// verify-on-use probe
    replica_invalidations: AtomicU64,
    /// cumulative payload bytes shipped by replications (kept separate
    /// from migration traffic)
    replica_bytes: AtomicU64,
}

/// What a spill decided to do about its cached pages.
enum SpillAction {
    /// run the PR 3 point-to-point migration pipeline (the default)
    Migrate,
    /// this shard earned a proactive replica of a hot read-mostly
    /// prefix (second spill-miss + detector agreement)
    Replicate,
    /// the chosen target verifiably holds the prefix already: no copy,
    /// and not a cold miss
    ReplicaHit,
}

/// Outcome of one run of the export/import shipping pipeline.
#[derive(Clone, Copy)]
enum Ship {
    /// pages crossed the wire — carries the payload byte count
    Shipped(usize),
    /// the target already held at least as much of the prefix, so the
    /// copy was skipped (still a success for residency purposes)
    AlreadyWarm,
    /// inflight cap, probe miss, empty payload, or a dead shard — no
    /// residency claim can be made
    Skipped,
}

/// Cap on distinct prefixes in the spill-attribution table. On overflow
/// the table resets — it feeds A/B bench reports, not billing.
const MAX_SPILL_ATTR: usize = 512;

/// Pool-level routing/migration outcome counters (served by `/metrics`).
#[derive(Default)]
struct RouteCounters {
    /// requests placed off their affinity home for load balance
    spills: AtomicU64,
    /// spills whose cached pages were migrated to the target shard
    migrations: AtomicU64,
    /// spills that proceeded without migration (queue full, nothing
    /// cached, cost model said recompute, target already warm, or the
    /// home shard was gone)
    migration_skipped: AtomicU64,
    /// submissions re-routed off a dead shard to a live one
    rerouted: AtomicU64,
}

/// Decrement-on-drop slot guard for the bounded migration queue.
struct MigSlot<'a>(&'a AtomicUsize);

impl Drop for MigSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// How the shard loop proceeds after one command.
enum Flow {
    Continue,
    /// orderly exit: drain every remaining waiter first
    Shutdown,
    /// fault-injected death: exit WITHOUT the drain, so waiters see the
    /// closed channel a real crash would leave behind
    Crash,
}

/// Apply one command on a shard thread.
fn handle_cmd(
    engine: &mut Engine,
    waiters: &mut HashMap<u64, mpsc::Sender<RequestOutcome>>,
    next_id: &mut u64,
    cmd: Cmd,
) -> Flow {
    match cmd {
        Cmd::Submit(mut req, reply) => {
            req.id = *next_id;
            *next_id += 1;
            req.arrival_us = engine.now_us();
            waiters.insert(req.id, reply);
            engine.submit(req);
            Flow::Continue
        }
        Cmd::Probe { adapter, tokens, reply } => {
            let _ = reply.send(engine.migration_probe(adapter, &tokens));
            Flow::Continue
        }
        Cmd::Export { adapter, tokens, reply } => {
            let _ = reply.send(engine.export_pages(adapter, &tokens));
            Flow::Continue
        }
        Cmd::Import(payload) => {
            engine.import_pages(&payload);
            Flow::Continue
        }
        Cmd::Stats(reply) => {
            let _ = reply.send(engine.stats_json());
            Flow::Continue
        }
        Cmd::Pressure(reply) => {
            let _ = reply.send(engine.budget_pressure());
            Flow::Continue
        }
        Cmd::Budget(bytes) => {
            engine.set_budget_bytes(bytes);
            Flow::Continue
        }
        Cmd::TierCompact(reply) => {
            let _ = reply.send(engine.tier_compact());
            Flow::Continue
        }
        Cmd::Prefetch { lease, adapter, tokens, reply } => {
            let _ = reply.send(engine.prefetch_pin(lease, adapter, &tokens));
            Flow::Continue
        }
        Cmd::PrefetchRelease { lease, hit } => {
            engine.prefetch_release(lease, hit);
            Flow::Continue
        }
        Cmd::ReplicaWarm { adapter, tokens, reply } => {
            let _ = reply.send(engine.replica_warm(adapter, &tokens));
            Flow::Continue
        }
        Cmd::Checkpoint(reply) => {
            let _ = reply.send(engine.checkpoint_json());
            Flow::Continue
        }
        Cmd::Crash { salvage } => {
            let _ = salvage.send(engine.take_tier());
            Flow::Crash
        }
        Cmd::Shutdown => Flow::Shutdown,
    }
}

/// Route every terminal outcome back to its waiter (completions and
/// drops), releasing the shard's depth slot *before* the reply so a
/// routing decision racing the reply never sees phantom load.
fn deliver(
    engine: &mut Engine,
    waiters: &mut HashMap<u64, mpsc::Sender<RequestOutcome>>,
    depth: &AtomicUsize,
) {
    for fin in engine.drain_finished() {
        if let Some(w) = waiters.remove(&fin.id) {
            depth.fetch_sub(1, Ordering::Relaxed);
            let _ = w.send(RequestOutcome::Finished(fin));
        }
    }
    for d in engine.drain_dropped() {
        if let Some(w) = waiters.remove(&d.id) {
            depth.fetch_sub(1, Ordering::Relaxed);
            let _ = w.send(RequestOutcome::Dropped(d));
        }
    }
}

/// One shard's event loop: the engine-thread driver extracted so N copies
/// run as peers. Owns its `Engine` exclusively; the only shared state is
/// the command channel and the atomic depth counter.
fn run_shard(
    mut engine: Engine,
    rx: mpsc::Receiver<Cmd>,
    depth: Arc<AtomicUsize>,
    idle_wait: Duration,
) {
    let mut waiters: HashMap<u64, mpsc::Sender<RequestOutcome>> = HashMap::new();
    let mut next_id = 1u64;
    'run: loop {
        // drain every queued command so concurrent submissions enter the
        // same scheduling step and co-batch
        loop {
            match rx.try_recv() {
                Ok(cmd) => {
                    match handle_cmd(&mut engine, &mut waiters, &mut next_id, cmd) {
                        Flow::Continue => {}
                        Flow::Shutdown => break 'run,
                        Flow::Crash => return, // no drain: waiters see a dead shard
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => break 'run,
            }
        }
        match engine.tick() {
            Ok(Tick::Progress) => deliver(&mut engine, &mut waiters, &depth),
            Ok(Tick::Idle) => {
                // event-driven: block until work arrives rather than
                // spinning; the timeout only bounds how stale a raced
                // command can get
                match rx.recv_timeout(idle_wait) {
                    Ok(cmd) => {
                        match handle_cmd(&mut engine, &mut waiters, &mut next_id, cmd) {
                            Flow::Continue => {}
                            Flow::Shutdown => break 'run,
                            Flow::Crash => return,
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break 'run,
                }
            }
            Err(e) => {
                eprintln!("engine shard error: {e:#}");
                break 'run;
            }
        }
    }
    // final drain so no waiter hangs across shutdown; the map (and thus
    // every remaining reply channel) drops after this
    deliver(&mut engine, &mut waiters, &depth);
}

impl Server {
    /// Spawn a single-shard pool with default `ServerConfig`.
    pub fn start(engine: Engine) -> (Arc<Server>, std::thread::JoinHandle<()>) {
        Self::start_with(engine, ServerConfig::default())
    }

    /// Spawn a single-shard pool around one engine (`cfg.shards` is
    /// overridden to 1; multi-shard pools go through `start_sharded`).
    pub fn start_with(
        engine: Engine,
        cfg: ServerConfig,
    ) -> (Arc<Server>, std::thread::JoinHandle<()>) {
        let (srv, mut handles) = Self::start_sharded(vec![engine], cfg);
        // index 0 is always the shard thread; any supervisor handles
        // behind it are detached here and exit on the shutdown stop flag
        (srv, handles.remove(0))
    }

    /// Spawn one event-driven thread per engine shard; returns the
    /// submission handle plus every shard's join handle. The engines must
    /// agree on model geometry (vocab / context window / page size) —
    /// they are peers serving one logical model.
    pub fn start_sharded(
        engines: Vec<Engine>,
        mut cfg: ServerConfig,
    ) -> (Arc<Server>, Vec<std::thread::JoinHandle<()>>) {
        assert!(!engines.is_empty(), "shard pool needs at least one engine");
        cfg.shards = engines.len();
        let meta = engines[0].meta().clone();
        let page_tokens = engines[0].cfg.cache.page_tokens;
        for e in &engines {
            assert_eq!(e.meta().vocab, meta.vocab, "shards must share a vocab");
            assert_eq!(e.meta().s_max, meta.s_max, "shards must share s_max");
            assert_eq!(
                e.cfg.cache.page_tokens, page_tokens,
                "shards must share page geometry (the affinity window)"
            );
        }
        let idle_wait = Duration::from_millis(cfg.idle_wait_ms.max(1));
        // the planner's authoritative starting point: whatever budgets
        // the engines were constructed with (normally `shard_slice`)
        let base_budgets: Vec<usize> = engines.iter().map(|e| e.budget_bytes()).collect();
        let shard_tx_stat = Arc::new(LockStat::new("shard_tx"));
        let mut shards = Vec::with_capacity(engines.len());
        let mut handles = Vec::with_capacity(engines.len() + 1);
        for (i, engine) in engines.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Cmd>();
            let depth = Arc::new(AtomicUsize::new(0));
            let thread_depth = depth.clone();
            let handle = std::thread::Builder::new()
                .name(format!("forkkv-shard-{i}"))
                .spawn(move || run_shard(engine, rx, thread_depth, idle_wait))
                // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                .expect("spawn engine shard thread");
            shards.push(ShardHandle {
                tx: RwLock::new(tx),
                depth,
                tx_lock: shard_tx_stat.clone(),
            });
            handles.push(handle);
        }
        let router = Router::new(
            cfg.route_policy,
            shards.len(),
            page_tokens,
            cfg.imbalance_factor,
        );
        // the migrate-vs-recompute price list: a calibrated cost model
        // when the CLI loaded one (measured FLOPs + memcpy bandwidth
        // from `forkkv calibrate`), else model-derived FLOP terms with
        // the configured inter-shard bandwidth
        let cost = cfg.migration_cost.clone().unwrap_or_else(|| {
            let mut c = CostModel::derived(&meta);
            c.migration_bandwidth_bytes_per_s = cfg.migration_bandwidth_bytes_per_s;
            c
        });
        let migration = MigrationPolicy::new(cfg.migrate && shards.len() > 1, cost);
        // elastic budgets need a peer to borrow from and a nonzero lend
        // allowance; otherwise the static split stands
        let rebalancer = (cfg.rebalance && shards.len() > 1 && cfg.lend_max_frac > 0.0)
            .then(|| Mutex::new(Rebalancer::new(base_budgets, cfg.lend_max_frac)));
        // the durable request journal: opening replays existing segments
        // (rebuilding the pending map from a previous process) and the
        // per-process key epoch keeps server-generated idempotency keys
        // unique across restarts
        let journal = cfg.journal.then(|| {
            let journal = Journal::open(
                cfg.journal_dir.clone(),
                cfg.journal_sync_ms,
                cfg.journal_sync_bytes,
                cfg.journal_segment_bytes,
            )
            // analyze:allow(panic_path) startup-only: an unopenable journal dir must abort before any request is accepted
            .expect("open request journal");
            let key_epoch = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or(0);
            JournalState {
                journal,
                outcomes: Mutex::new((HashMap::new(), VecDeque::new())),
                outcomes_lock: LockStat::new("outcomes"),
                key_epoch,
                key_seq: AtomicU64::new(1),
                replayed_requests: AtomicU64::new(0),
                replay_failed: AtomicU64::new(0),
                deduped_retries: AtomicU64::new(0),
                replay_races: AtomicU64::new(0),
                recovered_orphans: AtomicU64::new(0),
                checkpoints_written: AtomicU64::new(0),
            }
        });
        // hot-context replication: like migration, the subsystem only
        // makes sense with a peer to replicate onto. The detector's
        // slack is one affinity window — a tail that grows less than a
        // page is fork noise, not a parent-context extend.
        let replication = (cfg.replicate && cfg.shards > 1).then(|| {
            Mutex::new(ReplicaTracker {
                map: ReplicaMap::new(cfg.shards),
                detector: ReadMostly::new(
                    cfg.replicate_window,
                    cfg.replicate_min_forks,
                    page_tokens,
                ),
                misses: HashMap::new(),
            })
        });
        let srv = Arc::new(Server {
            shards,
            router,
            migration,
            mig_inflight: AtomicUsize::new(0),
            counters: RouteCounters::default(),
            replication,
            rep_counters: ReplicaCounters::default(),
            spill_attr: Mutex::new(HashMap::new()),
            rebalancer,
            reb_counters: RebalanceCounters::default(),
            tier_counters: TierCounters::default(),
            dags: Mutex::new(HashMap::new()),
            lease_seq: AtomicU64::new(1),
            pf_counters: PrefetchCounters::default(),
            stop: AtomicBool::new(false),
            journal,
            salvaged: Mutex::new(HashMap::new()),
            salvaged_lock: LockStat::new("salvaged"),
            shard_tx_stat,
            tokenizer: HashTokenizer::new(meta.vocab),
            max_ctx: meta.s_max,
            cfg,
        });
        // orphan recovery: Submit records a previous process accepted but
        // never outcomed are re-executed before this pool serves traffic
        // — a restart must not silently drop accepted work
        if let Some(js) = srv.journal.as_ref() {
            let orphans = js.journal.claim_all();
            if !orphans.is_empty() {
                js.recovered_orphans
                    .fetch_add(orphans.len() as u64, Ordering::Relaxed);
                for rec in orphans {
                    srv.replay_one(&rec);
                }
            }
        }
        if srv.rebalancer.is_some() {
            let sup = srv.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("forkkv-rebalance".into())
                    .spawn(move || sup.rebalance_supervisor())
                    // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                    .expect("spawn rebalance supervisor thread"),
            );
        }
        // dead tier records (promoted or superseded demotions) retain
        // bytes until a compaction pass; the supervisor keeps that
        // retained-over-live gap bounded in wall time
        if srv.cfg.tier && srv.cfg.tier_compact_ms > 0 {
            let sup = srv.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("forkkv-tier".into())
                    .spawn(move || sup.tier_compact_supervisor())
                    // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                    .expect("spawn tier compaction supervisor thread"),
            );
        }
        // the prefetch supervisor retries prefixes that were not yet
        // resident when first planned, and abandons leases for steps
        // that never arrived; a zero tick interval parks it (tests
        // drive `prefetch_tick` by hand)
        if srv.cfg.prefetch && srv.cfg.prefetch_tick_ms > 0 {
            let sup = srv.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("forkkv-prefetch".into())
                    .spawn(move || sup.prefetch_supervisor())
                    // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                    .expect("spawn prefetch supervisor thread"),
            );
        }
        // the group-commit pacer: without it a quiet journal could hold
        // buffered records unsynced past `journal_sync_ms` (appends only
        // check the thresholds when they happen)
        if srv.journal.is_some() && srv.cfg.journal_sync_ms > 0 {
            let sup = srv.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("forkkv-journal".into())
                    .spawn(move || sup.journal_supervisor())
                    // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                    .expect("spawn journal supervisor thread"),
            );
        }
        // periodic warm-restart checkpoints (plus the final one taken by
        // `shutdown`); a zero interval parks it (tests drive
        // `checkpoint_tick` by hand)
        if srv.journal.is_some() && srv.cfg.checkpoint_ms > 0 {
            let sup = srv.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("forkkv-checkpoint".into())
                    .spawn(move || sup.checkpoint_supervisor())
                    // analyze:allow(panic_path) startup-only: fails on OS thread exhaustion before any request is accepted
                    .expect("spawn checkpoint supervisor thread"),
            );
        }
        (srv, handles)
    }

    /// Stop the pool: signal the supervisor threads, take a final
    /// warm-restart checkpoint, flush the journal's group-commit buffer,
    /// and send every shard `Cmd::Shutdown` (each drains its in-flight
    /// waiters before exiting).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // a final checkpoint + group-commit flush: the next process
        // warm-starts from here, and no accepted record stays buffered
        // in memory across the exit
        self.checkpoint_tick();
        if let Some(js) = self.journal.as_ref() {
            js.journal.sync();
        }
        for shard in &self.shards {
            let _ = shard.send(Cmd::Shutdown);
        }
    }

    /// Drain one shard out of rotation (maintenance / tests): stop its
    /// thread and poison its depth so the router routes around it. New
    /// submissions that would have landed there are re-routed (counted
    /// as `rerouted` in `/metrics`); its in-flight requests still get
    /// terminal replies from the thread's final drain.
    pub fn shutdown_shard(&self, shard: usize) {
        let _ = self.shard(shard).send(Cmd::Shutdown);
        self.poison_shard(shard);
    }

    /// Mark a shard dead for routing: poison its depth (affinity spills
    /// away, least-loaded never picks it) and drop it from every replica
    /// set so no spill routes a fork onto pages that no longer exist.
    fn poison_shard(&self, shard: usize) {
        self.shard(shard).depth.store(usize::MAX, Ordering::Relaxed);
        if let Some(rep) = &self.replication {
            rep.lock().unwrap_or_else(|e| e.into_inner()).map.shard_dead(shard);
        }
    }

    /// The pool's effective configuration (after `start_sharded`
    /// overrode `shards` with the actual engine count).
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// The handle of shard `i` — the pool's one index-into-`shards`
    /// site. Every caller's index comes from the router (bounded by the
    /// pool size it was built with), a registered DAG lease/plan, or a
    /// bounds-checked admin path.
    // analyze:allow(panic_path, fn) callers' shard indices are router-produced or validated, always < shards.len()
    fn shard(&self, i: usize) -> &ShardHandle {
        &self.shards[i]
    }

    /// Request limits shared by every entry point (direct and HTTP): the
    /// single source of truth for what the engines will accept.
    fn validate_request(&self, prompt_tokens: &[u32], max_new: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!prompt_tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt_tokens.len() + max_new <= self.max_ctx,
            "prompt+output exceeds context window {}",
            self.max_ctx
        );
        Ok(())
    }

    /// Submit to the routed shard and wait for the request's terminal
    /// outcome (completion or engine-initiated drop). A spill off an
    /// overloaded home shard first runs the page-migration pipeline (see
    /// `try_migrate`), and a submission to a dead shard is re-routed to
    /// a live one. Errors only when the request never reached any live
    /// shard or its shard died mid-flight.
    pub fn generate_outcome_tagged(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
        tag: u64,
    ) -> anyhow::Result<RequestOutcome> {
        self.generate_outcome_hinted(prompt_tokens, adapter, max_new, tag, 0)
    }

    /// Like [`Server::generate_outcome_tagged`], with a declared fan
    /// width: `fan = K > 1` tells the target shard's gang scheduler that
    /// K requests of this tag form one workflow step, so admission may
    /// hold briefly (`gang_hold_ms`) for the stragglers and admit the fan
    /// together. `fan <= 1` is a plain tagged submission.
    pub fn generate_outcome_hinted(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
        tag: u64,
        fan: usize,
    ) -> anyhow::Result<RequestOutcome> {
        self.generate_outcome_keyed(prompt_tokens, adapter, max_new, tag, fan, None)
    }

    /// Like [`Server::generate_outcome_hinted`], with an optional
    /// idempotency key (the durable journal's unit of exactly-once).
    /// With the journal on, every submission is journaled under its key
    /// (client-supplied, else server-generated) and a duplicate retry of
    /// an already-terminal key is answered from the outcome window
    /// without re-executing. With the journal off the key is ignored and
    /// the submit path pays nothing.
    pub fn generate_outcome_keyed(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
        tag: u64,
        fan: usize,
        key: Option<String>,
    ) -> anyhow::Result<RequestOutcome> {
        self.validate_request(&prompt_tokens, max_new)?;
        let Some(js) = self.journal.as_ref() else {
            return self.submit_and_wait(prompt_tokens, adapter, max_new, tag, fan, None);
        };
        let key = key.unwrap_or_else(|| js.next_key());
        if let Some(prev) = self.lookup_outcome(&key) {
            // duplicate client retry: the original terminal outcome
            // stands, nothing is re-executed
            js.deduped_retries.fetch_add(1, Ordering::Relaxed);
            return Ok(prev);
        }
        self.submit_and_wait(prompt_tokens, adapter, max_new, tag, fan, Some(&key))
    }

    /// The shared submission core: route (with spill-migration), submit
    /// to a live shard (re-routing around dead ones), and wait for the
    /// terminal outcome. With `journal_key` set, the accepted submission
    /// is journaled against the shard that owns it, the outcome is
    /// journaled exactly once (the `claim` gate), and a shard dying
    /// mid-flight triggers replay of everything it owed instead of an
    /// error — the waiter then collects its key's replayed outcome.
    fn submit_and_wait(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
        tag: u64,
        fan: usize,
        journal_key: Option<&str>,
    ) -> anyhow::Result<RequestOutcome> {
        let depths: Vec<usize> = self
            .shards
            .iter()
            .map(|s| s.depth.load(Ordering::Relaxed))
            .collect();
        let (placement, action) =
            self.route_with_replicas(&prompt_tokens, tag, adapter, &depths);
        let mut shard = placement.shard;
        if let Some(home) = placement.spilled_from {
            self.counters.spills.fetch_add(1, Ordering::Relaxed);
            let fp = self.router.fingerprint(&prompt_tokens, tag);
            match action {
                // routed onto a verified replica holder: the prefix is
                // already resident there — no copy, no cold prefill
                SpillAction::ReplicaHit => {}
                // the one-to-many path: this prefix keeps spill-missing
                // here, so plant a durable replica instead of paying a
                // point-to-point copy on every future spill
                SpillAction::Replicate => {
                    self.attribute_spill(fp, tag);
                    self.replicate_to(fp, home, shard, adapter, &prompt_tokens);
                }
                // make the spill cost bandwidth instead of FLOPs: copy
                // the home shard's cached pages ahead of this Submit.
                // Deliberately NOT registered in the replica map: a
                // migration is transient residency (evictable, never
                // re-verified) — only the one-to-many path and prefetch
                // pins feed the map, so a hot prefix's repeat miss on
                // the same shard still reaches the replication trigger
                SpillAction::Migrate => {
                    self.attribute_spill(fp, tag);
                    self.try_migrate(home, shard, adapter, &prompt_tokens);
                }
            }
        }
        // journaled submissions keep the prompt for the Submit record
        let journal_tokens = journal_key.map(|_| prompt_tokens.clone());
        let (mut reply_tx, reply_rx) = mpsc::channel();
        let mut req = Request {
            id: 0, // assigned by the shard thread
            tag,
            adapter,
            tokens: prompt_tokens,
            max_new,
            arrival_us: 0,
            ignore_eos: false,
            fan,
        };
        let mut attempts = 0;
        loop {
            let handle = self.shard(shard);
            // a shard already known dead is re-routed WITHOUT touching
            // its depth: fetch_add on the poison value would wrap it
            // toward 0 and transiently advertise the dead shard as the
            // idlest in the pool to every racing placement
            if !handle.is_poisoned() {
                handle.depth.fetch_add(1, Ordering::Relaxed);
                match handle.send(Cmd::Submit(req, reply_tx)) {
                    Ok(()) => break,
                    Err(mpsc::SendError(cmd)) => {
                        // a dead shard must not look idle to the router:
                        // poison its depth so affinity spills away and
                        // least-loaded never picks it; then re-route
                        // this (still unsubmitted) request
                        self.poison_shard(shard);
                        let Cmd::Submit(r, t) = cmd else {
                            // analyze:allow(panic_path) mpsc::SendError echoes back the exact value we just sent
                            unreachable!("send echoes back the submit")
                        };
                        req = r;
                        reply_tx = t;
                    }
                }
            }
            attempts += 1;
            match self.live_least_loaded(shard) {
                Some(next) if attempts <= self.shards.len() => {
                    self.counters.rerouted.fetch_add(1, Ordering::Relaxed);
                    shard = next;
                }
                _ => anyhow::bail!(
                    "engine shard {shard} gone (no live shard to re-route to)"
                ),
            }
        }
        // the request is durably owned by `shard` now: journal the
        // Submit so a crash of that shard can replay it on a peer
        if let (Some(js), Some(key), Some(tokens)) =
            (self.journal.as_ref(), journal_key, journal_tokens)
        {
            js.journal.append_submit(&SubmitRecord {
                key: key.to_string(),
                shard,
                tag,
                adapter,
                max_new,
                fan,
                tokens,
            });
        }
        match reply_rx.recv() {
            Ok(out) => {
                if let (Some(js), Some(key)) = (self.journal.as_ref(), journal_key) {
                    // exactly-once gate: only the claimant journals the
                    // Outcome. Losing the claim means a concurrent
                    // replayer already owns this key (the shard finished
                    // the request at the instant it was declared dead) —
                    // the replayer's outcome record stands, ours doesn't.
                    if js.journal.claim(key).is_some() {
                        js.journal
                            .append_outcome(key, matches!(out, RequestOutcome::Finished(_)));
                        self.store_outcome(key, out.clone());
                    } else {
                        js.replay_races.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Ok(out)
            }
            Err(_) => {
                // the shard died holding our request: poison its depth so
                // everything routes around it
                self.poison_shard(shard);
                match (self.journal.as_ref(), journal_key) {
                    (Some(_), Some(key)) => {
                        // replay everything the dead shard still owed
                        // (this request included — claim partitions the
                        // records among concurrent waiters), then collect
                        // our key's outcome, written by whichever thread
                        // won its claim
                        self.replay_shard(shard);
                        self.await_outcome(key)
                    }
                    // no journal: the request is not replayable — the
                    // caller must see its shard fail
                    _ => anyhow::bail!("engine shard {shard} gone"),
                }
            }
        }
    }

    /// Replay every journaled Submit the dead shard still owed onto live
    /// peers. Exactly-once per key: `claim_shard` atomically removes the
    /// records from the journal's pending set, so concurrent waiters
    /// replaying the same dead shard partition the work between them and
    /// no record runs twice.
    fn replay_shard(&self, dead: usize) {
        let Some(js) = self.journal.as_ref() else { return };
        for rec in js.journal.claim_shard(dead) {
            self.replay_one(&rec);
        }
    }

    /// Re-execute one claimed Submit record on a live peer and journal
    /// its single Outcome. A replay that reaches no live shard lands a
    /// terminal `ShardLost` drop in the outcome window — the waiting
    /// client gets a definite 503, never a hang.
    fn replay_one(&self, rec: &SubmitRecord) {
        let Some(js) = self.journal.as_ref() else { return };
        let out = match self.submit_and_wait(
            rec.tokens.clone(),
            rec.adapter,
            rec.max_new,
            rec.tag,
            rec.fan,
            None,
        ) {
            Ok(out) => {
                js.replayed_requests.fetch_add(1, Ordering::Relaxed);
                out
            }
            Err(_) => {
                js.replay_failed.fetch_add(1, Ordering::Relaxed);
                RequestOutcome::Dropped(DroppedRequest {
                    id: 0,
                    tag: rec.tag,
                    adapter: rec.adapter,
                    prompt_len: rec.tokens.len(),
                    arrival_us: 0,
                    drop_us: 0,
                    reason: DropReason::ShardLost,
                })
            }
        };
        js.journal
            .append_outcome(&rec.key, matches!(out, RequestOutcome::Finished(_)));
        self.store_outcome(&rec.key, out);
    }

    /// Wait (bounded) for `key`'s outcome to land in the dedup window —
    /// written by whichever thread won the replay claim for it.
    fn await_outcome(&self, key: &str) -> anyhow::Result<RequestOutcome> {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Some(out) = self.lookup_outcome(key) {
                return Ok(out);
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "replayed request {key} produced no outcome within 30s"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Record a terminal outcome in the bounded dedup window.
    fn store_outcome(&self, key: &str, out: RequestOutcome) {
        let Some(js) = self.journal.as_ref() else { return };
        let mut guard = js.outcomes_lock.lock(&js.outcomes);
        let (map, order) = &mut *guard;
        if map.insert(key.to_string(), out).is_none() {
            order.push_back(key.to_string());
        }
        while order.len() > OUTCOME_WINDOW {
            if let Some(old) = order.pop_front() {
                map.remove(&old);
            }
        }
    }

    fn lookup_outcome(&self, key: &str) -> Option<RequestOutcome> {
        let js = self.journal.as_ref()?;
        let guard = js.outcomes_lock.lock(&js.outcomes);
        guard.0.get(key).cloned()
    }

    /// The least-loaded shard still believed alive (depth below the
    /// poison range), excluding `except`. None when every other shard is
    /// dead.
    fn live_least_loaded(&self, except: usize) -> Option<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|&(i, s)| i != except && !s.is_poisoned())
            .min_by_key(|&(_, s)| s.depth.load(Ordering::Relaxed))
            .map(|(i, _)| i)
    }

    /// The spilled-request migration pipeline, with counter accounting:
    /// a shipped payload counts as a migration, everything else (cap,
    /// probe miss, already warm, dead shard) as a skip. Returns the
    /// shipping outcome so the caller can feed the replica map.
    fn try_migrate(&self, home: usize, target: usize, adapter: u32, tokens: &[u32]) -> Ship {
        let ship = self.ship_pages(home, target, adapter, tokens);
        match ship {
            Ship::Shipped(_) => {
                self.counters.migrations.fetch_add(1, Ordering::Relaxed);
            }
            Ship::AlreadyWarm | Ship::Skipped => {
                self.counters
                    .migration_skipped
                    .fetch_add(1, Ordering::Relaxed);
            }
        }
        ship
    }

    /// The page-shipping pipeline shared by spill migration and replica
    /// planting: Probe the home shard → price migrate-vs-recompute →
    /// Probe the target (stop if it is already warm) → Export the
    /// matched pages → Import them on the target, all ahead of the
    /// request's Submit on the target's FIFO command channel. The export
    /// pins the matched pages under a lease until the payload is built,
    /// so a racing eviction cannot ship dangling pages. Best-effort by
    /// design: on any failure (home shard dead, bounded queue full,
    /// nothing cached, copy dearer than recompute) the spill simply
    /// proceeds down the recompute path it always had.
    fn ship_pages(&self, home: usize, target: usize, adapter: u32, tokens: &[u32]) -> Ship {
        if !self.migration.enabled || home == target || tokens.len() < 2 {
            return Ship::Skipped;
        }
        // bounded migration queue: page copies run on the shard threads,
        // so cap how many can be outstanding before spills fall back to
        // recompute — a spill storm must not back up the decode loops
        let slots = self.cfg.migration_max_inflight.max(1);
        if self.mig_inflight.fetch_add(1, Ordering::Relaxed) >= slots {
            self.mig_inflight.fetch_sub(1, Ordering::Relaxed);
            return Ship::Skipped;
        }
        let _slot = MigSlot(&self.mig_inflight);
        // the match window: everything but the final prompt token, which
        // is never served from cache (mirrors Engine::admit_fork)
        let window = &tokens[..tokens.len() - 1];
        let (probe_tx, probe_rx) = mpsc::channel();
        let probe = Cmd::Probe {
            adapter,
            tokens: window.to_vec(),
            reply: probe_tx,
        };
        if self.shard(home).send(probe).is_err() {
            return Ship::Skipped;
        }
        let Ok(est) = probe_rx.recv() else {
            return Ship::Skipped;
        };
        if !self.migration.should_migrate(&est) {
            return Ship::Skipped;
        }
        // target-side warmth check: an earlier migration of the same hot
        // context (or the target's own traffic) may already cover what
        // the home would send — re-shipping it would burn a full export
        // + import copy on both shard threads only to be deduplicated
        let (tgt_tx, tgt_rx) = mpsc::channel();
        let target_probe = Cmd::Probe {
            adapter,
            tokens: window.to_vec(),
            reply: tgt_tx,
        };
        if self.shard(target).send(target_probe).is_err() {
            return Ship::Skipped;
        }
        let Ok(target_est) = tgt_rx.recv() else {
            return Ship::Skipped;
        };
        if target_est.tokens_saved >= est.tokens_saved {
            return Ship::AlreadyWarm; // nothing worth moving
        }
        let (exp_tx, exp_rx) = mpsc::channel();
        let export = Cmd::Export {
            adapter,
            tokens: window.to_vec(),
            reply: exp_tx,
        };
        if self.shard(home).send(export).is_err() {
            return Ship::Skipped;
        }
        let Ok(payload) = exp_rx.recv() else {
            return Ship::Skipped;
        };
        let bytes = payload.bytes();
        // the home shard may have evicted between probe and export
        if payload.pages() == 0
            || self
                .shard(target)
                .send(Cmd::Import(Box::new(payload)))
                .is_err()
        {
            return Ship::Skipped;
        }
        Ship::Shipped(bytes)
    }

    /// Route one submission, preferring verified replica holders over
    /// cold spill targets. With replication off this is exactly
    /// `Router::place_spill` plus the default migrate action. With it
    /// on: feed the read-mostly detector (a parent-context extend bumps
    /// the prefix's epoch and drops every replica), ask the router for a
    /// holder-preferring placement, and verify-on-use — a holder that no
    /// longer probes warm (evicted or demoted since registration) is
    /// unregistered on the spot and the spill re-placed, so a stale
    /// entry never routes a fork to a shard that would cold-prefill it.
    fn route_with_replicas(
        &self,
        tokens: &[u32],
        tag: u64,
        adapter: u32,
        depths: &[usize],
    ) -> (Placement, SpillAction) {
        let Some(rep) = &self.replication else {
            return (self.router.place_spill(tokens, tag, depths), SpillAction::Migrate);
        };
        let fp = self.router.fingerprint(tokens, tag);
        let holders = {
            let mut tracker = rep.lock().unwrap_or_else(|e| e.into_inner());
            if tracker.detector.observe(fp, tokens.len()) {
                // the parent context grew: every replica of the shorter
                // prefix is stale — invalidate before routing
                let cleared = tracker.map.invalidate(fp);
                if cleared > 0 {
                    self.rep_counters
                        .replica_invalidations
                        .fetch_add(cleared as u64, Ordering::Relaxed);
                }
                tracker.misses.remove(&fp);
            }
            tracker.map.holders(fp)
        };
        let placement = self.router.place_spill_replicated(tokens, tag, depths, &holders);
        if placement.spilled_from.is_none() {
            return (placement, SpillAction::Migrate);
        }
        if holders.contains(&placement.shard) {
            // verify-on-use: registration is advisory, the probe is truth
            if self.probe_tokens_saved(placement.shard, adapter, tokens) > 0 {
                self.rep_counters.replica_hits.fetch_add(1, Ordering::Relaxed);
                return (placement, SpillAction::ReplicaHit);
            }
            {
                let mut tracker = rep.lock().unwrap_or_else(|e| e.into_inner());
                tracker.map.unregister(fp, placement.shard);
            }
            self.rep_counters
                .replica_invalidations
                .fetch_add(1, Ordering::Relaxed);
            // the stale holder may have been the only reason this shard
            // won: re-place without the replica preference
            let placement = self.router.place_spill(tokens, tag, depths);
            let action = self.tally_spill_miss(rep, fp, placement.shard);
            return (placement, action);
        }
        let action = self.tally_spill_miss(rep, fp, placement.shard);
        (placement, action)
    }

    /// Record one cold spill-miss of `fp` on `shard` and decide whether
    /// it has earned a replica: the `replicate_miss_threshold`-th miss on
    /// the same shard of a prefix the detector calls read-mostly
    /// triggers the one-to-many path (and resets that shard's tally so a
    /// failed plant retries after another full round of misses).
    fn tally_spill_miss(&self, rep: &Mutex<ReplicaTracker>, fp: u64, shard: usize) -> SpillAction {
        let mut guard = rep.lock().unwrap_or_else(|e| e.into_inner());
        let tracker = &mut *guard;
        if tracker.misses.len() >= MAX_SPILL_ATTR && !tracker.misses.contains_key(&fp) {
            tracker.misses.clear();
        }
        let n = tracker
            .misses
            .entry(fp)
            .or_default()
            .entry(shard)
            .or_insert(0);
        *n += 1;
        if *n >= self.cfg.replicate_miss_threshold && tracker.detector.is_read_mostly(fp) {
            if let Some(per_shard) = tracker.misses.get_mut(&fp) {
                per_shard.remove(&shard);
            }
            return SpillAction::Replicate;
        }
        SpillAction::Migrate
    }

    /// How many prompt tokens the shard would serve from cache for this
    /// prompt (the same match window the migration pipeline uses). 0 on
    /// a dead shard — which correctly reads as "not a replica holder".
    fn probe_tokens_saved(&self, shard: usize, adapter: u32, tokens: &[u32]) -> usize {
        if tokens.len() < 2 {
            return 0;
        }
        let (tx, rx) = mpsc::channel();
        let probe = Cmd::Probe {
            adapter,
            tokens: tokens[..tokens.len() - 1].to_vec(),
            reply: tx,
        };
        if self.shard(shard).send(probe).is_err() {
            return 0;
        }
        rx.recv_timeout(Duration::from_secs(5))
            .map_or(0, |est| est.tokens_saved)
    }

    /// Attribute one cold spill to its prefix fingerprint for the bench
    /// report (`router.spills_by_prefix`). Replica hits are deliberately
    /// not attributed: with replication on, a hot prefix's count stops
    /// growing once replicas serve its spills — exactly the signal the
    /// A/B gate checks.
    fn attribute_spill(&self, fp: u64, tag: u64) {
        let mut attr = self.spill_attr.lock().unwrap_or_else(|e| e.into_inner());
        if attr.len() >= MAX_SPILL_ATTR && !attr.contains_key(&fp) {
            attr.clear();
        }
        attr.entry(fp).or_insert((tag, 0)).1 += 1;
    }

    /// Register `shards` as verified holders of `fp` in the replica map
    /// (no-op with replication off; the map itself drops dead shards).
    fn register_replica(&self, fp: u64, shards: &[usize]) {
        let Some(rep) = &self.replication else { return };
        let mut tracker = rep.lock().unwrap_or_else(|e| e.into_inner());
        for &s in shards {
            tracker.map.register(fp, s);
        }
    }

    /// Plant a durable replica of a hot read-mostly prefix on `target`:
    /// first ask the target to promote any demoted copy of the prefix
    /// back to its device tier (`Cmd::ReplicaWarm` — free if the pages
    /// merely aged out to host), then run the shipping pipeline for
    /// whatever is still missing. Every outcome that proves residency
    /// registers the holder and counts as a replication event; a plant
    /// is zero-copy when the target was already warm (an earlier
    /// migration or the promotion above), so `replica_bytes` counts
    /// only actual copy traffic.
    fn replicate_to(&self, fp: u64, home: usize, target: usize, adapter: u32, tokens: &[u32]) {
        let (tx, rx) = mpsc::channel();
        let warm = Cmd::ReplicaWarm {
            adapter,
            tokens: tokens.to_vec(),
            reply: tx,
        };
        let promoted = if self.shard(target).send(warm).is_ok() {
            rx.recv_timeout(Duration::from_secs(5)).unwrap_or(0)
        } else {
            0
        };
        let planted = match self.ship_pages(home, target, adapter, tokens) {
            Ship::Shipped(bytes) => {
                self.rep_counters
                    .replica_bytes
                    .fetch_add(bytes as u64, Ordering::Relaxed);
                self.register_replica(fp, &[home, target]);
                true
            }
            // the target already covered the home's pages (an earlier
            // migration, or the promotion above): zero-copy plant
            Ship::AlreadyWarm => {
                self.register_replica(fp, &[home, target]);
                true
            }
            Ship::Skipped if promoted > 0 => {
                self.register_replica(fp, &[target]);
                true
            }
            Ship::Skipped => false,
        };
        if planted {
            self.rep_counters.replications.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Untagged [`Server::generate_outcome_tagged`]: route and wait for
    /// the terminal outcome, drops surfaced as `RequestOutcome::Dropped`
    /// rather than an error.
    pub fn generate_outcome(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
    ) -> anyhow::Result<RequestOutcome> {
        self.generate_outcome_tagged(prompt_tokens, adapter, max_new, 0)
    }

    /// Generate under a workflow tag and insist on completion: an
    /// engine-initiated drop (OOM eviction) comes back as an error
    /// naming the `DropReason`.
    pub fn generate_tagged(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
        tag: u64,
    ) -> anyhow::Result<FinishedRequest> {
        match self.generate_outcome_tagged(prompt_tokens, adapter, max_new, tag)? {
            RequestOutcome::Finished(fin) => Ok(fin),
            RequestOutcome::Dropped(d) => Err(anyhow::anyhow!(
                "request dropped by engine ({}): prompt {} tokens evicted under memory pressure",
                d.reason.as_str(),
                d.prompt_len
            )),
        }
    }

    /// The simplest entry point: untagged [`Server::generate_tagged`]
    /// (tag 0 — no workflow affinity, no gang admission).
    pub fn generate(
        &self,
        prompt_tokens: Vec<u32>,
        adapter: u32,
        max_new: usize,
    ) -> anyhow::Result<FinishedRequest> {
        self.generate_tagged(prompt_tokens, adapter, max_new, 0)
    }

    /// One stats snapshot per shard, in shard order. All `Cmd::Stats` go
    /// out before the first receive so busy shards snapshot concurrently
    /// (latency is the max per-shard tick wait, not the sum). A dead
    /// shard yields `{"dead": true}` instead of failing the whole
    /// snapshot — observability must survive a drained/crashed shard.
    pub fn shard_stats(&self) -> anyhow::Result<Vec<Json>> {
        let mut pending = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let (tx, rx) = mpsc::channel();
            pending.push(shard.send(Cmd::Stats(tx)).ok().map(|()| rx));
        }
        Ok(pending
            .into_iter()
            .map(|rx| match rx.and_then(|rx| rx.recv().ok()) {
                Some(stats) => stats,
                None => Json::obj(vec![("dead", Json::Bool(true))]),
            })
            .collect())
    }

    /// Pool-level aggregate (counters summed across shards, ratio metrics
    /// re-derived) — what `GET /stats` serves.
    pub fn stats(&self) -> anyhow::Result<Json> {
        Ok(metrics::aggregate_stats(&self.shard_stats()?))
    }

    /// Routing + migration outcome counters (the `router` object of
    /// `GET /metrics`).
    pub fn router_stats(&self) -> Json {
        let c = &self.counters;
        // cold-spill attribution, keyed by prefix fingerprint (hex) so
        // bench reports can split hot-context spills from the long tail
        let by_prefix: std::collections::BTreeMap<String, Json> = self
            .spill_attr
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(fp, &(tag, spills))| {
                (
                    format!("{fp:016x}"),
                    Json::obj(vec![
                        ("tag", Json::num(tag as f64)),
                        ("spills", Json::num(spills as f64)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("policy", Json::str(self.cfg.route_policy.name())),
            ("migrate", Json::Bool(self.migration.enabled)),
            ("spills", Json::num(c.spills.load(Ordering::Relaxed) as f64)),
            (
                "migrations",
                Json::num(c.migrations.load(Ordering::Relaxed) as f64),
            ),
            (
                "migration_skipped",
                Json::num(c.migration_skipped.load(Ordering::Relaxed) as f64),
            ),
            (
                "rerouted",
                Json::num(c.rerouted.load(Ordering::Relaxed) as f64),
            ),
            ("spills_by_prefix", Json::Obj(by_prefix)),
        ])
    }

    /// Hot-context replication knobs and outcome counters (the
    /// `replication` object of `GET /metrics`).
    pub fn replication_stats(&self) -> Json {
        let tracked = self.replication.as_ref().map_or(0, |rep| {
            rep.lock().unwrap_or_else(|e| e.into_inner()).map.len()
        });
        let c = &self.rep_counters;
        Json::obj(vec![
            ("enabled", Json::Bool(self.replication.is_some())),
            (
                "miss_threshold",
                Json::num(self.cfg.replicate_miss_threshold as f64),
            ),
            ("window", Json::num(self.cfg.replicate_window as f64)),
            ("min_forks", Json::num(self.cfg.replicate_min_forks as f64)),
            ("tracked_prefixes", Json::num(tracked as f64)),
            (
                "replications",
                Json::num(c.replications.load(Ordering::Relaxed) as f64),
            ),
            (
                "replica_hits",
                Json::num(c.replica_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "replica_invalidations",
                Json::num(c.replica_invalidations.load(Ordering::Relaxed) as f64),
            ),
            (
                "replica_bytes",
                Json::num(c.replica_bytes.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    // -----------------------------------------------------------------
    // elastic shard budgets (the rebalance supervisor)
    // -----------------------------------------------------------------

    /// The supervisor loop: every `cfg.rebalance_interval_ms` poll each
    /// shard's budget pressure and apply the planner's moves, until
    /// `shutdown` raises the stop flag. Runs on its own named thread
    /// (`forkkv-rebalance`), spawned by `start_sharded`.
    fn rebalance_supervisor(&self) {
        let interval = Duration::from_millis(self.cfg.rebalance_interval_ms.max(1));
        // sleep in short steps so shutdown is never blocked behind a
        // long interval
        let step = interval.min(Duration::from_millis(10));
        let mut since = Duration::ZERO;
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(step);
            since += step;
            if since >= interval {
                since = Duration::ZERO;
                self.rebalance_tick();
            }
        }
    }

    /// One rebalance step: snapshot every shard's `Cmd::Pressure`, run
    /// the planner, and push `Cmd::Budget` to each shard whose budget
    /// moved. Dead shards observe as `None` (their budget is frozen).
    /// Public so tests can drive the rebalancer deterministically;
    /// returns the bytes of budget moved this tick.
    pub fn rebalance_tick(&self) -> usize {
        let Some(reb) = &self.rebalancer else { return 0 };
        let mut obs: Vec<Option<BudgetPressure>> = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            if shard.is_poisoned() {
                obs.push(None);
                continue;
            }
            let (tx, rx) = mpsc::channel();
            if shard.send(Cmd::Pressure(tx)).is_err() {
                obs.push(None);
                continue;
            }
            // generous timeout: a shard that can't answer within this is
            // treated as dead *for this tick* only (its budget freezes)
            obs.push(rx.recv_timeout(Duration::from_secs(5)).ok());
        }
        // replica overlay: engines report hot_replicas = 0 (they have no
        // pool-wide view), so fill in each shard's holder count from the
        // replica map before the planner weighs donors and borrowers
        if let Some(rep) = &self.replication {
            let counts = rep
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .map
                .holder_counts();
            for (o, &c) in obs.iter_mut().zip(&counts) {
                if let Some(p) = o {
                    p.hot_replicas = c;
                }
            }
        }
        let (moves, moved) = reb.lock().unwrap_or_else(|e| e.into_inner()).tick(&obs);
        for &(i, bytes) in &moves {
            if self.shard(i).send(Cmd::Budget(bytes)).is_err() {
                // a closed channel means the shard died between the
                // pressure poll and the move. Poison its depth so the
                // router and every later tick see it dead — its budget
                // (including this undeliverable move) freezes in the
                // planner, exactly like any other dead shard's. A dead
                // engine allocates nothing, so live shards' enforced
                // budgets never exceed the planner's conserved total.
                self.poison_shard(i);
            }
        }
        if moved > 0 {
            self.reb_counters
                .budget_rebalances
                .fetch_add(1, Ordering::Relaxed);
            self.reb_counters
                .bytes_lent
                .fetch_add(moved as u64, Ordering::Relaxed);
        }
        moved
    }

    /// Elastic-budget outcome counters and knobs (the `rebalancer`
    /// object of `GET /metrics`).
    pub fn rebalancer_stats(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.rebalancer.is_some())),
            (
                "interval_ms",
                Json::num(self.cfg.rebalance_interval_ms as f64),
            ),
            ("lend_max_frac", Json::num(self.cfg.lend_max_frac)),
            (
                "budget_rebalances",
                Json::num(self.reb_counters.budget_rebalances.load(Ordering::Relaxed) as f64),
            ),
            (
                "bytes_lent",
                Json::num(self.reb_counters.bytes_lent.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    // -----------------------------------------------------------------
    // host-memory tier compaction (the tier supervisor)
    // -----------------------------------------------------------------

    /// The tier compaction loop: every `cfg.tier_compact_ms` ask each
    /// shard to compact its host-tier segments, until `shutdown` raises
    /// the stop flag. Runs on its own named thread (`forkkv-tier`),
    /// spawned by `start_sharded` when the tier is armed.
    fn tier_compact_supervisor(&self) {
        let interval = Duration::from_millis(self.cfg.tier_compact_ms.max(1));
        // sleep in short steps so shutdown is never blocked behind a
        // long interval
        let step = interval.min(Duration::from_millis(10));
        let mut since = Duration::ZERO;
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(step);
            since += step;
            if since >= interval {
                since = Duration::ZERO;
                self.tier_compact_tick();
            }
        }
    }

    /// One compaction step: fan `Cmd::TierCompact` out to every live
    /// shard, then sum the bytes each reclaimed (all sends go out before
    /// the first receive, so shards compact concurrently). Dead shards
    /// are skipped. Public so tests can drive compaction
    /// deterministically; returns the total bytes reclaimed this tick.
    pub fn tier_compact_tick(&self) -> usize {
        let mut pending = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            if shard.is_poisoned() {
                pending.push(None);
                continue;
            }
            let (tx, rx) = mpsc::channel();
            pending.push(shard.send(Cmd::TierCompact(tx)).ok().map(|()| rx));
        }
        let reclaimed: usize = pending
            .into_iter()
            .flatten()
            .filter_map(|rx| rx.recv_timeout(Duration::from_secs(5)).ok())
            .sum();
        if reclaimed > 0 {
            self.tier_counters
                .tier_compactions
                .fetch_add(1, Ordering::Relaxed);
            self.tier_counters
                .tier_bytes_reclaimed
                .fetch_add(reclaimed as u64, Ordering::Relaxed);
        }
        reclaimed
    }

    /// Host-tier knobs and pool-level compaction counters (the `tier`
    /// object of `GET /metrics`). Per-shard tier occupancy
    /// (`tier_bytes` / `tier_budget_bytes`) and the demote/promote
    /// counters live in each shard's snapshot and the aggregate.
    pub fn tier_stats(&self) -> Json {
        Json::obj(vec![
            ("enabled", Json::Bool(self.cfg.tier)),
            ("compact_ms", Json::num(self.cfg.tier_compact_ms as f64)),
            (
                "tier_compactions",
                Json::num(self.tier_counters.tier_compactions.load(Ordering::Relaxed) as f64),
            ),
            (
                "tier_bytes_reclaimed",
                Json::num(
                    self.tier_counters.tier_bytes_reclaimed.load(Ordering::Relaxed) as f64,
                ),
            ),
        ])
    }

    // -----------------------------------------------------------------
    // durability: shard crash, journal replay, warm restart
    // -----------------------------------------------------------------

    /// Fault injection + maintenance: crash one shard as if its process
    /// died mid-flight. The engine's host-memory tier is salvaged (host
    /// RAM survives an engine crash by construction; device pool state
    /// does not) and parked for a later [`Server::restart_shard`];
    /// everything else — device pages, radix indices, in-flight requests
    /// — is lost. In-flight waiters observe their reply channels close
    /// and run the journal replay path. Returns whether the shard was
    /// alive to kill.
    pub fn kill_shard(&self, shard: usize) -> bool {
        let handle = self.shard(shard);
        let (tx, rx) = mpsc::channel();
        let alive = handle.send(Cmd::Crash { salvage: tx }).is_ok();
        if alive {
            if let Ok(Some(tier)) = rx.recv_timeout(Duration::from_secs(5)) {
                let mut guard = self.salvaged_lock.lock(&self.salvaged);
                guard.insert(shard, tier);
            }
        }
        self.poison_shard(shard);
        alive
    }

    /// Warm-restart a dead shard around a fresh engine: re-adopt its
    /// salvaged host tier, replay its latest checkpoint (radix paths
    /// re-linked against the tier-resident pages, counted as
    /// `restored_pages`), install a fresh command channel under the
    /// sender lock, and un-poison its depth so the router sends traffic
    /// again. Returns the new shard thread's join handle.
    pub fn restart_shard(
        &self,
        shard: usize,
        mut engine: Engine,
    ) -> anyhow::Result<std::thread::JoinHandle<()>> {
        anyhow::ensure!(shard < self.shards.len(), "no such shard {shard}");
        anyhow::ensure!(
            self.shard(shard).is_poisoned(),
            "shard {shard} is still live; kill or drain it first"
        );
        // host tier first: the checkpoint restore pulls pages out of it
        {
            let mut guard = self.salvaged_lock.lock(&self.salvaged);
            if let Some(tier) = guard.remove(&shard) {
                engine.adopt_tier(tier);
            }
        }
        if let Some(js) = self.journal.as_ref() {
            let path = js.journal.dir().join(format!("ckpt-shard-{shard}.json"));
            if let Ok(text) = std::fs::read_to_string(&path) {
                if let Ok(ckpt) = json::parse(&text) {
                    engine.restore_checkpoint(&ckpt);
                }
            }
        }
        let (tx, rx) = mpsc::channel::<Cmd>();
        let handle = self.shard(shard);
        let depth = handle.depth.clone();
        let idle_wait = Duration::from_millis(self.cfg.idle_wait_ms.max(1));
        let thread = std::thread::Builder::new()
            .name(format!("forkkv-shard-{shard}"))
            .spawn(move || run_shard(engine, rx, depth, idle_wait))?;
        *handle.tx_lock.write(&handle.tx) = tx;
        // un-poison only after the fresh sender is installed: a racing
        // submit must never see depth 0 with the dead channel in place
        handle.depth.store(0, Ordering::Relaxed);
        // the restarted shard is routable again but holds no replicas:
        // its checkpoint restore is best-effort and verify-on-use would
        // catch stragglers anyway — start it with a clean slate
        if let Some(rep) = &self.replication {
            rep.lock()
                .unwrap_or_else(|e| e.into_inner())
                .map
                .shard_restarted(shard);
        }
        Ok(thread)
    }

    /// One checkpoint step: fan `Cmd::Checkpoint` to every live shard
    /// (all sends go out before the first receive), then atomically
    /// replace each shard's `ckpt-shard-{i}.json` in the journal
    /// directory (write a temp file, then rename — a crash mid-write
    /// leaves the previous checkpoint intact). Public so tests can drive
    /// checkpointing deterministically; returns the shards checkpointed.
    pub fn checkpoint_tick(&self) -> usize {
        let Some(js) = self.journal.as_ref() else { return 0 };
        let mut pending = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            if shard.is_poisoned() {
                pending.push(None);
                continue;
            }
            let (tx, rx) = mpsc::channel();
            pending.push(shard.send(Cmd::Checkpoint(tx)).ok().map(|()| rx));
        }
        let dir = js.journal.dir().to_path_buf();
        let mut written = 0usize;
        for (i, rx) in pending.into_iter().enumerate() {
            let Some(rx) = rx else { continue };
            let Ok(ckpt) = rx.recv_timeout(Duration::from_secs(5)) else {
                continue;
            };
            let tmp = dir.join(format!("ckpt-shard-{i}.tmp"));
            let dst = dir.join(format!("ckpt-shard-{i}.json"));
            if std::fs::write(&tmp, ckpt.to_string()).is_ok()
                && std::fs::rename(&tmp, &dst).is_ok()
            {
                written += 1;
            }
        }
        if written > 0 {
            js.checkpoints_written
                .fetch_add(written as u64, Ordering::Relaxed);
        }
        written
    }

    /// The journal group-commit pacer: flush + fsync buffered records on
    /// the `journal_sync_ms` cadence even when no append crosses the
    /// thresholds. Runs on its own named thread (`forkkv-journal`),
    /// spawned by `start_sharded` when the journal is armed.
    fn journal_supervisor(&self) {
        let step = Duration::from_millis(self.cfg.journal_sync_ms.clamp(1, 10));
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(step);
            if let Some(js) = self.journal.as_ref() {
                js.journal.maybe_sync();
            }
        }
    }

    /// The warm-restart checkpoint loop: every `cfg.checkpoint_ms`
    /// snapshot each live shard's radix/tier metadata, until `shutdown`
    /// raises the stop flag (which also takes one final checkpoint).
    /// Runs on its own named thread (`forkkv-checkpoint`), spawned by
    /// `start_sharded` when the journal is armed.
    fn checkpoint_supervisor(&self) {
        let interval = Duration::from_millis(self.cfg.checkpoint_ms.max(1));
        // sleep in short steps so shutdown is never blocked behind a
        // long interval
        let step = interval.min(Duration::from_millis(10));
        let mut since = Duration::ZERO;
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(step);
            since += step;
            if since >= interval {
                since = Duration::ZERO;
                self.checkpoint_tick();
            }
        }
    }

    /// Durability knobs plus journal/replay/restart counters (the
    /// `journal` object of `GET /metrics`).
    pub fn journal_stats(&self) -> Json {
        let Some(js) = self.journal.as_ref() else {
            return Json::obj(vec![("enabled", Json::Bool(false))]);
        };
        let s = js.journal.stats();
        Json::obj(vec![
            ("enabled", Json::Bool(true)),
            ("submits", Json::num(s.submits as f64)),
            ("outcomes", Json::num(s.outcomes as f64)),
            ("pending", Json::num(js.journal.pending_len() as f64)),
            ("group_commits", Json::num(s.group_commits as f64)),
            ("synced_bytes", Json::num(s.synced_bytes as f64)),
            ("segments_created", Json::num(s.segments_created as f64)),
            ("segments_gced", Json::num(s.segments_gced as f64)),
            ("truncated_bytes", Json::num(s.truncated_bytes as f64)),
            ("corrupt_lines", Json::num(s.corrupt_lines as f64)),
            (
                "duplicate_outcomes",
                Json::num(s.duplicate_outcomes as f64),
            ),
            (
                "replayed_requests",
                Json::num(js.replayed_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "replay_failed",
                Json::num(js.replay_failed.load(Ordering::Relaxed) as f64),
            ),
            (
                "deduped_retries",
                Json::num(js.deduped_retries.load(Ordering::Relaxed) as f64),
            ),
            (
                "replay_races",
                Json::num(js.replay_races.load(Ordering::Relaxed) as f64),
            ),
            (
                "recovered_orphans",
                Json::num(js.recovered_orphans.load(Ordering::Relaxed) as f64),
            ),
            (
                "checkpoints_written",
                Json::num(js.checkpoints_written.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// Sampled contention counters for the server's hot locks (the
    /// `locks` object of `GET /metrics`): per lock, total acquisitions,
    /// how many contended (failed the try-lock fast path), and the
    /// microseconds spent waiting on those.
    pub fn lock_stats(&self) -> Json {
        let mut stats: Vec<&LockStat> = vec![&*self.shard_tx_stat, &self.salvaged_lock];
        if let Some(js) = self.journal.as_ref() {
            stats.push(js.journal.lock_stat());
            stats.push(&js.outcomes_lock);
        }
        locks_json(&stats)
    }

    // -----------------------------------------------------------------
    // cross-step workflow prefetch (the DAG registry + horizon)
    // -----------------------------------------------------------------

    /// Register (or idempotently re-touch) one workflow's steps-to-execute
    /// DAG under its nonzero tag. Every agent of a step may attach the
    /// same `steps` payload — only the first registration counts.
    fn register_dag(&self, tag: u64, steps: &[Json], default_adapter: u32) -> anyhow::Result<()> {
        let dag = self.parse_dag(tag, steps, default_adapter)?;
        let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(live) = dags.get_mut(&tag) {
            live.touched = Instant::now();
        } else {
            dags.insert(tag, dag);
            self.pf_counters.dags_registered.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Validate and build a DAG from its `"steps"` JSON: unique ids,
    /// known `after` / `prefix_from` references, bounded size, acyclic.
    // analyze:allow(panic_path, fn) Kahn indices come from enumerate() over the same nodes vec that sized indeg
    fn parse_dag(&self, tag: u64, steps: &[Json], default_adapter: u32) -> anyhow::Result<Dag> {
        anyhow::ensure!(tag != 0, "dag registration needs a nonzero workflow tag");
        anyhow::ensure!(!steps.is_empty(), "empty steps array");
        anyhow::ensure!(
            steps.len() <= MAX_DAG_NODES,
            "dag exceeds {MAX_DAG_NODES} steps"
        );
        let mut by_id: HashMap<String, usize> = HashMap::new();
        for (i, s) in steps.iter().enumerate() {
            let id = s.req_str("id")?.to_string();
            anyhow::ensure!(
                by_id.insert(id.clone(), i).is_none(),
                "duplicate step id {id:?}"
            );
        }
        let mut nodes = Vec::with_capacity(steps.len());
        for s in steps {
            let id = s.req_str("id")?.to_string();
            let mut after = Vec::new();
            if let Some(arr) = s.get("after").and_then(Json::as_arr) {
                for a in arr {
                    let name = a
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("\"after\" entries must be step ids"))?;
                    let &idx = by_id.get(name).ok_or_else(|| {
                        anyhow::anyhow!("step {id:?} is after unknown step {name:?}")
                    })?;
                    if !after.contains(&idx) {
                        after.push(idx);
                    }
                }
            }
            let adapter = s
                .get("adapter")
                .and_then(Json::as_usize)
                .map(|a| a as u32)
                .unwrap_or(default_adapter);
            let step_tag = s
                .get("tag")
                .and_then(Json::as_usize)
                .map(|t| t as u64)
                .unwrap_or(tag);
            let prefix = if let Some(text) = s.get("prefix").and_then(Json::as_str) {
                PrefixSpec::Literal(self.tokenizer.encode(text))
            } else if let Some(from) = s.get("prefix_from").and_then(Json::as_str) {
                let &idx = by_id.get(from).ok_or_else(|| {
                    anyhow::anyhow!("step {id:?} prefix_from unknown step {from:?}")
                })?;
                PrefixSpec::FromStep(idx)
            } else {
                PrefixSpec::None
            };
            nodes.push(DagNode {
                id,
                after,
                adapter,
                tag: step_tag,
                prefix,
                state: NodeState::Pending,
                prompt: None,
                abandoned: false,
                lease: None,
            });
        }
        // Kahn's walk over the (deduplicated) edges: every node must
        // drain, or the declared dependencies contain a cycle and the
        // distance recursion would never terminate
        let mut indeg: Vec<usize> = nodes.iter().map(|n| n.after.len()).collect();
        let mut ready: Vec<usize> = indeg
            .iter()
            .enumerate()
            .filter(|&(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut drained = 0usize;
        while let Some(i) = ready.pop() {
            drained += 1;
            for (j, n) in nodes.iter().enumerate() {
                if n.after.contains(&i) {
                    indeg[j] -= 1;
                    if indeg[j] == 0 {
                        ready.push(j);
                    }
                }
            }
        }
        anyhow::ensure!(drained == nodes.len(), "steps dag has a cycle");
        Ok(Dag {
            nodes,
            touched: Instant::now(),
        })
    }

    /// A request declaring `"step"` arrived: mark the node running
    /// (which moves the prefetch frontier), record its actual prompt
    /// (resolving successors' `prefix_from`), take its lease (the caller
    /// releases it once the outcome lands, so the warmed pages stay
    /// pinned through admission), and re-evaluate the horizon.
    // analyze:allow(panic_path, fn) idx comes from position() over the same nodes vec, under the registry lock
    fn step_arrival(&self, tag: u64, step: &str, prompt: &[u32]) -> Option<IssuedLease> {
        let lease = {
            let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
            let dag = dags.get_mut(&tag)?;
            dag.touched = Instant::now();
            let idx = dag.nodes.iter().position(|n| n.id == step)?;
            let node = &mut dag.nodes[idx];
            node.state = NodeState::Running;
            node.prompt = Some(prompt.to_vec());
            node.lease.take()
        };
        self.prefetch_eval();
        lease
    }

    /// A step's request reached a terminal outcome. Success marks the
    /// node done; failure returns it to pending (the client may retry;
    /// abandonment GC covers workflows that die here). A fully-done DAG
    /// leaves the registry.
    // analyze:allow(panic_path, fn) idx comes from position() over the same nodes vec, under the registry lock
    fn step_done(&self, tag: u64, step: &str, ok: bool) {
        let (all_done, strays) = {
            let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
            let Some(dag) = dags.get_mut(&tag) else { return };
            dag.touched = Instant::now();
            let Some(idx) = dag.nodes.iter().position(|n| n.id == step) else {
                return;
            };
            if ok {
                dag.nodes[idx].state = NodeState::Done;
            } else {
                dag.nodes[idx].state = NodeState::Pending;
                dag.nodes[idx].prompt = None;
            }
            let all_done = dag.nodes.iter().all(|n| n.state == NodeState::Done);
            let mut strays = Vec::new();
            if all_done {
                // arrival already took every lease of a done node; the
                // sweep is belt-and-braces (engine release is
                // exactly-once, so a double release is a no-op)
                if let Some(dag) = dags.remove(&tag) {
                    strays.extend(dag.nodes.into_iter().filter_map(|n| n.lease));
                }
            }
            (all_done, strays)
        };
        for l in &strays {
            self.release_lease(l, false);
        }
        if !all_done {
            // a completed prefill published this step's context: prefixes
            // that were not yet resident at arrival time may now be
            self.prefetch_eval();
        }
    }

    /// Release one issued lease on its shard and account the outcome.
    fn release_lease(&self, l: &IssuedLease, hit: bool) {
        let _ = self.shard(l.shard).send(Cmd::PrefetchRelease { lease: l.id, hit });
        let ctr = if hit {
            &self.pf_counters.leases_hit
        } else {
            &self.pf_counters.leases_abandoned
        };
        ctr.fetch_add(1, Ordering::Relaxed);
    }

    /// Walk every registered DAG and warm each pending step within the
    /// horizon whose prefix is resolvable: plan under the registry lock,
    /// then migrate + pin outside it (`PrefetchPlan`). A plan whose
    /// prefix turns out not resident yet leaves no lease anywhere, so a
    /// later pass (arrival, completion, supervisor tick) retries it.
    // analyze:allow(panic_path, fn) i ranges over nodes.len() (dist is distances() over the same vec); after/FromStep indices validated at registration
    fn prefetch_eval(&self) {
        if !self.cfg.prefetch {
            return;
        }
        let plans = {
            let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
            let mut plans = Vec::new();
            for (&tag, dag) in dags.iter_mut() {
                let dist = dag.distances();
                for i in 0..dag.nodes.len() {
                    let n = &dag.nodes[i];
                    if n.state != NodeState::Pending
                        || n.abandoned
                        || n.lease.is_some()
                        || dist[i] > self.cfg.prefetch_horizon
                    {
                        continue;
                    }
                    let Some(tokens) = dag.resolve_prefix(i) else { continue };
                    if tokens.is_empty() {
                        continue;
                    }
                    let n = &dag.nodes[i];
                    // where will the step land? (None under round-robin:
                    // placement ignores content, nothing to warm)
                    let Some(target) = self.router.prefetch_home(&tokens, n.tag) else {
                        continue;
                    };
                    // where does the prefix live today? Its provenance —
                    // the first predecessor's home for this same window
                    // (predecessor prompts start with the shared prefix,
                    // and the affinity fingerprint only reads the first
                    // page window, so this is the predecessor's shard)
                    let source = n
                        .after
                        .first()
                        .and_then(|&p| self.router.prefetch_home(&tokens, dag.nodes[p].tag));
                    let lease = self.lease_seq.fetch_add(1, Ordering::Relaxed);
                    let adapter = dag.nodes[i].adapter;
                    let route_tag = dag.nodes[i].tag;
                    dag.nodes[i].lease = Some(IssuedLease {
                        id: lease,
                        shard: target,
                        issued: Instant::now(),
                    });
                    plans.push(PrefetchPlan {
                        tag,
                        node: i,
                        lease,
                        adapter,
                        tokens,
                        target,
                        source,
                        route_tag,
                    });
                }
            }
            plans
        };
        for plan in plans {
            self.execute_prefetch(plan);
        }
    }

    /// Carry out one planned prefetch: pre-migrate the prefix from its
    /// provenance shard when the successor homes elsewhere (the PR 3
    /// pipeline, priced by the same cost model and bounded by the same
    /// migration queue), then pin + tier-promote it on the target under
    /// the lease. Zero coverage clears the optimistic lease record so
    /// the step can be retried.
    fn execute_prefetch(&self, plan: PrefetchPlan) {
        if let Some(src) = plan.source {
            if src != plan.target {
                // `try_migrate`'s match window drops the final token
                // (mirroring admission, where the last *prompt* token is
                // never served from cache) — but a prefetch prefix is
                // fully cacheable, because the successor's prompt extends
                // past it. Pad one token so the window covers it whole.
                let mut window = plan.tokens.clone();
                window.push(0);
                self.try_migrate(src, plan.target, plan.adapter, &window);
            }
        }
        // the fingerprint the step will arrive under — computed before
        // the Prefetch send moves the tokens
        let fp = self.router.fingerprint(&plan.tokens, plan.route_tag);
        let (tx, rx) = mpsc::channel();
        let covered = self
            .shard(plan.target)
            .send(Cmd::Prefetch {
                lease: plan.lease,
                adapter: plan.adapter,
                tokens: plan.tokens,
                reply: tx,
            })
            .ok()
            .and_then(|()| rx.recv_timeout(Duration::from_secs(5)).ok())
            .unwrap_or(0);
        if covered > 0 {
            self.pf_counters.leases_issued.fetch_add(1, Ordering::Relaxed);
            // a pinned prefetch is verified residency: feed the replica
            // map so spills of this prefix can route onto the pin
            self.register_replica(fp, &[plan.target]);
            return;
        }
        // nothing resident yet (the predecessors may still be
        // prefilling): the engine left no lease behind, so clear the
        // registry record and let a later evaluation pass retry
        let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
        // get_mut, not indexing: the registry was unlocked during the
        // migration round trips, so the DAG may have been GC'd and
        // re-registered with fewer nodes in the meantime — a stale
        // `plan.node` must be a no-op, not a panic (the lease-id check
        // already guards the matching-index-different-lease case)
        if let Some(node) = dags
            .get_mut(&plan.tag)
            .and_then(|dag| dag.nodes.get_mut(plan.node))
        {
            if node.lease.as_ref().is_some_and(|l| l.id == plan.lease) {
                node.lease = None;
            }
        }
    }

    /// The prefetch maintenance loop: every `cfg.prefetch_tick_ms`,
    /// retry unwarmed steps and abandon leases whose step never came,
    /// until `shutdown` raises the stop flag. Runs on its own named
    /// thread (`forkkv-prefetch`), spawned by `start_sharded` when
    /// prefetch is armed.
    fn prefetch_supervisor(&self) {
        let interval = Duration::from_millis(self.cfg.prefetch_tick_ms.max(1));
        // sleep in short steps so shutdown is never blocked behind a
        // long interval
        let step = interval.min(Duration::from_millis(10));
        let mut since = Duration::ZERO;
        while !self.stop.load(Ordering::Relaxed) {
            std::thread::sleep(step);
            since += step;
            if since >= interval {
                since = Duration::ZERO;
                self.prefetch_tick();
            }
        }
    }

    /// One prefetch maintenance step: abandon leases older than
    /// `cfg.prefetch_abandon_ms` whose step is still pending (their
    /// pages count as `prefetch_wasted`), collect DAGs untouched for
    /// `DAG_GC_FACTOR` windows (releasing anything they still hold),
    /// then re-run the horizon so steps whose prefixes have since
    /// become resident get warmed. Public so tests can drive the
    /// supervisor deterministically; returns the leases abandoned.
    pub fn prefetch_tick(&self) -> usize {
        let abandon = Duration::from_millis(self.cfg.prefetch_abandon_ms.max(1));
        let released = {
            let mut dags = self.dags.lock().unwrap_or_else(|e| e.into_inner());
            let mut released = Vec::new();
            for dag in dags.values_mut() {
                for node in &mut dag.nodes {
                    if node.state != NodeState::Pending {
                        continue;
                    }
                    if node
                        .lease
                        .as_ref()
                        .is_some_and(|l| l.issued.elapsed() >= abandon)
                    {
                        released.extend(node.lease.take());
                        node.abandoned = true;
                    }
                }
            }
            let dead: Vec<u64> = dags
                .iter()
                .filter(|(_, d)| d.touched.elapsed() >= abandon * DAG_GC_FACTOR)
                .map(|(&t, _)| t)
                .collect();
            for t in dead {
                if let Some(dag) = dags.remove(&t) {
                    released.extend(dag.nodes.into_iter().filter_map(|n| n.lease));
                }
            }
            released
        };
        for l in &released {
            self.release_lease(l, false);
        }
        self.prefetch_eval();
        released.len()
    }

    /// Prefetch knobs and pool-level lease/DAG counters (the `prefetch`
    /// object of `GET /metrics`). Page-granular counters
    /// (`prefetched_pages` / `prefetch_hits` / `prefetch_wasted`) live
    /// in each shard's snapshot and the aggregate.
    pub fn prefetch_stats(&self) -> Json {
        let live_dags = self.dags.lock().unwrap_or_else(|e| e.into_inner()).len();
        Json::obj(vec![
            ("enabled", Json::Bool(self.cfg.prefetch)),
            ("horizon", Json::num(self.cfg.prefetch_horizon as f64)),
            (
                "abandon_ms",
                Json::num(self.cfg.prefetch_abandon_ms as f64),
            ),
            ("live_dags", Json::num(live_dags as f64)),
            (
                "dags_registered",
                Json::num(self.pf_counters.dags_registered.load(Ordering::Relaxed) as f64),
            ),
            (
                "leases_issued",
                Json::num(self.pf_counters.leases_issued.load(Ordering::Relaxed) as f64),
            ),
            (
                "leases_hit",
                Json::num(self.pf_counters.leases_hit.load(Ordering::Relaxed) as f64),
            ),
            (
                "leases_abandoned",
                Json::num(self.pf_counters.leases_abandoned.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// Full observability payload: aggregate + per-shard snapshots + the
    /// active route policy with its spill/migration/reroute counters +
    /// the elastic-budget rebalancer counters + the host-tier compaction
    /// counters + the cross-step prefetch counters + the hot-context
    /// replication counters — what `GET /metrics` serves. Each shard
    /// snapshot carries its live `budget_bytes`; across live shards they
    /// always sum to the configured pool budget.
    pub fn metrics_json(&self) -> anyhow::Result<Json> {
        let per_shard = self.shard_stats()?;
        Ok(Json::obj(vec![
            ("aggregate", metrics::aggregate_stats(&per_shard)),
            ("route", Json::str(self.cfg.route_policy.name())),
            ("router", self.router_stats()),
            ("rebalancer", self.rebalancer_stats()),
            ("tier", self.tier_stats()),
            ("prefetch", self.prefetch_stats()),
            ("replication", self.replication_stats()),
            ("journal", self.journal_stats()),
            ("locks", self.lock_stats()),
            ("per_shard", Json::Arr(per_shard)),
        ]))
    }

    /// Bind `addr` and serve until `max_requests` connections were accepted
    /// (None = run forever). Blocking; connections are handled by the
    /// bounded worker pool.
    pub fn serve_http(&self, addr: &str, max_requests: Option<usize>) -> anyhow::Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("forkkv serving on http://{}", listener.local_addr()?);
        self.serve_listener(listener, max_requests)
    }

    /// Serve an already-bound listener (tests bind port 0 and read the
    /// actual address before calling this). Accepted connections are handed
    /// to `cfg.workers` scoped worker threads over a bounded channel, so up
    /// to `workers` requests are parsed/submitted concurrently and the
    /// accept loop backpressures at `cfg.accept_backlog` queued
    /// connections. Returns once the accept loop ends AND every accepted
    /// connection has been fully served (the scope joins the pool).
    pub fn serve_listener(
        &self,
        listener: TcpListener,
        max_requests: Option<usize>,
    ) -> anyhow::Result<()> {
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(self.cfg.accept_backlog.max(1));
        let conn_rx = Mutex::new(conn_rx);
        std::thread::scope(|scope| {
            for _ in 0..self.cfg.workers.max(1) {
                scope.spawn(|| loop {
                    // hold the lock only while waiting for the next
                    // connection; handling happens unlocked so workers
                    // service clients in parallel
                    let next = {
                        let guard = conn_rx.lock().unwrap_or_else(|e| e.into_inner());
                        guard.recv()
                    };
                    match next {
                        Ok(stream) => {
                            if let Err(e) = self.handle_conn(stream) {
                                eprintln!("conn error: {e:#}");
                            }
                        }
                        Err(_) => break, // accept loop done, queue drained
                    }
                });
            }
            let mut accepted = 0usize;
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                if conn_tx.send(stream).is_err() {
                    break;
                }
                accepted += 1;
                if let Some(max) = max_requests {
                    if accepted >= max {
                        break;
                    }
                }
            }
            // closing the channel is what lets the workers drain and exit;
            // the scope then joins them before returning
            drop(conn_tx);
        });
        Ok(())
    }

    fn handle_conn(&self, mut stream: TcpStream) -> anyhow::Result<()> {
        stream.set_nodelay(true).ok();
        // a silent or stalled client must not occupy a worker forever
        let io_timeout = (self.cfg.io_timeout_ms > 0)
            .then(|| Duration::from_millis(self.cfg.io_timeout_ms));
        stream.set_read_timeout(io_timeout).ok();
        stream.set_write_timeout(io_timeout).ok();
        let mut reader = BufReader::new(stream.try_clone()?);

        // cap the request-line + header section so an endless header stream
        // cannot exhaust memory (the body has its own max_body_bytes cap)
        let mut header_budget = MAX_HEADER_BYTES;
        let (request_line, truncated) = read_capped_line(&mut reader, &mut header_budget)?;
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let path = parts.next().unwrap_or("").to_string();

        let mut content_len = 0usize;
        let mut bad_content_len = false;
        let mut header_truncated = truncated;
        while !header_truncated {
            let (line, truncated) = read_capped_line(&mut reader, &mut header_budget)?;
            header_truncated = truncated;
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some(v) = line
                .to_ascii_lowercase()
                .strip_prefix("content-length:")
                .map(|v| v.trim().to_string())
            {
                match v.parse::<usize>() {
                    Ok(n) => content_len = n,
                    // a malformed length used to fall back to 0 and read an
                    // empty body — report it instead of mis-parsing
                    Err(_) => bad_content_len = true,
                }
            }
        }
        if header_truncated {
            return self.reject(
                &mut stream,
                &mut reader,
                "431 Request Header Fields Too Large",
                format!("header section exceeds {MAX_HEADER_BYTES} bytes"),
            );
        }
        if bad_content_len {
            return self.reject(
                &mut stream,
                &mut reader,
                "400 Bad Request",
                "invalid Content-Length header".to_string(),
            );
        }
        if content_len > self.cfg.max_body_bytes {
            return self.reject(
                &mut stream,
                &mut reader,
                "413 Payload Too Large",
                format!(
                    "body of {content_len} bytes exceeds limit {}",
                    self.cfg.max_body_bytes
                ),
            );
        }
        let mut body = vec![0u8; content_len];
        reader.read_exact(&mut body)?;
        let body = String::from_utf8_lossy(&body).to_string();

        let (status, payload) = match (method.as_str(), path.as_str()) {
            ("POST", "/generate") => self.api_generate(&body),
            ("POST", "/admin/kill_shard") => self.api_kill_shard(&body),
            ("GET", "/stats") => match self.stats() {
                Ok(j) => ("200 OK", j),
                Err(e) => (
                    "500 Internal Server Error",
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
                ),
            },
            ("GET", "/metrics") => match self.metrics_json() {
                Ok(j) => ("200 OK", j),
                Err(e) => (
                    "500 Internal Server Error",
                    Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
                ),
            },
            ("GET", "/health") => ("200 OK", Json::obj(vec![("ok", Json::Bool(true))])),
            _ => (
                "404 Not Found",
                Json::obj(vec![("error", Json::str("not found"))]),
            ),
        };
        write_response(&mut stream, status, &payload)
    }

    /// Early rejection: answer, then discard (a bounded amount of) any
    /// in-flight request bytes so closing the socket doesn't RST the
    /// response away before the client reads it. The drain runs under a
    /// short read timeout: it clears what's already on the wire without
    /// stalling on a client that sends nothing further.
    fn reject(
        &self,
        stream: &mut TcpStream,
        reader: &mut BufReader<TcpStream>,
        status: &'static str,
        error: String,
    ) -> anyhow::Result<()> {
        write_response(stream, status, &Json::obj(vec![("error", Json::str(error))]))?;
        stream
            .set_read_timeout(Some(Duration::from_millis(250)))
            .ok();
        let limit = (self.cfg.max_body_bytes as u64).max(64 << 10);
        let _ = std::io::copy(&mut reader.by_ref().take(limit), &mut std::io::sink());
        Ok(())
    }

    /// Returns (status line, payload); an engine-side drop is a capacity
    /// failure (503, retryable), not a client error.
    fn api_generate(&self, body: &str) -> (&'static str, Json) {
        fn err(status: &'static str, msg: String) -> (&'static str, Json) {
            (status, Json::obj(vec![("error", Json::str(msg))]))
        }
        let j = match json::parse(body) {
            Ok(j) => j,
            Err(e) => return err("400 Bad Request", format!("bad json: {e}")),
        };
        let prompt = match j.req_str("prompt") {
            Ok(p) => p,
            Err(e) => return err("400 Bad Request", format!("{e:#}")),
        };
        let adapter = j.get("adapter").and_then(Json::as_usize).unwrap_or(0) as u32;
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        // opaque workflow id: feeds the affinity fingerprint so one
        // workflow's agents co-locate even across HTTP connections
        let tag = j.get("tag").and_then(Json::as_usize).unwrap_or(0) as u64;
        // declared fan width of this workflow step (gang-admission hint)
        let fan = j.get("fan").and_then(Json::as_usize).unwrap_or(0);
        let tokens = self.tokenizer.encode(prompt);
        if let Err(e) = self.validate_request(&tokens, max_new) {
            return err("400 Bad Request", format!("{e:#}"));
        }
        // the DAG registry key: a step routed under its own tag (e.g. a
        // reducer homing on its own shard) still belongs to one workflow
        let workflow = j
            .get("workflow")
            .and_then(Json::as_usize)
            .map(|w| w as u64)
            .unwrap_or(tag);
        // workflow DAG registration (idempotent per workflow): every
        // agent of a step may attach the same `steps` payload
        if let Some(steps) = j.get("steps").and_then(Json::as_arr) {
            if let Err(e) = self.register_dag(workflow, steps, adapter) {
                return err("400 Bad Request", format!("bad dag: {e:#}"));
            }
        }
        // DAG arrival: mark the declared step running (moving the
        // prefetch frontier for its successors) and take its lease — it
        // is released only after the outcome lands, so prefetched pages
        // stay pinned through this request's admission
        let step = j.get("step").and_then(Json::as_str).map(str::to_string);
        let lease = step
            .as_deref()
            .and_then(|s| self.step_arrival(workflow, s, &tokens));
        // client-supplied idempotency key: with the journal on, a retry
        // of an already-terminal key returns the original outcome
        let key = j.get("key").and_then(Json::as_str).map(str::to_string);
        let outcome = self.generate_outcome_keyed(tokens, adapter, max_new, tag, fan, key);
        if let Some(l) = &lease {
            // the warmed step arrived: a prefetch hit whatever its outcome
            self.release_lease(l, true);
        }
        if let Some(s) = step.as_deref() {
            self.step_done(
                workflow,
                s,
                matches!(&outcome, Ok(RequestOutcome::Finished(_))),
            );
        }
        match outcome {
            Ok(RequestOutcome::Finished(fin)) => (
                "200 OK",
                Json::obj(vec![
                    (
                        "tokens",
                        Json::Arr(
                            fin.generated.iter().map(|&t| Json::num(t as f64)).collect(),
                        ),
                    ),
                    ("text", Json::str(self.tokenizer.decode(&fin.generated))),
                    ("prompt_tokens", Json::num(fin.prompt_len as f64)),
                    ("hit_tokens", Json::num(fin.hit_full as f64)),
                    ("ttft_us", Json::num(fin.ttft_us() as f64)),
                    ("latency_us", Json::num(fin.latency_us() as f64)),
                ]),
            ),
            Ok(RequestOutcome::Dropped(d)) => err(
                "503 Service Unavailable",
                format!("request dropped by engine ({}); retry later", d.reason.as_str()),
            ),
            Err(e) => err("500 Internal Server Error", format!("{e:#}")),
        }
    }

    /// Fault injection over HTTP: `POST /admin/kill_shard` with
    /// `{"shard": i, "min_depth": d, "wait_ms": w}` crashes shard `i` as
    /// if its process died mid-flight (see [`Server::kill_shard`]). With
    /// `min_depth > 0` the kill first waits (up to `wait_ms`) for the
    /// victim to hold at least that many in-flight requests, so a bench
    /// can guarantee the journal replay path actually runs.
    fn api_kill_shard(&self, body: &str) -> (&'static str, Json) {
        fn err(status: &'static str, msg: String) -> (&'static str, Json) {
            (status, Json::obj(vec![("error", Json::str(msg))]))
        }
        let j = match json::parse(body) {
            Ok(j) => j,
            Err(e) => return err("400 Bad Request", format!("bad json: {e}")),
        };
        let Some(shard) = j.get("shard").and_then(Json::as_usize) else {
            return err("400 Bad Request", "missing \"shard\"".to_string());
        };
        if shard >= self.shards.len() {
            return err("400 Bad Request", format!("no such shard {shard}"));
        }
        let min_depth = j.get("min_depth").and_then(Json::as_usize).unwrap_or(0);
        let wait_ms = j.get("wait_ms").and_then(Json::as_usize).unwrap_or(0) as u64;
        let deadline = Instant::now() + Duration::from_millis(wait_ms);
        while self.shard(shard).depth.load(Ordering::Relaxed) < min_depth
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        let depth_at_kill = if self.shard(shard).is_poisoned() {
            0
        } else {
            self.shard(shard).depth.load(Ordering::Relaxed)
        };
        let killed = self.kill_shard(shard);
        (
            "200 OK",
            Json::obj(vec![
                ("killed", Json::Bool(killed)),
                ("shard", Json::num(shard as f64)),
                ("depth_at_kill", Json::num(depth_at_kill as f64)),
            ]),
        )
    }
}

/// Cap on the request-line + header section of one request.
const MAX_HEADER_BYTES: usize = 16 << 10;

/// `read_line` bounded by a shared byte budget; the bool reports that the
/// budget was exhausted before a newline arrived (header section too big).
fn read_capped_line(
    reader: &mut BufReader<TcpStream>,
    budget: &mut usize,
) -> std::io::Result<(String, bool)> {
    let mut line = String::new();
    reader.by_ref().take(*budget as u64).read_line(&mut line)?;
    *budget -= line.len().min(*budget);
    let truncated = !line.ends_with('\n') && *budget == 0;
    Ok((line, truncated))
}

fn write_response(stream: &mut TcpStream, status: &str, payload: &Json) -> anyhow::Result<()> {
    let body = payload.to_string();
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// minimal HTTP client (tests + closed-loop workload driver)
// ---------------------------------------------------------------------------

/// One-shot HTTP/1.1 request against the hand-rolled server; returns
/// (status code, body). Relies on `Connection: close` framing.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let req = match body {
        Some(b) => format!(
            "{method} {path} HTTP/1.1\r\nHost: forkkv\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{b}",
            b.len()
        ),
        None => format!("{method} {path} HTTP/1.1\r\nHost: forkkv\r\nConnection: close\r\n\r\n"),
    };
    stream.write_all(req.as_bytes())?;
    let mut resp = String::new();
    BufReader::new(stream).read_to_string(&mut resp)?;
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed http response: {resp:?}"))?;
    let body = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, body))
}

/// Minimal HTTP/1.1 POST helper (tests and the bench harness talk to a
/// served pool through this): returns `(status, body)`.
pub fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<(u16, String)> {
    http_request(addr, "POST", path, Some(body))
}

/// Minimal HTTP/1.1 GET helper: returns `(status, body)`.
pub fn http_get(addr: &str, path: &str) -> anyhow::Result<(u16, String)> {
    http_request(addr, "GET", path, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CacheConfig, CachePolicy, EngineConfig, TierConfig};
    use crate::exec::SimExecutor;
    use crate::router::RoutePolicy;
    use crate::workload::{run_http_load, HttpLoadSpec};

    fn sim_engine(budget_bytes: usize, wall_pace_us: u64) -> Engine {
        let cfg = EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig { page_tokens: 16, budget_bytes, capacity_bytes: 0 },
            ..EngineConfig::default()
        };
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8])
            .unwrap()
            .with_wall_pace_us(wall_pace_us);
        Engine::new(cfg, Box::new(sim)).unwrap()
    }

    fn sim_server() -> (Arc<Server>, std::thread::JoinHandle<()>) {
        Server::start(sim_engine(32 << 20, 0))
    }

    /// Bind port 0 (no fixed-port collisions under parallel `cargo test`)
    /// and serve `max` connections on a background thread.
    fn spawn_server(
        srv: &Arc<Server>,
        max: usize,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let srv = srv.clone();
        let handle =
            std::thread::spawn(move || srv.serve_listener(listener, Some(max)).unwrap());
        (addr, handle)
    }

    #[test]
    fn generate_round_trip_over_engine_thread() {
        let (srv, handle) = sim_server();
        let tokens: Vec<u32> = (10..90).collect();
        let fin = srv.generate(tokens, 1, 8).unwrap();
        assert_eq!(fin.generated.len(), 8);
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn http_round_trip() {
        let (srv, handle) = sim_server();
        let (addr, server_thread) = spawn_server(&srv, 3);

        let body = r#"{"prompt": "the quick brown fox jumps over the lazy dog", "adapter": 2, "max_new": 6}"#;
        let (status, resp_body) = http_post(&addr, "/generate", body).unwrap();
        assert_eq!(status, 200, "{resp_body}");
        let j = json::parse(&resp_body).unwrap();
        assert_eq!(j.at(&["tokens"]).as_arr().unwrap().len(), 6);

        let (status, stats_body) = http_get(&addr, "/stats").unwrap();
        assert_eq!(status, 200, "{stats_body}");
        let stats = json::parse(&stats_body).unwrap();
        assert_eq!(stats.at(&["shards"]).as_usize().unwrap(), 1);

        let (status, metrics_body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(status, 200, "{metrics_body}");
        let m = json::parse(&metrics_body).unwrap();
        assert_eq!(m.at(&["per_shard"]).as_arr().unwrap().len(), 1);
        assert_eq!(m.at(&["route"]).as_str().unwrap(), "affinity");

        server_thread.join().unwrap();
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn malformed_content_length_is_rejected_not_misparsed() {
        let (srv, handle) = sim_server();
        let (addr, server_thread) = spawn_server(&srv, 1);

        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.write_all(b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: banana\r\n\r\n")
            .unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(resp.contains("invalid Content-Length"), "{resp}");

        server_thread.join().unwrap();
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn oversized_body_is_rejected_with_413() {
        let (srv, handle) = sim_server();
        let (addr, server_thread) = spawn_server(&srv, 1);

        let too_big = srv.config().max_body_bytes + 1;
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.write_all(
            format!("POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {too_big}\r\n\r\n")
                .as_bytes(),
        )
        .unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");

        server_thread.join().unwrap();
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn oom_drop_unblocks_waiter_with_error() {
        // budget of one base page: the request's lifetime footprint can
        // never be admitted, so the deadlock breaker must OOM-drop it —
        // and the waiter must get an error, not block forever
        let (srv, handle) = Server::start(sim_engine(64 << 10, 0));
        let tokens: Vec<u32> = (10..90).collect();
        let err = srv.generate(tokens, 0, 8).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("dropped"), "unexpected error: {msg}");

        // through HTTP the drop is a capacity failure: 503, not 400
        let (addr, server_thread) = spawn_server(&srv, 1);
        let prompt: String = (0..80).map(|i| format!("w{i}")).collect::<Vec<_>>().join(" ");
        let body = format!(r#"{{"prompt": "{prompt}", "max_new": 8}}"#);
        let (status, resp) = http_post(&addr, "/generate", &body).unwrap();
        assert_eq!(status, 503, "{resp}");
        assert!(resp.contains("dropped"), "{resp}");
        server_thread.join().unwrap();

        let stats = srv.stats().unwrap();
        assert!(
            stats.at(&["oom_drops"]).as_f64().unwrap() >= 2.0,
            "drops not accounted: {stats:?}"
        );
        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_clients_cobatch_through_http() {
        // 8 simultaneous closed-loop HTTP clients against a wall-paced sim:
        // all must complete, and the engine must have decoded real
        // multi-sequence batches (occupancy > 1), proving the worker pool
        // actually overlaps requests end to end
        let engine = sim_engine(64 << 20, 2_000);
        let (srv, handle) = Server::start_with(
            engine,
            ServerConfig { workers: 8, ..ServerConfig::default() },
        );
        let (addr, server_thread) = spawn_server(&srv, 8);

        let spec = HttpLoadSpec {
            clients: 8,
            requests_per_client: 1,
            shared_words: 120,
            unique_words: 4,
            max_new: 64,
            adapters: 4,
        };
        let report = run_http_load(&addr, &spec).unwrap();
        assert_eq!(report.at(&["ok"]).as_usize().unwrap(), 8, "{report:?}");
        assert_eq!(report.at(&["errors"]).as_usize().unwrap(), 0, "{report:?}");

        server_thread.join().unwrap();

        let stats = srv.stats().unwrap();
        let avg = stats.at(&["avg_decode_batch"]).as_f64().unwrap();
        let max = stats.at(&["max_decode_batch"]).as_f64().unwrap();
        assert!(avg > 1.0, "decode occupancy collapsed to serial: avg {avg}");
        assert!(max >= 2.0, "no multi-sequence decode batch formed: max {max}");
        assert_eq!(stats.at(&["completed"]).as_usize().unwrap(), 8);
        assert_eq!(stats.at(&["oom_drops"]).as_usize().unwrap(), 0);

        srv.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn sharded_pool_cobatches_on_every_shard() {
        // two wall-paced shards under round-robin: 8 closed-loop clients
        // split across the shards, so EACH shard must form multi-sequence
        // decode batches — the whole point of replicating the engine
        let engines: Vec<Engine> = (0..2).map(|_| sim_engine(32 << 20, 2_000)).collect();
        let scfg = ServerConfig {
            workers: 8,
            route_policy: RoutePolicy::RoundRobin,
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(engines, scfg);
        let (addr, server_thread) = spawn_server(&srv, 16);

        let spec = HttpLoadSpec {
            clients: 8,
            requests_per_client: 2,
            shared_words: 120,
            unique_words: 4,
            max_new: 48,
            adapters: 4,
        };
        let report = run_http_load(&addr, &spec).unwrap();
        assert_eq!(report.at(&["ok"]).as_usize().unwrap(), 16, "{report:?}");
        assert_eq!(report.at(&["errors"]).as_usize().unwrap(), 0, "{report:?}");
        server_thread.join().unwrap();

        let per_shard = srv.shard_stats().unwrap();
        assert_eq!(per_shard.len(), 2);
        for (i, s) in per_shard.iter().enumerate() {
            let avg = s.at(&["avg_decode_batch"]).as_f64().unwrap();
            let completed = s.at(&["completed"]).as_usize().unwrap();
            assert!(completed > 0, "shard {i} served nothing");
            assert!(avg > 1.0, "shard {i} decode occupancy collapsed: {avg}");
        }
        let agg = srv.stats().unwrap();
        assert_eq!(agg.at(&["completed"]).as_usize().unwrap(), 16);
        assert_eq!(agg.at(&["shards"]).as_usize().unwrap(), 2);

        // /metrics exposes the same per-shard split over HTTP
        let (addr2, t2) = spawn_server(&srv, 1);
        let (status, body) = http_get(&addr2, "/metrics").unwrap();
        assert_eq!(status, 200, "{body}");
        let m = json::parse(&body).unwrap();
        assert_eq!(m.at(&["per_shard"]).as_arr().unwrap().len(), 2);
        assert_eq!(m.at(&["route"]).as_str().unwrap(), "round_robin");
        t2.join().unwrap();

        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dead_shard_submissions_reroute_to_live_shard() {
        // two shards under round-robin: kill shard 0 outright, then every
        // request the rr counter lands there must be re-routed to the
        // live shard instead of failing the client
        let engines: Vec<Engine> = (0..2).map(|_| sim_engine(32 << 20, 0)).collect();
        let scfg = ServerConfig {
            route_policy: RoutePolicy::RoundRobin,
            ..ServerConfig::default()
        };
        let (srv, mut handles) = Server::start_sharded(engines, scfg);
        srv.shutdown_shard(0);
        handles.remove(0).join().unwrap(); // thread gone: channel closed

        for _ in 0..4 {
            let fin = srv.generate((10..60).collect(), 0, 4).unwrap();
            assert_eq!(fin.generated.len(), 4);
        }
        let m = srv.metrics_json().unwrap();
        assert!(
            m.at(&["router", "rerouted"]).as_usize().unwrap() >= 1,
            "re-routes not counted: {m:?}"
        );
        // observability survives the dead shard: it is reported, the
        // live shard's numbers still aggregate
        let per = m.at(&["per_shard"]).as_arr().unwrap();
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].at(&["dead"]).as_bool(), Some(true));
        assert_eq!(per[1].at(&["completed"]).as_usize().unwrap(), 4);
        assert_eq!(m.at(&["aggregate", "completed"]).as_usize().unwrap(), 4);
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rebalance_lends_budget_to_hot_shard_and_conserves_total() {
        // 4 shards, 4 MB pool: one 250-token request's lifetime footprint
        // (~17 base + 17 residual pages ≈ 1.25 MB + admission slack)
        // exceeds the 1 MB static slice, so its home shard OOM-drops it
        // while three peers sit idle. One rebalance tick lends the hot
        // shard their free budget, and the same request then fits.
        let total = 4 << 20;
        let base_cfg = EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: total,
                capacity_bytes: 0,
            },
            ..EngineConfig::default()
        };
        let engines: Vec<Engine> = (0..4)
            .map(|i| {
                let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
                Engine::new(base_cfg.shard_slice(i, 4), Box::new(sim)).unwrap()
            })
            .collect();
        let scfg = ServerConfig {
            rebalance: true,
            // park the supervisor: the test drives ticks deterministically
            rebalance_interval_ms: 3_600_000,
            lend_max_frac: 0.5,
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(engines, scfg);

        let budgets = |srv: &Server| -> Vec<usize> {
            srv.shard_stats()
                .unwrap()
                .iter()
                .map(|s| s.at(&["budget_bytes"]).as_usize().unwrap())
                .collect()
        };
        // the static split is exact before any rebalance
        assert_eq!(budgets(&srv).iter().sum::<usize>(), total);
        assert_eq!(budgets(&srv), vec![total / 4; 4]);

        let tokens: Vec<u32> = (100..350).collect(); // 250 tokens
        let err = srv.generate_tagged(tokens.clone(), 3, 8, 11).unwrap_err();
        assert!(format!("{err:#}").contains("dropped"), "{err:#}");

        // the drop (and the budget denials behind it) is the hot signal:
        // one tick lends the hot shard its idle peers' free budget
        let moved = srv.rebalance_tick();
        assert!(moved > 0, "no budget moved toward the hot shard");
        let after = budgets(&srv);
        assert_eq!(
            after.iter().sum::<usize>(),
            total,
            "lending must conserve the pool budget: {after:?}"
        );
        assert!(
            after.iter().copied().max().unwrap() > total / 4,
            "no shard grew past its static slice: {after:?}"
        );

        // with the lent budget the same request (same tag -> same home
        // shard) now completes
        let fin = srv.generate_tagged(tokens, 3, 8, 11).unwrap();
        assert_eq!(fin.generated.len(), 8);

        let m = srv.metrics_json().unwrap();
        assert_eq!(m.at(&["rebalancer", "enabled"]).as_bool(), Some(true));
        assert!(m.at(&["rebalancer", "budget_rebalances"]).as_usize().unwrap() >= 1);
        assert!(
            m.at(&["rebalancer", "bytes_lent"]).as_usize().unwrap() >= moved,
            "{m:?}"
        );
        assert!(
            m.at(&["aggregate", "budget_denials"]).as_usize().unwrap() >= 1,
            "{m:?}"
        );
        assert_eq!(m.at(&["aggregate", "budget_bytes"]).as_usize().unwrap(), total);
        assert_eq!(m.at(&["aggregate", "oom_drops"]).as_usize().unwrap(), 1);

        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rebalance_off_keeps_the_static_split() {
        let total = 4 << 20;
        let base_cfg = EngineConfig {
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: total,
                capacity_bytes: 0,
            },
            ..EngineConfig::default()
        };
        let engines: Vec<Engine> = (0..4)
            .map(|i| {
                let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
                Engine::new(base_cfg.shard_slice(i, 4), Box::new(sim)).unwrap()
            })
            .collect();
        let scfg = ServerConfig { rebalance: false, ..ServerConfig::default() };
        let (srv, handles) = Server::start_sharded(engines, scfg);
        // a drop creates pressure, but with the rebalancer disarmed a
        // tick is a no-op and every slice stays put
        let tokens: Vec<u32> = (100..350).collect();
        let _ = srv.generate_tagged(tokens, 3, 8, 11).unwrap_err();
        assert_eq!(srv.rebalance_tick(), 0);
        let m = srv.metrics_json().unwrap();
        assert_eq!(m.at(&["rebalancer", "enabled"]).as_bool(), Some(false));
        let per = m.at(&["per_shard"]).as_arr().unwrap();
        for s in per {
            assert_eq!(s.at(&["budget_bytes"]).as_usize().unwrap(), total / 4);
        }
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn tier_demotes_promotes_and_compacts_on_tick() {
        // one tiered shard, 2 MB pool: session B's working set forces
        // session A's pages out of the budget, but eviction demotes them
        // into the host tier; A's return promotes them back (bytes, not
        // FLOPs), and the promotion's dead tier records are reclaimed by
        // a deterministic compaction tick.
        let cfg = EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: 2 << 20,
                capacity_bytes: 0,
            },
            tier: TierConfig { tier_bytes: 64 << 20, cost: None },
            ..EngineConfig::default()
        };
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
        let engine = Engine::new(cfg, Box::new(sim)).unwrap();
        let scfg = ServerConfig {
            tier: true,
            // park the supervisor: the test drives compaction manually
            tier_compact_ms: 3_600_000,
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(vec![engine], scfg);

        let t_a: Vec<u32> = (1000..1300).collect(); // 300-token session A
        let t_b: Vec<u32> = (500..800).collect(); // 300-token session B
        srv.generate(t_a.clone(), 0, 8).unwrap();
        srv.generate(t_b, 1, 8).unwrap();
        srv.generate(t_a, 0, 8).unwrap(); // A returns

        let m = srv.metrics_json().unwrap();
        assert!(
            m.at(&["aggregate", "demoted_pages"]).as_usize().unwrap() > 0,
            "eviction never demoted: {m:?}"
        );
        assert!(
            m.at(&["aggregate", "promoted_pages"]).as_usize().unwrap() > 0,
            "returning session never promoted: {m:?}"
        );
        assert!(m.at(&["aggregate", "tier_hits"]).as_usize().unwrap() > 0);
        assert_eq!(m.at(&["tier", "enabled"]).as_bool(), Some(true));
        let tier_bytes = m.at(&["aggregate", "tier_bytes"]).as_usize().unwrap();
        let tier_budget = m.at(&["aggregate", "tier_budget_bytes"]).as_usize().unwrap();
        assert_eq!(tier_budget, 64 << 20);
        assert!(tier_bytes > 0 && tier_bytes <= tier_budget);

        // promotion invalidated its tier records; their bytes stay
        // retained until this tick reclaims them
        let reclaimed = srv.tier_compact_tick();
        assert!(reclaimed > 0, "nothing reclaimed after promotions");
        let m2 = srv.metrics_json().unwrap();
        assert!(m2.at(&["tier", "tier_compactions"]).as_usize().unwrap() >= 1);
        assert!(
            m2.at(&["tier", "tier_bytes_reclaimed"]).as_usize().unwrap() >= reclaimed
        );
        assert!(
            m2.at(&["aggregate", "tier_bytes"]).as_usize().unwrap() < tier_bytes,
            "compaction did not shrink retained tier bytes"
        );

        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn affinity_pins_shared_context_to_one_shard() {
        // same prompt + same tag, sequential (no overload): every request
        // must land on the same shard, and that shard's tree must serve
        // the repeats from cache
        let engines: Vec<Engine> = (0..4).map(|_| sim_engine(32 << 20, 0)).collect();
        let (srv, handles) = Server::start_sharded(engines, ServerConfig::default());
        let tokens: Vec<u32> = (100..260).collect();
        for _ in 0..4 {
            srv.generate_tagged(tokens.clone(), 3, 8, 9).unwrap();
        }
        let per_shard = srv.shard_stats().unwrap();
        let serving: Vec<usize> = per_shard
            .iter()
            .enumerate()
            .filter(|(_, s)| s.at(&["completed"]).as_usize().unwrap() > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(serving.len(), 1, "affinity scattered one context: {serving:?}");
        let agg = srv.stats().unwrap();
        assert_eq!(agg.at(&["completed"]).as_usize().unwrap(), 4);
        assert!(
            agg.at(&["hit_rate"]).as_f64().unwrap() > 0.5,
            "repeats did not hit the pinned shard's cache: {agg:?}"
        );
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Steps-to-execute DAG for a `width`-wide map→reduce workflow whose
    /// reducer declares the shared context as its known prefix.
    fn mapreduce_steps(width: usize, ctx: &str) -> Json {
        Json::Arr(
            (0..width)
                .map(|a| Json::obj(vec![("id", Json::str(format!("map{a}")))]))
                .chain(std::iter::once(Json::obj(vec![
                    ("id", Json::str("reduce")),
                    (
                        "after",
                        Json::Arr(
                            (0..width).map(|a| Json::str(format!("map{a}"))).collect(),
                        ),
                    ),
                    ("prefix", Json::str(ctx)),
                ])))
                .collect(),
        )
    }

    fn dag_body(ctx: &str, tail: &str, step: &str, steps: Option<&Json>) -> String {
        let mut fields = vec![
            ("prompt", Json::str(format!("{ctx} {tail}"))),
            ("adapter", Json::num(0.0)),
            ("max_new", Json::num(4.0)),
            ("tag", Json::num(5.0)),
            ("workflow", Json::num(5.0)),
            ("step", Json::str(step)),
        ];
        if let Some(s) = steps {
            fields.push(("steps", s.clone()));
        }
        Json::obj(fields).to_string()
    }

    #[test]
    fn dag_prefetch_lease_issued_before_arrival_and_hit_on_it() {
        // parked supervisor (tick 0): arrivals and completions drive
        // every horizon evaluation, so the test is fully deterministic
        let scfg = ServerConfig { prefetch_tick_ms: 0, ..ServerConfig::default() };
        let (srv, handles) = Server::start_sharded(vec![sim_engine(32 << 20, 0)], scfg);
        let (addr, server_thread) = spawn_server(&srv, 4);

        let ctx: String =
            (0..160).map(|i| format!("c{i}")).collect::<Vec<_>>().join(" ");
        let steps = mapreduce_steps(3, &ctx);
        for a in 0..3 {
            let body =
                dag_body(&ctx, &format!("map question {a}"), &format!("map{a}"), Some(&steps));
            let (status, resp) = http_post(&addr, "/generate", &body).unwrap();
            assert_eq!(status, 200, "{resp}");
        }

        // every predecessor has arrived, so the reducer entered the
        // horizon and its lease was issued before it ever posted
        let pf = srv.prefetch_stats();
        assert_eq!(pf.at(&["leases_issued"]).as_usize(), Some(1), "{pf}");
        assert_eq!(pf.at(&["leases_hit"]).as_usize(), Some(0), "{pf}");
        let m = srv.metrics_json().unwrap();
        assert!(
            m.at(&["aggregate", "prefetched_pages"]).as_usize().unwrap() > 0,
            "{m}"
        );

        // the warmed step arrives: its lease is released as a hit exactly
        // once, the DAG completes, and the registry empties
        let body = dag_body(&ctx, "join the mapper outputs", "reduce", None);
        let (status, resp) = http_post(&addr, "/generate", &body).unwrap();
        assert_eq!(status, 200, "{resp}");
        let j = json::parse(&resp).unwrap();
        assert!(j.at(&["hit_tokens"]).as_usize().unwrap() > 0, "{resp}");

        let pf = srv.prefetch_stats();
        assert_eq!(pf.at(&["leases_hit"]).as_usize(), Some(1), "{pf}");
        assert_eq!(pf.at(&["leases_abandoned"]).as_usize(), Some(0), "{pf}");
        assert_eq!(pf.at(&["live_dags"]).as_usize(), Some(0), "{pf}");
        let m = srv.metrics_json().unwrap();
        assert_eq!(m.at(&["aggregate", "prefetch_hits"]).as_usize(), Some(1), "{m}");
        assert_eq!(m.at(&["aggregate", "prefetch_wasted"]).as_usize(), Some(0), "{m}");

        server_thread.join().unwrap();
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn dag_abandonment_releases_the_lease_once_and_gcs_the_dag() {
        let scfg = ServerConfig {
            prefetch_tick_ms: 0,
            prefetch_abandon_ms: 1,
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(vec![sim_engine(32 << 20, 0)], scfg);
        let (addr, server_thread) = spawn_server(&srv, 3);

        let ctx: String =
            (0..160).map(|i| format!("d{i}")).collect::<Vec<_>>().join(" ");
        let steps = mapreduce_steps(3, &ctx);
        for a in 0..3 {
            let body =
                dag_body(&ctx, &format!("map question {a}"), &format!("map{a}"), Some(&steps));
            let (status, resp) = http_post(&addr, "/generate", &body).unwrap();
            assert_eq!(status, 200, "{resp}");
        }
        server_thread.join().unwrap();
        let pf = srv.prefetch_stats();
        assert_eq!(pf.at(&["leases_issued"]).as_usize(), Some(1), "{pf}");

        // the reducer never arrives: past the abandonment window the tick
        // releases its lease and accounts the warmed pages as wasted
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(srv.prefetch_tick(), 1);
        let m = srv.metrics_json().unwrap();
        let wasted = m.at(&["aggregate", "prefetch_wasted"]).as_usize().unwrap();
        let warmed = m.at(&["aggregate", "prefetched_pages"]).as_usize().unwrap();
        assert!(wasted > 0, "{m}");
        assert_eq!(wasted, warmed, "every warmed page is accounted wasted: {m}");

        // a second tick finds nothing: the abandoned node is never
        // re-warmed and the release never double-fires
        assert_eq!(srv.prefetch_tick(), 0);
        let pf = srv.prefetch_stats();
        assert_eq!(pf.at(&["leases_abandoned"]).as_usize(), Some(1), "{pf}");
        assert_eq!(pf.at(&["leases_issued"]).as_usize(), Some(1), "{pf}");

        // untouched for DAG_GC_FACTOR abandonment windows, the dead
        // workflow leaves the registry
        std::thread::sleep(Duration::from_millis(20));
        srv.prefetch_tick();
        assert_eq!(srv.prefetch_stats().at(&["live_dags"]).as_usize(), Some(0));

        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn malformed_dags_are_rejected_with_400() {
        let (srv, handle) = sim_server();
        let (addr, server_thread) = spawn_server(&srv, 3);

        let post = |steps: &str| {
            let body = format!(
                r#"{{"prompt": "one two three", "max_new": 2, "tag": 5, "step": "a", "steps": {steps}}}"#
            );
            http_post(&addr, "/generate", &body).unwrap()
        };
        let (status, resp) = post(r#"[{"id": "a", "after": ["b"]}, {"id": "b", "after": ["a"]}]"#);
        assert_eq!(status, 400, "{resp}");
        assert!(resp.contains("cycle"), "{resp}");
        let (status, resp) = post(r#"[{"id": "a"}, {"id": "a"}]"#);
        assert_eq!(status, 400, "{resp}");
        assert!(resp.contains("duplicate"), "{resp}");
        let (status, resp) = post(r#"[{"id": "a", "after": ["ghost"]}]"#);
        assert_eq!(status, 400, "{resp}");
        assert!(resp.contains("unknown step"), "{resp}");

        server_thread.join().unwrap();
        srv.shutdown();
        handle.join().unwrap();
    }

    /// Fresh per-test journal directory (removed by the test on success;
    /// a leaked dir from a failed run is rebuilt by the next).
    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("forkkv-srv-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Small-budget tiered engine: a second session's working set forces
    /// the first's pages to demote into the host tier.
    fn tiered_engine() -> Engine {
        let cfg = EngineConfig {
            policy: CachePolicy::Disaggregated,
            cache: CacheConfig {
                page_tokens: 16,
                budget_bytes: 2 << 20,
                capacity_bytes: 0,
            },
            tier: TierConfig { tier_bytes: 64 << 20, cost: None },
            ..EngineConfig::default()
        };
        let sim = SimExecutor::new("llama3-8b-sim", vec![1, 2, 4, 8]).unwrap();
        Engine::new(cfg, Box::new(sim)).unwrap()
    }

    #[test]
    fn duplicate_retry_with_same_key_returns_original_outcome() {
        let dir = tmp_dir("dedup");
        let scfg = ServerConfig {
            journal: true,
            journal_dir: dir.to_string_lossy().to_string(),
            journal_sync_ms: 0, // park the pacer; shutdown syncs
            checkpoint_ms: 0,
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(vec![sim_engine(32 << 20, 0)], scfg);
        let tokens: Vec<u32> = (10..90).collect();
        let first = srv
            .generate_outcome_keyed(tokens.clone(), 1, 8, 0, 0, Some("cli-req-1".into()))
            .unwrap();
        let RequestOutcome::Finished(fin) = &first else {
            panic!("dropped: {first:?}")
        };
        let retry = srv
            .generate_outcome_keyed(tokens, 1, 8, 0, 0, Some("cli-req-1".into()))
            .unwrap();
        let RequestOutcome::Finished(fin2) = &retry else {
            panic!("dropped: {retry:?}")
        };
        assert_eq!(fin.generated, fin2.generated, "retry changed the outcome");
        let j = srv.journal_stats();
        assert_eq!(j.at(&["enabled"]).as_bool(), Some(true));
        assert_eq!(j.at(&["submits"]).as_usize(), Some(1), "{j}");
        assert_eq!(j.at(&["outcomes"]).as_usize(), Some(1), "{j}");
        assert_eq!(j.at(&["deduped_retries"]).as_usize(), Some(1), "{j}");
        assert_eq!(j.at(&["duplicate_outcomes"]).as_usize(), Some(0), "{j}");
        // the engine executed the request exactly once
        let agg = srv.stats().unwrap();
        assert_eq!(agg.at(&["completed"]).as_usize(), Some(1), "{agg}");
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn killed_shard_requests_are_replayed_exactly_once_on_a_peer() {
        let dir = tmp_dir("replay");
        let scfg = ServerConfig {
            route_policy: RoutePolicy::RoundRobin,
            journal: true,
            journal_dir: dir.to_string_lossy().to_string(),
            checkpoint_ms: 0,
            ..ServerConfig::default()
        };
        // wall-paced decode: each request holds its shard for tens of
        // milliseconds, so the kill below lands mid-flight
        let engines: Vec<Engine> = (0..2).map(|_| sim_engine(32 << 20, 500)).collect();
        let (srv, handles) = Server::start_sharded(engines, scfg);
        let mut clients = Vec::new();
        for c in 0..4u32 {
            let srv = srv.clone();
            clients.push(std::thread::spawn(move || {
                let tokens: Vec<u32> = (100 + c * 40..160 + c * 40).collect();
                srv.generate_outcome_keyed(tokens, c, 48, 0, 0, Some(format!("cli-{c}")))
            }));
        }
        // catch a shard holding at least one in-flight request, crash it
        let deadline = Instant::now() + Duration::from_secs(10);
        let victim = loop {
            if let Some(v) = (0..2).find(|&i| {
                !srv.shards[i].is_poisoned()
                    && srv.shards[i].depth.load(Ordering::Relaxed) > 0
            }) {
                break v;
            }
            assert!(Instant::now() < deadline, "no shard ever held a request");
            std::thread::yield_now();
        };
        assert!(srv.kill_shard(victim));
        // every client gets a terminal outcome — no hangs, no errors:
        // the dead shard's journaled submits were replayed on the peer
        for c in clients {
            c.join().unwrap().unwrap();
        }
        let j = srv.journal_stats();
        assert!(j.at(&["replayed_requests"]).as_usize().unwrap() > 0, "{j}");
        assert_eq!(j.at(&["pending"]).as_usize(), Some(0), "{j}");
        assert_eq!(
            j.at(&["submits"]).as_usize().unwrap(),
            j.at(&["outcomes"]).as_usize().unwrap(),
            "every accepted submit must reach exactly one outcome: {j}"
        );
        assert_eq!(j.at(&["duplicate_outcomes"]).as_usize(), Some(0), "{j}");
        srv.shutdown();
        // the crashed shard's thread already exited; joins must not hang
        for h in handles {
            h.join().unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_restart_restores_checkpointed_pages_and_serves_hits() {
        let dir = tmp_dir("warm");
        let scfg = ServerConfig {
            tier: true,
            tier_compact_ms: 3_600_000,
            journal: true,
            journal_dir: dir.to_string_lossy().to_string(),
            checkpoint_ms: 0, // the test drives checkpointing by hand
            ..ServerConfig::default()
        };
        let (srv, handles) = Server::start_sharded(vec![tiered_engine()], scfg);
        let t_a: Vec<u32> = (1000..1300).collect();
        let t_b: Vec<u32> = (500..800).collect();
        srv.generate(t_a.clone(), 0, 8).unwrap();
        // B's working set forces A's pages to demote into the host tier
        srv.generate(t_b, 1, 8).unwrap();
        assert_eq!(srv.checkpoint_tick(), 1);
        assert!(dir.join("ckpt-shard-0.json").is_file());

        assert!(srv.kill_shard(0));
        let thread = srv.restart_shard(0, tiered_engine()).unwrap();
        let m = srv.metrics_json().unwrap();
        assert!(
            m.at(&["aggregate", "restored_pages"]).as_usize().unwrap() > 0,
            "warm restart restored nothing: {m}"
        );
        // session A returns to the restarted shard: served from the
        // salvaged tier + restored index instead of recomputed — a cold
        // restart starts from zero hits
        let fin = srv.generate(t_a, 0, 8).unwrap();
        assert!(fin.hit_full > 0, "warm-restarted shard served no cache hits");
        let j = srv.journal_stats();
        assert!(j.at(&["checkpoints_written"]).as_usize().unwrap() >= 1, "{j}");
        srv.shutdown();
        thread.join().unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kill_shard_endpoint_crashes_one_shard_and_survivors_serve() {
        let dir = tmp_dir("killhttp");
        let scfg = ServerConfig {
            route_policy: RoutePolicy::RoundRobin,
            journal: true,
            journal_dir: dir.to_string_lossy().to_string(),
            checkpoint_ms: 0,
            ..ServerConfig::default()
        };
        let engines: Vec<Engine> = (0..2).map(|_| sim_engine(32 << 20, 0)).collect();
        let (srv, handles) = Server::start_sharded(engines, scfg);
        let (addr, server_thread) = spawn_server(&srv, 4);

        let (status, resp) =
            http_post(&addr, "/admin/kill_shard", r#"{"shard": 9}"#).unwrap();
        assert_eq!(status, 400, "{resp}");
        let (status, resp) =
            http_post(&addr, "/admin/kill_shard", r#"{"shard": 1}"#).unwrap();
        assert_eq!(status, 200, "{resp}");
        let j = json::parse(&resp).unwrap();
        assert_eq!(j.at(&["killed"]).as_bool(), Some(true), "{resp}");

        // the survivor keeps serving; placements landing on the corpse
        // are re-routed instead of erroring
        for i in 0..2 {
            let body = format!(r#"{{"prompt": "hello survivor {i}", "max_new": 4}}"#);
            let (status, resp) = http_post(&addr, "/generate", &body).unwrap();
            assert_eq!(status, 200, "{resp}");
        }
        server_thread.join().unwrap();
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn planted_replica_serves_spills_as_hits_until_the_context_extends() {
        let scfg = ServerConfig {
            replicate: true,
            migrate: true,
            migration_max_inflight: 8,
            ..ServerConfig::default()
        };
        let engines: Vec<Engine> = (0..2).map(|_| sim_engine(32 << 20, 0)).collect();
        let (srv, handles) = Server::start_sharded(engines, scfg);
        const TAG: u64 = 0xF00D;
        let tokens: Vec<u32> = (1000..1300).collect();
        let home = srv.router.affinity_shard(&tokens, TAG);
        let other = 1 - home;
        let fp = srv.router.fingerprint(&tokens, TAG);
        // warm the home shard, then plant a replica on the peer
        srv.generate_tagged(tokens.clone(), 1, 8, TAG).unwrap();
        srv.replicate_to(fp, home, other, 1, &tokens);
        let m = srv.replication_stats();
        assert_eq!(m.at(&["replications"]).as_usize(), Some(1), "{m}");
        assert!(m.at(&["replica_bytes"]).as_usize().unwrap() > 0, "{m}");
        // an overloaded home now spills straight onto the holder, and the
        // verified-warm routing counts as a hit, not a cold miss
        let mut depths = vec![0usize; 2];
        depths[home] = 100;
        let (p, action) = srv.route_with_replicas(&tokens, TAG, 1, &depths);
        assert_eq!(p.shard, other, "spill must prefer the replica holder");
        assert_eq!(p.spilled_from, Some(home));
        assert!(matches!(action, SpillAction::ReplicaHit), "expected a replica hit");
        let m = srv.replication_stats();
        assert!(m.at(&["replica_hits"]).as_usize().unwrap() >= 1, "{m}");
        // the parent context extends past the detector's slack: every
        // replica of the shorter prefix is invalidated before routing
        let extended: Vec<u32> = (1000..1340).collect();
        assert_eq!(srv.router.fingerprint(&extended, TAG), fp);
        let (_, action) = srv.route_with_replicas(&extended, TAG, 1, &depths);
        assert!(
            !matches!(action, SpillAction::ReplicaHit),
            "a stale replica must not serve the extended context"
        );
        let holders = {
            let rep = srv.replication.as_ref().unwrap();
            rep.lock().unwrap().map.holders(fp)
        };
        assert!(holders.is_empty(), "extend left replicas behind: {holders:?}");
        let m = srv.replication_stats();
        assert!(m.at(&["replica_invalidations"]).as_usize().unwrap() >= 2, "{m}");
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn demoted_replica_unregisters_on_use_and_promotion_reregisters() {
        let scfg = ServerConfig {
            replicate: true,
            migrate: true,
            migration_max_inflight: 8,
            tier: true,
            tier_compact_ms: 3_600_000,
            ..ServerConfig::default()
        };
        let engines: Vec<Engine> = (0..2).map(|_| tiered_engine()).collect();
        let (srv, handles) = Server::start_sharded(engines, scfg);
        const TAG: u64 = 0xBEEF;
        let tokens: Vec<u32> = (2000..2300).collect();
        let home = srv.router.affinity_shard(&tokens, TAG);
        let other = 1 - home;
        let fp = srv.router.fingerprint(&tokens, TAG);
        srv.generate_tagged(tokens.clone(), 1, 8, TAG).unwrap();
        srv.replicate_to(fp, home, other, 1, &tokens);
        assert_eq!(
            srv.replication_stats().at(&["replications"]).as_usize(),
            Some(1)
        );
        // shrink the holder's device budget to nothing: every replica
        // page demotes into its host tier (`evict_demote`)
        assert!(srv.shards[other].send(Cmd::Budget(1)).is_ok());
        // verify-on-use: the demoted holder probes cold, so the spill is
        // NOT treated as a replica hit (which would cold-prefill) and the
        // stale registration is dropped on the spot
        let mut depths = vec![0usize; 2];
        depths[home] = 100;
        let (p, action) = srv.route_with_replicas(&tokens, TAG, 1, &depths);
        assert!(p.spilled_from.is_some(), "synthetic overload must spill");
        assert!(
            !matches!(action, SpillAction::ReplicaHit),
            "a demoted replica must not route a fork to a cold-prefilling shard"
        );
        let holders = {
            let rep = srv.replication.as_ref().unwrap();
            rep.lock().unwrap().map.holders(fp)
        };
        assert!(
            !holders.contains(&other),
            "stale holder survived verify-on-use: {holders:?}"
        );
        assert!(
            srv.replication_stats()
                .at(&["replica_invalidations"])
                .as_usize()
                .unwrap()
                >= 1
        );
        // restore the budget and re-plant: `Cmd::ReplicaWarm` promotes
        // the demoted pages back to the device tier (FIFO after the
        // Budget command), so residency re-registers without a new copy
        let bytes_before = srv
            .replication_stats()
            .at(&["replica_bytes"])
            .as_usize()
            .unwrap();
        assert!(bytes_before > 0, "the first plant shipped no bytes");
        assert!(srv.shards[other].send(Cmd::Budget(2 << 20)).is_ok());
        srv.replicate_to(fp, home, other, 1, &tokens);
        let holders = {
            let rep = srv.replication.as_ref().unwrap();
            rep.lock().unwrap().map.holders(fp)
        };
        assert!(
            holders.contains(&other),
            "promotion did not re-register the holder: {holders:?}"
        );
        assert_eq!(
            srv.replication_stats().at(&["replica_bytes"]).as_usize(),
            Some(bytes_before),
            "re-planting a promoted replica must be zero-copy"
        );
        srv.shutdown();
        for h in handles {
            h.join().unwrap();
        }
    }
}
