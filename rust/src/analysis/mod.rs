//! `forkkv analyze`: a cross-layer invariant linter for this repo.
//!
//! Six named passes machine-check the correctness rules that were
//! previously enforced by review alone (see `docs/ANALYSIS.md`):
//!
//!   - `panic_path` — no `unwrap()`/`expect()`/panicking macro/
//!     unchecked indexing in hot-path non-test code
//!   - `pair_discipline` — pin/lease acquisitions lexically paired
//!     with their releases; every `Cmd` variant handled
//!   - `lock_order` — nested acquisitions of the named pool locks
//!     respect the declared `analyze:lock-order:` hierarchy
//!   - `counter_drift` — every numeric `EngineMetrics` field is
//!     aggregated, serialized, and documented
//!   - `knob_drift` — every config field has a JSON key, a CLI flag,
//!     and a README knob-table row
//!   - `doc_gate` — the doc-gated modules opt into
//!     `#![warn(missing_docs)]` and their pub surface is documented
//!
//! Findings carry `file:line`, and a reviewed finding is suppressed in
//! place with `// analyze:allow(<pass>) reason` (see
//! [`scan::allow_map`] for the exact scoping rules). Allowed findings
//! are still reported — with `allowed: true` — so the escape hatch is
//! auditable; only non-allowed ("active") findings fail the run.
//!
//! The scanner is dependency-free by design: it lexes (comments out,
//! string interiors blanked) rather than parses, which is what makes
//! it immune to grep's false positives while staying fast enough to
//! run on every CI push.

#![warn(missing_docs)]

pub mod passes;
pub mod scan;

use std::path::Path;

use crate::util::json::Json;

/// One invariant violation (or reviewed-and-allowed exception).
pub struct Finding {
    /// Pass that produced the finding (`panic_path`, `lock_order`, …).
    pub pass: &'static str,
    /// Repo-relative file the finding points at.
    pub file: String,
    /// 1-based line number (whole-file findings anchor to line 1).
    pub line: usize,
    /// Human-readable description of the violation.
    pub message: String,
    /// True when an `analyze:allow` annotation covers the site.
    pub allowed: bool,
}

/// The result of one analyzer run over the tree.
pub struct Report {
    /// Every finding, allowed or not, in pass order.
    pub findings: Vec<Finding>,
}

impl Report {
    /// Number of findings *not* covered by an allow annotation — the
    /// run fails iff this is non-zero.
    pub fn active(&self) -> usize {
        self.findings.iter().filter(|f| !f.allowed).count()
    }

    /// Machine-readable report (the `--json` output and CI artifact).
    pub fn to_json(&self) -> String {
        let items = self.findings.iter().map(|f| {
            Json::obj(vec![
                ("pass", Json::str(f.pass)),
                ("file", Json::str(f.file.as_str())),
                ("line", Json::Num(f.line as f64)),
                ("message", Json::str(f.message.as_str())),
                ("allowed", Json::Bool(f.allowed)),
            ])
        });
        Json::obj(vec![
            ("findings", Json::arr(items)),
            ("active", Json::Num(self.active() as f64)),
        ])
        .to_string()
    }

    /// Human-readable report (the default CLI output).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let mut pass: &str = "";
        for f in &self.findings {
            if f.pass != pass {
                pass = f.pass;
                out.push_str(&format!("== {pass} ==\n"));
            }
            let mark = if f.allowed { " (allowed)" } else { "" };
            out.push_str(&format!("  {}:{}: {}{mark}\n", f.file, f.line, f.message));
        }
        out.push_str(&format!(
            "{} findings, {} active\n",
            self.findings.len(),
            self.active()
        ));
        out
    }
}

/// Hot-path files the `panic_path` pass scans.
const HOT_FILES: [&str; 4] = [
    "src/server/mod.rs",
    "src/engine/mod.rs",
    "src/router/mod.rs",
    "src/journal/mod.rs",
];

/// Files the `pair_discipline` pass scans for acquire/release pairing.
const PAIR_FILES: [&str; 7] = [
    "src/server/mod.rs",
    "src/engine/mod.rs",
    "src/router/mod.rs",
    "src/journal/mod.rs",
    "src/migrate/mod.rs",
    "src/radix/mod.rs",
    "src/tier/mod.rs",
];

/// Modules the `doc_gate` pass requires `#![warn(missing_docs)]` in.
const DOC_MODULES: [&str; 5] = [
    "src/engine/mod.rs",
    "src/server/mod.rs",
    "src/journal/mod.rs",
    "src/tier/mod.rs",
    "src/rebalance/mod.rs",
];

/// Locate the crate root (the directory holding `src/server/mod.rs`)
/// from `start`: accepts the crate dir itself or the repo root above
/// it (where the crate lives under `rust/`).
pub fn find_root(start: &Path) -> Option<std::path::PathBuf> {
    if start.join("src/server/mod.rs").is_file() {
        return Some(start.to_path_buf());
    }
    let nested = start.join("rust");
    if nested.join("src/server/mod.rs").is_file() {
        return Some(nested);
    }
    None
}

fn load(root: &Path, rel: &str) -> Option<String> {
    std::fs::read_to_string(root.join(rel)).ok()
}

/// Run every pass over the tree rooted at `root`. `filter` restricts
/// the report to findings whose file path starts with one of the given
/// prefixes (empty = everything).
pub fn run(root: &Path, filter: &[String]) -> Report {
    let mut findings = Vec::new();

    for rel in HOT_FILES {
        if let Some(src) = load(root, rel) {
            findings.extend(passes::panic_path(rel, &src));
        }
    }
    for rel in PAIR_FILES {
        if let Some(src) = load(root, rel) {
            findings.extend(passes::pair_discipline(rel, &src));
        }
    }
    if let Some(src) = load(root, "src/server/mod.rs") {
        findings.extend(passes::cmd_coverage("src/server/mod.rs", &src));
        findings.extend(passes::lock_order("src/server/mod.rs", &src));
    }
    if let Some(metrics) = load(root, "src/metrics/mod.rs") {
        let docs = load(root, "docs/METRICS.md").unwrap_or_default();
        findings.extend(passes::counter_drift("src/metrics/mod.rs", &metrics, &docs));
    }
    if let Some(config) = load(root, "src/config/mod.rs") {
        let main_src = load(root, "src/main.rs").unwrap_or_default();
        let readme = load(root, "README.md").unwrap_or_default();
        findings.extend(passes::knob_drift("src/config/mod.rs", &config, &main_src, &readme));
    }
    for rel in DOC_MODULES {
        if let Some(src) = load(root, rel) {
            findings.extend(passes::doc_gate(rel, &src));
        }
    }

    if !filter.is_empty() {
        findings.retain(|f| filter.iter().any(|p| f.file.starts_with(p.as_str())));
    }
    Report { findings }
}
