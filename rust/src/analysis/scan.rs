//! Line-level Rust source scanning: a small lexer that strips comments
//! and string contents per line, a `#[cfg(test)]` item mask, and the
//! `// analyze:allow(<pass>)` annotation map.
//!
//! This is deliberately *not* a parser. Every invariant pass works on
//! lexed lines (comments removed from code, string/char interiors
//! blanked so their contents can never fake a call site), which keeps
//! the analyzer dependency-free and fast while staying immune to the
//! classic grep failure modes (`unwrap` inside a string literal, a
//! commented-out `panic!`, an index expression inside a doc example).

/// A source file split into per-line code and comment channels.
///
/// `code[i]` is line `i` with comments removed and string/char literal
/// interiors dropped (the delimiting quotes are kept so "a string was
/// here" is still visible). `comments[i]` is the comment text of line
/// `i` (line comments, doc comments, and the body of block comments).
pub struct Lexed {
    /// Per-line code channel (strings blanked, comments removed).
    pub code: Vec<String>,
    /// Per-line comment channel (everything the code channel dropped).
    pub comments: Vec<String>,
}

/// Lex `src` into per-line code and comment channels.
///
/// Handles line comments (`//`, `///`, `//!`), nested block comments,
/// string literals with escapes, raw strings (`r"…"`, `r#"…"#`, byte
/// variants), and char literals vs lifetimes (`'a'` vs `'a`).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code: Vec<String> = Vec::new();
    let mut comments: Vec<String> = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comment = String::new();

    enum State {
        Normal,
        Block(usize),
        Str,
        RawStr(usize),
    }
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_comment));
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let c2 = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && c2 == '/' {
                    // line comment (incl. /// and //! docs)
                    while i < n && chars[i] != '\n' {
                        cur_comment.push(chars[i]);
                        i += 1;
                    }
                } else if c == '/' && c2 == '*' {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur_code.push('"');
                    i += 1;
                } else if let Some((skip, hashes)) = raw_string_open(&chars, i) {
                    state = State::RawStr(hashes);
                    cur_code.push('"');
                    i += skip;
                } else if c == '\'' {
                    if let Some(skip) = char_literal(&chars, i) {
                        cur_code.push_str("' '");
                        i += skip;
                    } else {
                        // a lifetime: keep the tick, the ident follows as code
                        cur_code.push('\'');
                        i += 1;
                    }
                } else {
                    cur_code.push(c);
                    i += 1;
                }
            }
            State::Block(depth) => {
                let c2 = chars.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && c2 == '*' {
                    state = State::Block(depth + 1);
                    i += 2;
                } else if c == '*' && c2 == '/' {
                    if depth == 1 {
                        state = State::Normal;
                    } else {
                        state = State::Block(depth - 1);
                    }
                    i += 2;
                } else {
                    cur_comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    cur_code.push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    cur_code.push('"');
                    state = State::Normal;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_comment);
    Lexed { code, comments }
}

/// If position `i` opens a raw string (`r"`, `r#"`, `br"`, …), return
/// `(chars to skip, hash count)`. Guards against identifiers ending in
/// `r` by requiring the previous char not be a word char.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_word(chars[i - 1]) {
        return None;
    }
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string with `hashes` trailing `#`s?
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// If position `i` (a `'`) starts a char literal, return its length in
/// chars; `None` means it is a lifetime tick.
fn char_literal(chars: &[char], i: usize) -> Option<usize> {
    let next = chars.get(i + 1).copied()?;
    if next == '\\' {
        // escaped char: scan to the closing quote on the same line
        let mut j = i + 2;
        while j < chars.len() && chars[j] != '\n' && j < i + 12 {
            if chars[j] == '\'' {
                return Some(j + 1 - i);
            }
            j += 1;
        }
        None
    } else if next != '\'' && chars.get(i + 2) == Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Per-line mask: `true` where the line is inside a `#[cfg(test)]`
/// item (the attribute line itself, through the item's closing brace).
pub fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0usize;
    while i < code.len() {
        let t = &code[i];
        if !(t.contains("cfg(test)") && t.contains("#[")) {
            i += 1;
            continue;
        }
        // brace-track from the attribute through the item it gates
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < code.len() {
            for ch in code[j].chars() {
                if ch == '{' {
                    depth += 1;
                    opened = true;
                } else if ch == '}' {
                    depth -= 1;
                }
            }
            mask[j] = true;
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Per-line allow map: `map[i]` is the set of pass names an
/// `// analyze:allow(<pass>) reason` annotation suppresses on line `i`.
///
/// Two forms:
///   - `// analyze:allow(<pass>) reason` — covers its own line and the
///     next line (annotate above or at end of the flagged line);
///   - `// analyze:allow(<pass>, fn) reason` — covers the whole body of
///     the next `fn` item (skipping blank and `#[…]` attribute lines).
pub fn allow_map(lx: &Lexed) -> Vec<Vec<String>> {
    let mut map: Vec<Vec<String>> = vec![Vec::new(); lx.code.len()];
    let mut push = |map: &mut Vec<Vec<String>>, ln: usize, name: &str| {
        if ln < map.len() && !map[ln].iter().any(|s| s == name) {
            map[ln].push(name.to_string());
        }
    };
    for (ln, text) in lx.comments.iter().enumerate() {
        let mut rest: &str = text;
        while let Some(p) = rest.find("analyze:allow(") {
            rest = &rest[p + "analyze:allow(".len()..];
            let Some((name, fn_scoped, after)) = parse_allow_args(rest) else {
                continue;
            };
            rest = after;
            if !fn_scoped {
                push(&mut map, ln, &name);
                push(&mut map, ln + 1, &name);
                continue;
            }
            // fn-scoped: locate the next fn item, cover through its close
            let mut j = ln + 1;
            while j < lx.code.len() {
                let s = lx.code[j].trim();
                if line_declares_fn(&lx.code[j]) {
                    break;
                }
                if s.is_empty() || s.starts_with("#[") {
                    j += 1;
                    continue;
                }
                break;
            }
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut k = j;
            while k < lx.code.len() {
                push(&mut map, k, &name);
                for ch in lx.code[k].chars() {
                    if ch == '{' {
                        depth += 1;
                        opened = true;
                    } else if ch == '}' {
                        depth -= 1;
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                k += 1;
            }
        }
    }
    map
}

/// Parse `<name>)` or `<name>, fn)` at the head of `rest`; return the
/// pass name, whether it is fn-scoped, and the remaining text.
fn parse_allow_args(rest: &str) -> Option<(String, bool, &str)> {
    let mut name = String::new();
    for (idx, c) in rest.char_indices() {
        if c.is_ascii_alphanumeric() || c == '_' || c == '-' {
            name.push(c);
            continue;
        }
        if name.is_empty() {
            return None;
        }
        let tail = &rest[idx..];
        if let Some(t) = tail.strip_prefix(')') {
            return Some((name, false, t));
        }
        // optional `, fn)` (whitespace tolerated)
        let t = tail.trim_start_matches([',', ' ', '\t']);
        if tail.starts_with(',') && t.starts_with("fn)") {
            return Some((name, true, &t[3..]));
        }
        return None;
    }
    None
}

/// Does this code line declare a `fn` (as a word, followed by a name)?
pub fn line_declares_fn(line: &str) -> bool {
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0usize;
    while i + 1 < chars.len() {
        if chars[i] == 'f'
            && chars[i + 1] == 'n'
            && (i == 0 || !is_word(chars[i - 1]))
            && chars.get(i + 2).is_some_and(|c| c.is_whitespace())
        {
            let mut j = i + 2;
            while j < chars.len() && chars[j].is_whitespace() {
                j += 1;
            }
            if chars.get(j).is_some_and(|&c| is_word(c)) {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// A `name: Type` field of a struct, with its 0-based line number.
pub struct Field {
    /// Field name.
    pub name: String,
    /// Field type as written (up to the first `,`).
    pub ty: String,
    /// 0-based line of the field in the lexed file.
    pub line: usize,
}

/// The fields of `struct <name> { … }` in lexed code lines. Returns an
/// empty list when the struct is absent.
pub fn struct_fields(code: &[String], name: &str) -> Vec<Field> {
    let mut fields = Vec::new();
    let header = format!("struct {name}");
    let mut start: Option<usize> = None;
    let mut depth: i64 = 0;
    for (ln, t) in code.iter().enumerate() {
        match start {
            None => {
                let is_decl = t.contains(&header)
                    && !t
                        .split(&header)
                        .nth(1)
                        .is_some_and(|rest| rest.starts_with(|c: char| is_word(c)));
                let brace_near =
                    code[ln..code.len().min(ln + 3)].iter().any(|l| l.contains('{'));
                if is_decl && brace_near {
                    start = Some(ln);
                    depth = brace_delta(t);
                }
            }
            Some(_) => {
                depth += brace_delta(t);
                if let Some(f) = parse_field_line(t, ln) {
                    if depth >= 1 {
                        fields.push(f);
                    }
                }
                if depth <= 0 {
                    break;
                }
            }
        }
    }
    fields
}

fn brace_delta(line: &str) -> i64 {
    let mut d = 0i64;
    for c in line.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
    }
    d
}

/// Parse a `pub name: Type,` struct-field line (attributes and
/// non-field lines return `None`).
fn parse_field_line(line: &str, ln: usize) -> Option<Field> {
    let mut s = line.trim_start();
    if s.starts_with("#[") || s.starts_with("#![") {
        return None;
    }
    if let Some(rest) = s.strip_prefix("pub ") {
        s = rest.trim_start();
    }
    let name_end = s
        .char_indices()
        .find(|&(_, c)| !is_word(c))
        .map(|(i, _)| i)
        .unwrap_or(s.len());
    if name_end == 0 {
        return None;
    }
    let name = &s[..name_end];
    if !name.starts_with(|c: char| c.is_ascii_alphabetic() || c == '_') {
        return None;
    }
    let after = s[name_end..].trim_start();
    let ty_part = after.strip_prefix(':')?;
    // type chars per the field grammar we care about: idents, paths,
    // generics, whitespace — stop at the trailing comma
    let ty_end = ty_part
        .char_indices()
        .find(|&(_, c)| !(is_word(c) || c == ':' || c == '<' || c == '>' || c.is_whitespace()))
        .map(|(i, _)| i)
        .unwrap_or(ty_part.len());
    let ty = ty_part[..ty_end].trim();
    if ty.is_empty() {
        return None;
    }
    Some(Field { name: name.to_string(), ty: ty.to_string(), line: ln })
}

/// Positions of `needle` in `hay` where the char before the match is
/// not a word char (a poor man's `\b` on the left side).
pub fn find_word_starts(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let boundary = hay[..at]
            .chars()
            .next_back()
            .map(|c| !is_word(c))
            .unwrap_or(true);
        if boundary {
            out.push(at);
        }
        from = at + needle.len();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_strips_comments_and_strings() {
        let lx = lex("let x = \"unwrap()\"; // panic!()\nlet y = 1;\n");
        assert_eq!(lx.code[0], "let x = \"\"; ");
        assert!(lx.comments[0].contains("panic!"));
        assert_eq!(lx.code[1], "let y = 1;");
    }

    #[test]
    fn lexer_handles_raw_strings_and_lifetimes() {
        let lx = lex("let r = r#\"a \" b\"#;\nfn f<'a>(x: &'a str) -> char { 'x' }\n");
        assert_eq!(lx.code[0], "let r = \"\";");
        assert!(lx.code[1].contains("<'a>"));
        assert!(lx.code[1].contains("' '"));
    }

    #[test]
    fn block_comments_nest() {
        let lx = lex("a /* x /* y */ z */ b\n");
        assert_eq!(lx.code[0].trim(), "a  b".trim());
        assert!(lx.comments[0].contains('y'));
    }

    #[test]
    fn test_mask_covers_cfg_test_mod() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}\n";
        let lx = lex(src);
        let m = test_mask(&lx.code);
        assert_eq!(m, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn allow_map_plain_and_fn_scoped() {
        let src = "\
// analyze:allow(panic_path) reason
let a = v[i];
// analyze:allow(panic_path, fn) whole body
fn f(v: &[u32]) -> u32 {
    v[0]
}
let later = 1;
";
        let lx = lex(src);
        let m = allow_map(&lx);
        assert!(m[0].iter().any(|s| s == "panic_path"));
        assert!(m[1].iter().any(|s| s == "panic_path"));
        assert!(m[4].iter().any(|s| s == "panic_path"));
        assert!(m[5].iter().any(|s| s == "panic_path"));
        assert!(m[6].is_empty());
    }

    #[test]
    fn struct_fields_finds_typed_fields() {
        let src = "pub struct M {\n    pub a: u64,\n    b: Vec<usize>,\n}\n";
        let lx = lex(src);
        let fs = struct_fields(&lx.code, "M");
        assert_eq!(fs.len(), 2);
        assert_eq!(fs[0].name, "a");
        assert_eq!(fs[0].ty, "u64");
        assert_eq!(fs[1].name, "b");
    }
}
