//! The six invariant passes behind `forkkv analyze`.
//!
//! Every pass is a pure function over lexed source text (plus, for the
//! drift passes, the companion artifacts it cross-checks against), so
//! the fixture tests in `tests/analyze.rs` can drive each one against a
//! single bad file without touching the real tree. The driver in
//! [`super`] maps them over the repo's hot-path files.
//!
//! Suppression: a `// analyze:allow(<pass>) reason` comment (see
//! [`super::scan::allow_map`]) marks a finding as reviewed; allowed
//! findings are still reported (with `allowed: true`) but do not fail
//! the run.

use super::scan::{self, allow_map, lex, struct_fields, test_mask, Lexed};
use super::Finding;

/// Build a finding for `pass` at 0-based `line` of `file`.
fn finding(pass: &'static str, file: &str, line: usize, msg: String, allowed: bool) -> Finding {
    Finding {
        pass,
        file: file.to_string(),
        line: line + 1,
        message: msg,
        allowed,
    }
}

fn has_allow(map: &[Vec<String>], line: usize, pass: &str) -> bool {
    map.get(line).is_some_and(|v| v.iter().any(|s| s == pass))
}

// ------------------------------------------------------------------
// pass 1: panic-path
// ------------------------------------------------------------------

/// Panicking macros flagged on the hot path (left word boundary and a
/// following `(` are required, so `log_panic!`-style names don't trip).
const PANIC_MACROS: [&str; 4] = ["panic!", "unreachable!", "todo!", "unimplemented!"];

/// `panic-path`: no `unwrap()`, `expect(…)`, panicking macro, or
/// unchecked `[index]` expression in non-test code of a hot-path file.
///
/// `assert!`-family contract checks are deliberately *not* flagged:
/// an assertion states an invariant, an unwrap hides one.
pub fn panic_path(path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_mask(&lx.code);
    let amap = allow_map(&lx);
    let mut out = Vec::new();
    for (ln, text) in lx.code.iter().enumerate() {
        if tmask[ln] {
            continue;
        }
        let allowed = has_allow(&amap, ln, "panic_path");
        if text.contains(".unwrap()") {
            out.push(finding("panic_path", path, ln, "panicking call: .unwrap()".into(), allowed));
        }
        if text.contains(".expect(") {
            out.push(finding("panic_path", path, ln, "panicking call: .expect(".into(), allowed));
        }
        for mac in PANIC_MACROS {
            for at in scan::find_word_starts(text, mac) {
                let after = text[at + mac.len()..].trim_start();
                if after.starts_with('(') {
                    out.push(finding(
                        "panic_path",
                        path,
                        ln,
                        format!("panicking call: {mac}("),
                        allowed,
                    ));
                }
            }
        }
        for inner in index_expressions(text) {
            out.push(finding(
                "panic_path",
                path,
                ln,
                format!("unchecked indexing [{inner}]"),
                allowed,
            ));
        }
    }
    out
}

/// Extract `expr[index]` subscript interiors worth flagging: the char
/// before `[` must be a word char / `)` / `]` (so slice types, array
/// literals, and attributes don't match), the interior must contain a
/// letter (so `[0]` literals pass), and ranges (`..`) and array-type
/// notation (`;`) are skipped.
fn index_expressions(line: &str) -> Vec<String> {
    let chars: Vec<char> = line.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < chars.len() {
        if chars[i] != '[' {
            i += 1;
            continue;
        }
        let prev_ok = i > 0
            && (chars[i - 1].is_ascii_alphanumeric()
                || chars[i - 1] == '_'
                || chars[i - 1] == ')'
                || chars[i - 1] == ']');
        if !prev_ok {
            i += 1;
            continue;
        }
        // innermost-bracket scan: abandon on a nested `[`
        let mut j = i + 1;
        let mut inner = String::new();
        let mut closed = false;
        while j < chars.len() {
            match chars[j] {
                ']' => {
                    closed = true;
                    break;
                }
                '[' => break,
                c => inner.push(c),
            }
            j += 1;
        }
        if !closed {
            i += 1;
            continue;
        }
        i = j + 1;
        let inner = inner.trim().to_string();
        if inner.is_empty() || inner.contains("..") || inner.contains(';') {
            continue;
        }
        if !inner.chars().any(|c| c.is_ascii_alphabetic()) {
            continue;
        }
        let numeric = {
            let stem = inner.strip_suffix("usize").unwrap_or(&inner);
            !stem.is_empty() && stem.chars().all(|c| c.is_ascii_digit() || c == '_')
        };
        if numeric {
            continue;
        }
        out.push(inner);
    }
    out
}

// ------------------------------------------------------------------
// pass 2: pair discipline (+ Cmd coverage)
// ------------------------------------------------------------------

/// Acquire → release vocabularies the pair pass knows about.
const PAIRS: [(&str, &[&str]); 3] = [
    ("pin_prefix(", &["unpin_path("]),
    ("match_lease(", &["release_path("]),
    ("prefetch_pin(", &["prefetch_release(", "PrefetchRelease"]),
];

/// `pair-discipline`, per-file half: every `pin_prefix` /
/// `match_lease` / `prefetch_pin` call site must be lexically paired
/// with its release somewhere in the same (non-test) file — a file
/// that acquires but can never release is a leak by construction.
pub fn pair_discipline(path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_mask(&lx.code);
    let amap = allow_map(&lx);
    let nontest: Vec<(usize, &String)> = lx
        .code
        .iter()
        .enumerate()
        .filter(|(ln, _)| !tmask[*ln])
        .collect();
    let lines: Vec<&str> = nontest.iter().map(|(_, t)| t.as_str()).collect();
    let blob = lines.join("\n");
    let mut out = Vec::new();
    for (acquire, releases) in PAIRS {
        if !blob.contains(acquire) {
            continue;
        }
        let stem = acquire.trim_end_matches('(');
        let call_lines: Vec<usize> = nontest
            .iter()
            .filter(|(_, t)| t.contains(acquire) && !is_fn_def_of(t, stem))
            .map(|(ln, _)| *ln)
            .collect();
        if call_lines.is_empty() {
            continue;
        }
        if releases.iter().any(|r| blob.contains(r)) {
            continue;
        }
        for ln in call_lines {
            let allowed = has_allow(&amap, ln, "pair_discipline");
            out.push(finding(
                "pair_discipline",
                path,
                ln,
                format!(
                    "{stem} call without any {} in file",
                    releases[0].trim_end_matches('(')
                ),
                allowed,
            ));
        }
    }
    out
}

/// Does this line *define* a function whose name ends with `stem`
/// (rather than calling it)?
fn is_fn_def_of(line: &str, stem: &str) -> bool {
    let mut rest = line;
    while let Some(p) = rest.find("fn ") {
        let at_boundary = rest[..p]
            .chars()
            .next_back()
            .map(|c| !(c.is_ascii_alphanumeric() || c == '_'))
            .unwrap_or(true);
        let after = rest[p + 3..].trim_start();
        let name: String = after
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if at_boundary && name.ends_with(stem) {
            return true;
        }
        rest = &rest[p + 3..];
    }
    false
}

/// `pair-discipline`, Cmd half: every variant of the server's `Cmd`
/// enum must be *handled* somewhere outside the enum declaration (a
/// variant nobody matches is a shard-protocol message that would be
/// silently dropped).
pub fn cmd_coverage(path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_mask(&lx.code);
    let mut variants: Vec<(String, usize)> = Vec::new();
    let mut enum_start: Option<usize> = None;
    let mut enum_end = 0usize;
    let mut depth: i64 = 0;
    for (ln, t) in lx.code.iter().enumerate() {
        match enum_start {
            None => {
                let longer_name = t
                    .split("enum Cmd")
                    .nth(1)
                    .is_some_and(|r| r.starts_with(|c: char| c.is_ascii_alphanumeric() || c == '_'));
                if t.contains("enum Cmd") && !longer_name {
                    enum_start = Some(ln);
                    depth = delta(t);
                }
            }
            Some(_) => {
                depth += delta(t);
                let head: String = t
                    .trim_start()
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                if depth >= 1 && head.starts_with(|c: char| c.is_ascii_uppercase()) {
                    variants.push((head, ln));
                }
                if depth <= 0 {
                    enum_end = ln;
                    break;
                }
            }
        }
    }
    let Some(start) = enum_start else { return Vec::new() };
    let body: String = lx
        .code
        .iter()
        .enumerate()
        .filter(|(ln, _)| !tmask[*ln] && !(start <= *ln && *ln <= enum_end))
        .map(|(_, t)| t.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let mut out = Vec::new();
    for (v, ln) in variants {
        if !body.contains(&format!("Cmd::{v}")) {
            out.push(finding(
                "pair_discipline",
                path,
                ln,
                format!("Cmd::{v} not handled outside the enum declaration"),
                false,
            ));
        }
    }
    out
}

fn delta(line: &str) -> i64 {
    let mut d = 0i64;
    for c in line.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
    }
    d
}

// ------------------------------------------------------------------
// pass 3: lock order
// ------------------------------------------------------------------

/// The named pool-wide locks the order pass tracks, and the call
/// shapes that acquire them in `server/mod.rs`.
fn lock_hits(line: &str) -> Vec<&'static str> {
    let mut hits = Vec::new();
    if line.contains("tx_lock.read(") || line.contains("tx_lock.write(") {
        hits.push("shard_tx");
    }
    if line.contains("salvaged_lock.lock(") {
        hits.push("salvaged");
    }
    const JOURNAL_CALLS: [&str; 10] = [
        "journal.append_submit(",
        "journal.append_outcome(",
        "journal.claim(",
        "journal.claim_shard(",
        "journal.claim_all(",
        "journal.pending_len(",
        "journal.stats(",
        "journal.sync(",
        "journal.maybe_sync(",
        "journal.lock_stat(",
    ];
    if JOURNAL_CALLS.iter().any(|c| line.contains(c)) {
        hits.push("journal");
    }
    if line.contains("outcomes_lock.lock(") {
        hits.push("outcomes");
    }
    let rep_hit = !scan::find_word_starts(line, "rep.lock()").is_empty()
        || line
            .find("replication")
            .is_some_and(|p| line[p..].contains(".lock("));
    if rep_hit {
        hits.push("replicas");
    }
    hits
}

/// `lock-order`: extract nested acquisition scopes over the named
/// pool locks and check them against the `// analyze:lock-order:`
/// declaration (and for cycles). A `let`-bound guard is held until its
/// enclosing block closes; a temporary guard dies at its statement's
/// `;`. Edges are (held → acquired) pairs observed while another lock
/// is live.
pub fn lock_order(path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_mask(&lx.code);
    // declared order from the annotation comment
    let mut declared: Option<Vec<String>> = None;
    for text in &lx.comments {
        if let Some(p) = text.find("analyze:lock-order:") {
            let rest = &text[p + "analyze:lock-order:".len()..];
            let spec: String = rest
                .chars()
                .take_while(|c| {
                    c.is_ascii_alphanumeric() || *c == '_' || *c == '<' || c.is_whitespace()
                })
                .collect();
            declared = Some(spec.split('<').map(|s| s.trim().to_string()).collect());
        }
    }
    // nested-acquisition edges
    let mut edges: Vec<(&'static str, &'static str, usize)> = Vec::new();
    // (lock, Some(close-at-depth) for let-guards | None for temporaries)
    let mut held: Vec<(&'static str, Option<i64>)> = Vec::new();
    let mut depth: i64 = 0;
    for (ln, t) in lx.code.iter().enumerate() {
        if tmask[ln] {
            continue;
        }
        for name in lock_hits(t) {
            for &(h, _) in &held {
                if h != name && !edges.iter().any(|&(a, b, _)| a == h && b == name) {
                    edges.push((h, name, ln));
                }
            }
            let is_let = !scan::find_word_starts(t, "let ").is_empty()
                || t.trim_start().starts_with("let ");
            held.push((name, if is_let { Some(depth) } else { None }));
        }
        if t.contains(';') {
            held.retain(|h| h.1.is_some());
        }
        for c in t.chars() {
            if c == '{' {
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                held.retain(|h| match h.1 {
                    None => true,
                    Some(close) => close < depth + 1,
                });
            }
        }
    }
    let mut out = Vec::new();
    if has_cycle(&edges) {
        let msg = "cycle in lock acquisition order graph".to_string();
        out.push(finding("lock_order", path, 0, msg, false));
    }
    match declared {
        Some(order) => {
            let rank = |n: &str| order.iter().position(|o| o == n);
            for &(a, b, ln) in &edges {
                if let (Some(ra), Some(rb)) = (rank(a), rank(b)) {
                    if ra > rb {
                        out.push(finding(
                            "lock_order",
                            path,
                            ln,
                            format!("acquisition {a} -> {b} contradicts declared order"),
                            false,
                        ));
                    }
                }
            }
        }
        None => {
            out.push(finding(
                "lock_order",
                path,
                0,
                "no analyze:lock-order declaration found".into(),
                false,
            ));
        }
    }
    out
}

fn has_cycle(edges: &[(&'static str, &'static str, usize)]) -> bool {
    let nodes: Vec<&str> = {
        let mut v: Vec<&str> = Vec::new();
        for &(a, b, _) in edges {
            if !v.contains(&a) {
                v.push(a);
            }
            if !v.contains(&b) {
                v.push(b);
            }
        }
        v
    };
    // DFS three-color over a tiny graph
    fn dfs(
        u: &str,
        edges: &[(&'static str, &'static str, usize)],
        grey: &mut Vec<String>,
        black: &mut Vec<String>,
    ) -> bool {
        grey.push(u.to_string());
        for &(a, b, _) in edges {
            if a != u {
                continue;
            }
            if grey.iter().any(|g| g == b) {
                return true;
            }
            if !black.iter().any(|x| x == b) && dfs(b, edges, grey, black) {
                return true;
            }
        }
        grey.retain(|g| g != u);
        black.push(u.to_string());
        false
    }
    let mut grey = Vec::new();
    let mut black = Vec::new();
    nodes
        .iter()
        .any(|n| !black.iter().any(|x| x == n) && dfs(n, edges, &mut grey, &mut black))
}

// ------------------------------------------------------------------
// pass 4: counter drift
// ------------------------------------------------------------------

/// Numeric field types that count as counters/gauges.
const NUMERIC: [&str; 6] = ["u64", "usize", "u32", "f64", "u8", "i64"];

/// `counter-drift`: every numeric `EngineMetrics` field must appear in
/// the pool aggregation (`SUMMED_KEYS` or the `aggregate_stats` body),
/// in the `to_json` serializer, and as a `docs/METRICS.md` row — a
/// counter missing any leg silently under-reports.
pub fn counter_drift(path: &str, metrics_src: &str, metrics_docs: &str) -> Vec<Finding> {
    let lx = lex(metrics_src);
    let amap = allow_map(&lx);
    let summed = summed_keys(metrics_src);
    let agg = body_between(metrics_src, "fn aggregate_stats", "\n}");
    let to_json = body_between(metrics_src, "fn to_json", "\n    }");
    let mut out = Vec::new();
    for f in struct_fields(&lx.code, "EngineMetrics") {
        if !NUMERIC.contains(&f.ty.as_str()) {
            continue;
        }
        let allowed = has_allow(&amap, f.line, "counter_drift");
        let quoted = format!("\"{}\"", f.name);
        if !summed.iter().any(|k| *k == f.name) && !agg.contains(&f.name) {
            out.push(finding(
                "counter_drift",
                path,
                f.line,
                format!("metrics field `{}` missing from aggregate_stats/SUMMED_KEYS", f.name),
                allowed,
            ));
        }
        if !to_json.contains(&quoted) {
            out.push(finding(
                "counter_drift",
                path,
                f.line,
                format!("metrics field `{}` missing from to_json serializer", f.name),
                allowed,
            ));
        }
        if !metrics_docs.contains(&format!("`{}`", f.name)) {
            out.push(finding(
                "counter_drift",
                path,
                f.line,
                format!("metrics field `{}` has no docs/METRICS.md row", f.name),
                allowed,
            ));
        }
    }
    out
}

/// The string keys of the `SUMMED_KEYS` array in raw source.
fn summed_keys(src: &str) -> Vec<String> {
    let Some(p) = src.find("SUMMED_KEYS") else { return Vec::new() };
    // skip past the `=` so the `[&str; N]` type annotation's bracket
    // can't be mistaken for the array literal
    let Some(eq) = src[p..].find('=') else { return Vec::new() };
    let rest = &src[p + eq..];
    let Some(open) = rest.find('[') else { return Vec::new() };
    let Some(close) = rest[open..].find(']') else { return Vec::new() };
    let body = &rest[open..open + close];
    let mut keys = Vec::new();
    let mut it = body.split('"');
    it.next();
    while let (Some(k), Some(_)) = (it.next(), it.next()) {
        keys.push(k.to_string());
    }
    keys
}

/// The raw-source span from the first occurrence of `start` to the
/// next occurrence of `end` (inclusive of neither bound's tail).
fn body_between(src: &str, start: &str, end: &str) -> String {
    let Some(p) = src.find(start) else { return String::new() };
    let rest = &src[p..];
    match rest.find(end) {
        Some(q) => rest[..q].to_string(),
        None => rest.to_string(),
    }
}

// ------------------------------------------------------------------
// pass 5: knob drift
// ------------------------------------------------------------------

/// Config-field → serving-surface aliases: the JSON key / CLI flag /
/// README spelling when it differs from the field name (unit-scaled
/// knobs like `budget_bytes` ↔ `budget_mb`).
const KNOB_ALIASES: [(&str, &[&str]); 13] = [
    ("route_policy", &["route"]),
    ("max_body_bytes", &["max_body_kb", "max-body-kb"]),
    ("migration_bandwidth_bytes_per_s", &["migrate_gbps", "migrate-gbps"]),
    ("migration_max_inflight", &["migrate-max-inflight", "migrate_max_inflight"]),
    ("replicate_miss_threshold", &["replicate-miss", "replicate_miss"]),
    ("rebalance_interval_ms", &["rebalance-ms", "rebalance_ms"]),
    ("lend_max_frac", &["lend-max", "lend_max"]),
    ("journal_sync_bytes", &["journal-sync-kb", "journal_sync_kb"]),
    ("journal_segment_bytes", &["journal-seg-kb", "journal_seg_kb"]),
    ("imbalance_factor", &["imbalance"]),
    ("budget_bytes", &["budget_mb", "budget-mb"]),
    ("capacity_bytes", &["capacity_mb"]),
    ("tier_bytes", &["tier_mb", "tier-mb"]),
];

/// Struct-typed config fields whose knobs live on their own struct.
const NESTED_CONFIG_TYPES: [&str; 4] =
    ["CacheConfig", "SchedulerConfig", "TierConfig", "CachePolicy"];

/// `knob-drift`: every `ServerConfig` / `EngineConfig` / `TierConfig`
/// field must be loadable from JSON, settable from the CLI, and listed
/// in the README knob table — a knob missing a surface is dead config.
pub fn knob_drift(path: &str, config_src: &str, main_src: &str, readme: &str) -> Vec<Finding> {
    let lx = lex(config_src);
    let amap = allow_map(&lx);
    let mut out = Vec::new();
    for sname in ["ServerConfig", "EngineConfig", "TierConfig"] {
        for f in struct_fields(&lx.code, sname) {
            let base = f.ty.split('<').next().unwrap_or("").trim();
            if NESTED_CONFIG_TYPES.contains(&base) {
                continue;
            }
            let allowed = has_allow(&amap, f.line, "knob_drift");
            let mut names: Vec<String> = vec![f.name.clone()];
            for (field, aliases) in KNOB_ALIASES {
                if field == f.name {
                    names.extend(aliases.iter().map(|s| s.to_string()));
                }
            }
            if !names.iter().any(|n| config_src.contains(&format!("\"{n}\""))) {
                out.push(finding(
                    "knob_drift",
                    path,
                    f.line,
                    format!("{sname}.{}: no JSON key in config", f.name),
                    allowed,
                ));
            }
            if !names.iter().any(|n| main_src.contains(&format!("--{}", kebab(n)))) {
                out.push(finding(
                    "knob_drift",
                    path,
                    f.line,
                    format!("{sname}.{}: no CLI flag in main.rs", f.name),
                    allowed,
                ));
            }
            let in_readme = names.iter().any(|n| {
                readme.contains(&format!("`{n}`")) || readme.contains(&format!("`--{}", kebab(n)))
            });
            if !in_readme {
                out.push(finding(
                    "knob_drift",
                    path,
                    f.line,
                    format!("{sname}.{}: no README knob-table entry", f.name),
                    allowed,
                ));
            }
        }
    }
    out
}

fn kebab(s: &str) -> String {
    s.replace('_', "-")
}

// ------------------------------------------------------------------
// pass 6: doc gate
// ------------------------------------------------------------------

/// Item keywords the doc gate inventories.
const PUB_ITEM_KINDS: [&str; 8] =
    ["fn", "struct", "enum", "trait", "mod", "const", "static", "type"];

/// `doc-gate`: the module must opt into `#![warn(missing_docs)]`, and
/// (mirroring what rustc will then enforce) every non-test `pub` item,
/// `pub` struct field, and enum variant must carry a `///` doc.
pub fn doc_gate(path: &str, src: &str) -> Vec<Finding> {
    let lx = lex(src);
    let tmask = test_mask(&lx.code);
    let mut out = Vec::new();
    if !src.contains("#![warn(missing_docs)]") {
        let msg = "module missing #![warn(missing_docs)]".to_string();
        out.push(finding("doc_gate", path, 0, msg, false));
    }
    for (ln, t) in lx.code.iter().enumerate() {
        if tmask[ln] {
            continue;
        }
        let Some((kind, name)) = pub_item(t) else { continue };
        if !looks_documented(&lx, ln) {
            out.push(finding(
                "doc_gate",
                path,
                ln,
                format!("undocumented pub {kind} {name}"),
                false,
            ));
        }
    }
    out.extend(member_docs(path, &lx, &tmask));
    out
}

/// Parse `pub [unsafe] <kind> <name>` at the head of a code line.
fn pub_item(line: &str) -> Option<(&'static str, String)> {
    let mut s = line.trim_start();
    s = s.strip_prefix("pub ")?;
    s = s.trim_start();
    if let Some(rest) = s.strip_prefix("unsafe ") {
        s = rest.trim_start();
    }
    for kind in PUB_ITEM_KINDS {
        if let Some(rest) = s.strip_prefix(kind) {
            let rest = rest.strip_prefix(' ')?;
            let name: String = rest
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                return Some((kind, name));
            }
            return None;
        }
    }
    None
}

/// Walk upward from the item over attribute lines looking for a `///`
/// (or `//!`-adjacent) doc comment.
fn looks_documented(lx: &Lexed, ln: usize) -> bool {
    let mut k = ln as i64 - 1;
    while k >= 0 {
        let prev_comment = lx.comments[k as usize].trim();
        let prev_code = lx.code[k as usize].trim();
        if prev_comment.starts_with("///") {
            return true;
        }
        if prev_code.starts_with("#[") || prev_code.starts_with("#![") {
            k -= 1;
            continue;
        }
        return false;
    }
    false
}

/// Undocumented `pub` fields of pub structs and variants of pub enums.
fn member_docs(path: &str, lx: &Lexed, tmask: &[bool]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < lx.code.len() {
        let Some((kind, owner)) = pub_container(&lx.code[i]) else {
            i += 1;
            continue;
        };
        let brace_near = lx.code[i..lx.code.len().min(i + 3)].iter().any(|l| l.contains('{'));
        if tmask[i] || !brace_near {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut j = i;
        while j < lx.code.len() {
            depth += delta(&lx.code[j]);
            if j > i && depth == 1 {
                let member = if kind == "struct" {
                    pub_field_name(&lx.code[j])
                } else {
                    variant_name(&lx.code[j])
                };
                if let Some(m) = member {
                    let doc = j > 0 && lx.comments[j - 1].trim().starts_with("///");
                    if !doc {
                        out.push(finding(
                            "doc_gate",
                            path,
                            j,
                            format!("undocumented {kind} member {owner}::{m}"),
                            false,
                        ));
                    }
                }
            }
            if depth <= 0 && j > i {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    out
}

/// Parse `pub struct <name>` / `pub enum <name>` at the head of a line.
fn pub_container(line: &str) -> Option<(&'static str, String)> {
    let s = line.trim_start().strip_prefix("pub ")?.trim_start();
    for kind in ["struct", "enum"] {
        if let Some(rest) = s.strip_prefix(kind) {
            let rest = rest.strip_prefix(' ')?;
            let name: String = rest
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect();
            if !name.is_empty() {
                let k: &'static str = if kind == "struct" { "struct" } else { "enum" };
                return Some((k, name));
            }
        }
    }
    None
}

/// `pub <name>:` field line inside a struct body.
fn pub_field_name(line: &str) -> Option<String> {
    let s = line.trim_start().strip_prefix("pub ")?.trim_start();
    let name: String = s
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        return None;
    }
    s[name.len()..].trim_start().starts_with(':').then_some(name)
}

/// `<Variant>` line inside an enum body (leading uppercase ident).
fn variant_name(line: &str) -> Option<String> {
    let s = line.trim_start();
    let name: String = s
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.starts_with(|c: char| c.is_ascii_uppercase()) {
        Some(name)
    } else {
        None
    }
}
