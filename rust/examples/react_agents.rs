//! ReAct agent loop over the DAG serving API: a 4-step reason→act chain
//! where each step's prompt extends the previous step's. The chain is
//! declared up front as a steps-to-execute DAG with `prefix_from` edges,
//! so while step N decodes the server already knows step N+1's prefix
//! (everything step N submitted) and pre-warms it under a prefetch lease
//! — the cross-step horizon from the KVFlow line of work.
//!
//!   cargo run --release --example react_agents

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::SimExecutor;
use forkkv::server::{http_post, Server};
use forkkv::util::json::{self, Json};
use forkkv::workload::presets;

const STEPS: usize = 4;

fn post_step(addr: &str, prompt: &str, step: &str, steps: Option<&Json>) -> anyhow::Result<Json> {
    let mut fields = vec![
        ("prompt", Json::str(prompt)),
        ("adapter", Json::num(0.0)),
        ("max_new", Json::num(8.0)),
        ("tag", Json::num(9.0)),
        ("workflow", Json::num(9.0)),
        ("step", Json::str(step)),
    ];
    if let Some(s) = steps {
        fields.push(("steps", s.clone()));
    }
    let (status, resp) = http_post(addr, "/generate", &Json::obj(fields).to_string())?;
    anyhow::ensure!(status == 200, "step {step}: HTTP {status}: {resp}");
    Ok(json::parse(&resp)?)
}

fn main() -> anyhow::Result<()> {
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 64 << 20, capacity_bytes: 0 },
        seed: 9,
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", presets::SIM_BUCKETS.to_vec())?;
    let engine = Engine::new(cfg, Box::new(sim))?;
    let scfg = ServerConfig { prefetch: true, ..ServerConfig::default() };
    let (server, shard_handles) = Server::start_sharded(vec![engine], scfg);

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let serve = {
        let server = server.clone();
        std::thread::spawn(move || server.serve_listener(listener, Some(STEPS)))
    };

    // the chain: s1 depends on s0 and inherits its prefix, and so on —
    // `prefix_from` tells the server each successor's prefix is whatever
    // its predecessor submitted, resolved once that predecessor arrives
    let steps = Json::Arr(
        (0..STEPS)
            .map(|i| {
                let mut node = vec![("id", Json::str(format!("s{i}")))];
                if i > 0 {
                    node.push(("after", Json::Arr(vec![Json::str(format!("s{}", i - 1))])));
                    node.push(("prefix_from", Json::str(format!("s{}", i - 1))));
                }
                Json::obj(node)
            })
            .collect(),
    );

    println!("# ReAct chain over the DAG API, sim execution");
    // shared scratchpad context, grown by one observation per step; long
    // enough to span several 16-token pages so leases have pages to pin
    let mut prompt = (0..100).map(|i| format!("obs{i}")).collect::<Vec<_>>().join(" ");
    for i in 0..STEPS {
        prompt = format!("{prompt} thought{i} action{i}");
        let r = post_step(&addr, &prompt, &format!("s{i}"), (i == 0).then_some(&steps))?;
        println!(
            "s{i} | prompt {} tok, hit {} tok, ttft {:.0} us",
            r.at(&["prompt_tokens"]).as_usize().unwrap_or(0),
            r.at(&["hit_tokens"]).as_usize().unwrap_or(0),
            r.at(&["ttft_us"]).as_f64().unwrap_or(0.0),
        );
    }

    serve.join().unwrap()?;
    println!("prefetch: {}", server.prefetch_stats());
    server.shutdown();
    for h in shard_handles {
        h.join().ok();
    }
    Ok(())
}
