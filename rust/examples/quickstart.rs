//! Quickstart: load the AOT artifacts, serve a few multi-adapter requests
//! through the full ForkKV engine (real PJRT execution, no python on the
//! request path), and show the fork/CoW sharing in action.
//!
//!   make artifacts && cargo run --release --example quickstart

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::{Engine, Request, Tick};
use forkkv::exec::PjrtExecutor;
use forkkv::util::tokenizer::HashTokenizer;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts/llama3-8b-sim");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    eprintln!("loading {} ...", dir.display());
    let exec = PjrtExecutor::load(dir)?;
    let tokenizer = HashTokenizer::new(2048);

    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 64 << 20, capacity_bytes: 0 },
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg, Box::new(exec))?;

    // a shared "codebase" context + three specialized agents
    let shared = "fn main() { let cache = DualRadixTree::new(); \
                  cache.fork(agent); } // shared repository context \
                  module scheduler policy eviction memory pages tokens \
                  adapters residual base attention kernel rope lora rank \
                  router batcher leader worker decode prefill chunk page";
    let questions = [
        (0u32, "navigator: where is the scheduler defined ?"),
        (1u32, "generator: write the eviction policy patch"),
        (2u32, "tester: draft a unit test for fork semantics"),
    ];

    for (i, (adapter, q)) in questions.iter().enumerate() {
        let mut tokens = tokenizer.encode(shared);
        tokens.extend(tokenizer.encode(q));
        engine.submit(Request {
            id: i as u64,
            tag: 0,
            adapter: *adapter,
            tokens,
            max_new: 12,
            arrival_us: i as u64,
            ignore_eos: true,
            fan: 0,
        });
    }

    let t0 = std::time::Instant::now();
    let mut done = 0;
    while done < questions.len() {
        match engine.tick()? {
            Tick::Progress => {
                for fin in engine.drain_finished() {
                    done += 1;
                    println!(
                        "agent {} | prompt {} tok | inherited: {} full + {} partial (bCache) | out: {}",
                        fin.adapter,
                        fin.prompt_len,
                        fin.hit_full,
                        fin.hit_partial,
                        tokenizer.decode(&fin.generated),
                    );
                }
            }
            Tick::Idle => break,
        }
    }
    println!(
        "\n{} requests in {:.2}s wallclock | hit rate {:.2} | partial (bCache reuse) {:.2}",
        done,
        t0.elapsed().as_secs_f64(),
        engine.metrics.hit_rate(),
        engine.metrics.hit_partial_tokens as f64 / engine.metrics.prompt_tokens as f64
    );
    println!(
        "base pool {:.1} MB | residual pool {:.2} MB  <- the Eq. 3 asymmetry",
        engine.base_pool().used_bytes() as f64 / 1048576.0,
        engine.res_pool().map_or(0.0, |p| p.used_bytes() as f64 / 1048576.0),
    );
    Ok(())
}
