//! MapReduce workflow over the DAG serving API: three mapper agents fork
//! the same shared context in parallel (the paper's broadcast-redundancy
//! case, Fig. 2b) while the server — told the whole step graph up front —
//! pre-warms the reducer's declared prefix on its home shard under a
//! prefetch lease. By the time the reducer posts, its context pages are
//! pinned and warm: the cross-step horizon from the KVFlow line of work.
//!
//!   cargo run --release --example mapreduce_agents

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig, ServerConfig};
use forkkv::engine::Engine;
use forkkv::exec::SimExecutor;
use forkkv::server::{http_post, Server};
use forkkv::util::json::{self, Json};
use forkkv::workload::presets;

const WIDTH: usize = 3;

fn post_step(
    addr: &str,
    prompt: &str,
    step: &str,
    fan: usize,
    steps: Option<&Json>,
) -> anyhow::Result<Json> {
    let mut fields = vec![
        ("prompt", Json::str(prompt)),
        ("adapter", Json::num(0.0)),
        ("max_new", Json::num(8.0)),
        ("tag", Json::num(7.0)),
        ("workflow", Json::num(7.0)),
        ("step", Json::str(step)),
        ("fan", Json::num(fan as f64)),
    ];
    if let Some(s) = steps {
        fields.push(("steps", s.clone()));
    }
    let (status, resp) = http_post(addr, "/generate", &Json::obj(fields).to_string())?;
    anyhow::ensure!(status == 200, "step {step}: HTTP {status}: {resp}");
    Ok(json::parse(&resp)?)
}

fn print_step(name: &str, r: &Json) {
    println!(
        "{name:<7}| prompt {} tok, hit {} tok, ttft {:.0} us",
        r.at(&["prompt_tokens"]).as_usize().unwrap_or(0),
        r.at(&["hit_tokens"]).as_usize().unwrap_or(0),
        r.at(&["ttft_us"]).as_f64().unwrap_or(0.0),
    );
}

fn main() -> anyhow::Result<()> {
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 64 << 20, capacity_bytes: 0 },
        seed: 10,
        ..EngineConfig::default()
    };
    let sim = SimExecutor::new("llama3-8b-sim", presets::SIM_BUCKETS.to_vec())?;
    let engine = Engine::new(cfg, Box::new(sim))?;
    let scfg = ServerConfig { prefetch: true, ..ServerConfig::default() };
    let (server, shard_handles) = Server::start_sharded(vec![engine], scfg);

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let serve = {
        let server = server.clone();
        std::thread::spawn(move || server.serve_listener(listener, Some(WIDTH + 1)))
    };

    // shared "document" context every agent forks; long enough to span
    // several 16-token pages so the prefetch lease has pages to pin
    let ctx = (0..120).map(|i| format!("doc{i}")).collect::<Vec<_>>().join(" ");

    // the steps-to-execute DAG: mappers are roots, the reducer depends on
    // all of them and declares its prefix (the shared context) up front so
    // the server can warm it while the mappers are still decoding
    let steps = Json::Arr(
        (0..WIDTH)
            .map(|a| Json::obj(vec![("id", Json::str(format!("map{a}")))]))
            .chain(std::iter::once(Json::obj(vec![
                ("id", Json::str("reduce")),
                (
                    "after",
                    Json::Arr((0..WIDTH).map(|a| Json::str(format!("map{a}"))).collect()),
                ),
                ("prefix", Json::str(&ctx)),
            ])))
            .collect(),
    );

    println!("# MapReduce fan over the DAG API, sim execution");
    let mappers: Vec<_> = (0..WIDTH)
        .map(|a| {
            let (addr, ctx, steps) = (addr.clone(), ctx.clone(), steps.clone());
            std::thread::spawn(move || {
                post_step(
                    &addr,
                    &format!("{ctx} map{a} extract the key facts"),
                    &format!("map{a}"),
                    WIDTH,
                    Some(&steps),
                )
            })
        })
        .collect();
    for (a, h) in mappers.into_iter().enumerate() {
        let r = h.join().unwrap()?;
        print_step(&format!("map{a}"), &r);
    }

    // every mapper has answered, so the reducer's lease is already issued:
    // this request lands on warm, pinned pages
    let r = post_step(&addr, &format!("{ctx} join the mapper outputs"), "reduce", 1, None)?;
    print_step("reduce", &r);

    serve.join().unwrap()?;
    println!("prefetch: {}", server.prefetch_stats());
    server.shutdown();
    for h in shard_handles {
        h.join().ok();
    }
    Ok(())
}
