//! Serving demo: start the HTTP front-end on the real PJRT engine, fire a
//! few client requests at it from this process, print the responses, then
//! shut down. (For a long-running server use `forkkv serve`.)
//!
//!   make artifacts && cargo run --release --example serve

use std::io::{Read, Write};
use std::net::TcpStream;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::Engine;
use forkkv::exec::PjrtExecutor;
use forkkv::server::Server;

fn post(addr: &str, body: &str) -> anyhow::Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    let req = format!(
        "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(req.as_bytes())?;
    let mut resp = String::new();
    conn.read_to_string(&mut resp)?;
    Ok(resp
        .split("\r\n\r\n")
        .nth(1)
        .unwrap_or("")
        .to_string())
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts/llama3-8b-sim");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let exec = PjrtExecutor::load(dir)?;
    let cfg = EngineConfig {
        policy: CachePolicy::Disaggregated,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 48 << 20, capacity_bytes: 0 },
        ..EngineConfig::default()
    };
    let engine = Engine::new(cfg, Box::new(exec))?;
    let (server, engine_thread) = Server::start(engine);

    let addr = "127.0.0.1:18080";
    let http_thread = {
        let server = server.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || server.serve_http(&addr, Some(4)))
    };
    std::thread::sleep(std::time::Duration::from_millis(200));

    let shared = "analyze this repository scheduler allocator radix tree fork \
                  copy on write pages adapters residual base cache kernel";
    for (adapter, task) in [
        (0, "summarize the design"),
        (1, "find potential bugs"),
        (0, "summarize the design"), // repeat: full cache hit
        (2, "suggest optimizations"),
    ] {
        let body = format!(
            r#"{{"prompt": "{shared} {task}", "adapter": {adapter}, "max_new": 10}}"#
        );
        let t0 = std::time::Instant::now();
        let resp = post(addr, &body)?;
        println!(
            "adapter {adapter} [{task}] -> {resp} ({:.0} ms)",
            t0.elapsed().as_secs_f64() * 1000.0
        );
    }
    http_thread.join().unwrap()?;
    println!("\nstats: {}", server.stats()?);
    server.shutdown();
    engine_thread.join().ok();
    Ok(())
}
