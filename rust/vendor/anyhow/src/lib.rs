//! Offline stand-in for the `anyhow` crate (the container has no crates.io
//! registry access, so the serving crate vendors the small subset it uses).
//!
//! Implemented surface — exactly what the forkkv sources call:
//!   - `anyhow::Error`: opaque error with an optional source chain
//!   - `anyhow::Result<T>` with defaulted error type
//!   - `anyhow!`, `bail!`, `ensure!` macros (format-string style)
//!   - `From<E: std::error::Error + Send + Sync + 'static>` so `?` converts
//!     io/parse/etc. errors, mirroring the real crate's blanket conversion
//!
//! Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` (that would make the blanket `From` impl overlap
//! with the identity conversion). `{:#}` formatting appends the source
//! chain; `{:?}` prints a "Caused by:" report.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error, keeping it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend higher-level context, demoting self's message into the chain.
    pub fn context<M: fmt::Display>(self, message: M) -> Self {
        Error { msg: format!("{message}: {}", self.msg), source: self.source }
    }

    /// Root-most error in the chain (self's message when there is none).
    pub fn root_cause(&self) -> String {
        let mut cur: Option<&(dyn StdError + 'static)> = match &self.source {
            Some(b) => Some(&**b),
            None => None,
        };
        let mut last = self.msg.clone();
        while let Some(s) = cur {
            last = s.to_string();
            cur = s.source();
        }
        last
    }

    fn chain_below_top(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> + '_ {
        // `msg` already renders the boxed source itself; the interesting
        // remainder of the chain starts at its own source.
        let mut cur: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().and_then(|s| s.source());
        std::iter::from_fn(move || {
            let item = cur?;
            cur = item.source();
            Some(item)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            for s in self.chain_below_top() {
                write!(f, ": {s}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut first = true;
        for s in self.chain_below_top() {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {s}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Create an [`Error`] from format arguments: `anyhow!("bad value {v}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an error: `bail!("no decode bucket {b}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("condition failed: `", ::std::stringify!($cond), "`"),
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    fn guarded(x: usize) -> Result<usize> {
        ensure!(x < 10, "x too big: {x}");
        Ok(x)
    }

    fn bails() -> Result<()> {
        bail!("nope: {}", 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("gone"), "{e}");
    }

    #[test]
    fn macros_format() {
        assert_eq!(guarded(3).unwrap(), 3);
        assert_eq!(guarded(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(bails().unwrap_err().to_string(), "nope: 7");
        let e: Error = anyhow!("v={}", 1);
        assert_eq!(format!("{e}"), "v=1");
        assert_eq!(format!("{e:#}"), "v=1");
    }

    #[test]
    fn context_prepends() {
        let e = anyhow!("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
        assert_eq!(e.root_cause(), "outer: inner");
    }
}
