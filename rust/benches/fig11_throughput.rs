//! Fig. 11 — end-to-end throughput: ForkKV vs prefix caching across three
//! models, three datasets, and both workflow paradigms (8 workflows,
//! 2 req/s, distinct adapters per agent — paper §7.1 scaled per DESIGN.md).

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec, WorkflowKind, DATASETS};

fn run(model: &str, dataset: &str, kind: WorkflowKind, policy: CachePolicy) -> f64 {
    let spec = WorkloadSpec::paper(dataset, kind, 8, 32);
    let mut driver = WorkflowDriver::new(spec);
    // budget scales with the model's KV width so each model sees the same
    // relative contention (the paper sizes hardware per model similarly)
    let budget = match model {
        "qwen2.5-7b-sim" => 96,   // GQA 4:1 -> halved KV width
        "qwen2.5-14b-sim" => 420, // deeper + wider: 2.6x bytes/token
        _ => 160,
    };
    let mut engine = presets::paper_sim_engine(model, policy, budget, 16, 7).unwrap();
    engine.run_driver(&mut driver).unwrap();
    driver.throughput_tasks_per_s()
}

fn main() {
    println!("# Fig. 11: end-to-end throughput (tasks/s), 8 workflows, 2 req/s");
    println!(
        "{:<18} {:<13} {:<10} {:>10} {:>10} {:>9}",
        "model", "dataset", "workflow", "prefix", "forkkv", "speedup"
    );
    for model in ["llama3-8b-sim", "qwen2.5-7b-sim", "qwen2.5-14b-sim"] {
        for dataset in DATASETS {
            for kind in [
                WorkflowKind::ReAct { n_agents: 4 },
                WorkflowKind::MapReduce { n_mappers: 6 },
            ] {
                let unified = run(model, dataset, kind, CachePolicy::UnifiedPerAdapter);
                let fork = run(model, dataset, kind, CachePolicy::Disaggregated);
                println!(
                    "{:<18} {:<13} {:<10} {:>10.2} {:>10.2} {:>8.2}x",
                    model,
                    dataset,
                    kind.name(),
                    unified,
                    fork,
                    fork / unified
                );
            }
        }
    }
    println!("# paper: 1.25-3.04x (ReAct), 1.68-2.60x (MapReduce); largest gains under");
    println!("# highest memory contention (bigger model / longer contexts)");
}
