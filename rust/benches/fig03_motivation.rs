//! Fig. 3 — motivation: prefix-caching throughput collapses as the number
//! of concurrent workflows scales (every agent in every workflow has a
//! distinct adapter, so nothing is shareable under per-adapter caching).

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec, WorkflowKind};

fn tps(kind: WorkflowKind, n_wf: usize) -> f64 {
    let n_requests = (n_wf * 5).max(16);
    let spec = WorkloadSpec::paper("loogle", kind, n_wf, n_requests);
    let mut driver = WorkflowDriver::new(spec);
    let mut engine = presets::paper_sim_engine(
        "llama3-8b-sim",
        CachePolicy::UnifiedPerAdapter,
        160,
        16,
        3,
    )
    .unwrap();
    engine.run_driver(&mut driver).unwrap();
    driver.throughput_tasks_per_s()
}

fn main() {
    println!("# Fig. 3: prefix-caching throughput vs concurrent workflows (motivation)");
    println!("{:>10} {:>14} {:>14} {:>10} {:>10}", "workflows", "react t/s", "mapred t/s", "react drop", "mr drop");
    let mut base = (0.0, 0.0);
    for (i, &n) in [1usize, 2, 4, 8].iter().enumerate() {
        let react = tps(WorkflowKind::ReAct { n_agents: 4 }, n);
        let mr = tps(WorkflowKind::MapReduce { n_mappers: 6 }, n);
        if i == 0 {
            base = (react, mr);
        }
        println!(
            "{:>10} {:>14.2} {:>14.2} {:>9.1}% {:>9.1}%",
            n,
            react,
            mr,
            (1.0 - react / base.0) * 100.0,
            (1.0 - mr / base.1) * 100.0
        );
    }
    println!("# paper: ReAct drops 90.8%, MapReduce 90.1% from 1 -> 8 workflows");
}
