//! Fig. 13 — throughput vs request arrival rate (LooGLE, llama3-8b-sim).
//! At low rates both systems keep up (throughput == offered load); as the
//! rate scales past the baseline's capacity, prefix caching saturates while
//! ForkKV keeps absorbing load.

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec};

fn run(rate: f64, policy: CachePolicy) -> f64 {
    let mut spec = WorkloadSpec::paper_react4("loogle", 8, 32);
    spec.arrival_rate = rate;
    let mut driver = WorkflowDriver::new(spec);
    let mut engine = presets::paper_sim_engine("llama3-8b-sim", policy, 160, 16, 13).unwrap();
    engine.run_driver(&mut driver).unwrap();
    driver.throughput_tasks_per_s()
}

fn main() {
    println!("# Fig. 13: throughput vs arrival rate (8 workflows, LooGLE)");
    println!(
        "{:>12} {:>12} {:>12} {:>9}",
        "rate(req/s)", "prefix t/s", "forkkv t/s", "speedup"
    );
    for &rate in &[0.5f64, 1.0, 2.0, 4.0, 8.0] {
        let u = run(rate, CachePolicy::UnifiedPerAdapter);
        let f = run(rate, CachePolicy::Disaggregated);
        println!("{:>12.1} {:>12.2} {:>12.2} {:>8.2}x", rate, u, f, f / u);
    }
    println!("# paper: ~2.52x / ~2.05x over baselines in steady state");
}
