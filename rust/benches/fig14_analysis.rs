//! Fig. 14 — why ForkKV wins: (a) per-agent memory footprint,
//! (b) cache hit rate, (c) average decode batch size, measured on the
//! Fig. 11 default configuration.

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec};

struct Row {
    mem_per_agent_mb: f64,
    peak_agents: f64,
    hit_rate: f64,
    partial_rate: f64,
    decode_batch: f64,
    preemptions: u64,
}

fn run(policy: CachePolicy) -> Row {
    let spec = WorkloadSpec::paper_react4("loogle", 8, 40);
    let mut driver = WorkflowDriver::new(spec);
    let mut engine = presets::paper_sim_engine("llama3-8b-sim", policy, 160, 16, 14).unwrap();
    engine.run_driver(&mut driver).unwrap();
    let m = &mut engine.metrics;
    Row {
        mem_per_agent_mb: m.bytes_per_agent.mean() / 1048576.0,
        peak_agents: m.active_seqs.max(),
        hit_rate: m.hit_rate(),
        partial_rate: m.hit_partial_tokens as f64 / m.prompt_tokens as f64,
        decode_batch: m.avg_decode_batch(),
        preemptions: m.preemptions,
    }
}

fn main() {
    println!("# Fig. 14: mechanism analysis (8 workflows, LooGLE, llama3-8b-sim)");
    let u = run(CachePolicy::UnifiedPerAdapter);
    let f = run(CachePolicy::Disaggregated);
    println!(
        "{:<26} {:>12} {:>12} {:>9}",
        "metric", "prefix", "forkkv", "ratio"
    );
    println!(
        "{:<26} {:>12.2} {:>12.2} {:>8.2}x",
        "(a) memory/agent (MB)",
        u.mem_per_agent_mb,
        f.mem_per_agent_mb,
        u.mem_per_agent_mb / f.mem_per_agent_mb
    );
    println!(
        "{:<26} {:>12.0} {:>12.0} {:>8.2}x",
        "    peak concurrent agents",
        u.peak_agents,
        f.peak_agents,
        f.peak_agents / u.peak_agents.max(1.0)
    );
    println!(
        "{:<26} {:>12.3} {:>12.3} {:>8.2}x",
        "(b) cache hit rate",
        u.hit_rate,
        f.hit_rate,
        f.hit_rate / u.hit_rate.max(1e-9)
    );
    println!(
        "{:<26} {:>12.3} {:>12.3} {:>9}",
        "    (+partial hits)", u.partial_rate, f.partial_rate, "-"
    );
    println!(
        "{:<26} {:>12.2} {:>12.2} {:>8.2}x",
        "(c) avg decode batch",
        u.decode_batch,
        f.decode_batch,
        f.decode_batch / u.decode_batch.max(1e-9)
    );
    println!(
        "{:<26} {:>12} {:>12} {:>9}",
        "    preemptions", u.preemptions, f.preemptions, "-"
    );
    println!("# paper: 12.7x lower memory/agent, 6.93x hit rate, 12.0x batch size");
}
