//! L3 hot-path microbenchmarks (the §Perf targets in DESIGN.md §8):
//! radix match, fork+release cycle, pool alloc/free, page scatter/gather
//! bandwidth, JSON parse. Used by the performance pass to find and verify
//! coordinator-side bottlenecks.

use std::time::Instant;

use forkkv::batch::{scatter_chunk, SeqSlab, SlabSpec};
use forkkv::kvcache::{BlockPool, PoolSpec};
use forkkv::radix::RadixTree;
use forkkv::util::json;
use forkkv::util::rng::Rng;

fn timeit<F: FnMut()>(name: &str, iters: usize, unit_work: f64, unit: &str, mut f: F) {
    // warmup
    f();
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    let secs = t.elapsed().as_secs_f64();
    let per = secs / iters as f64;
    println!(
        "{:<34} {:>10.1} us/op {:>14.1} {}/s",
        name,
        per * 1e6,
        unit_work / per,
        unit
    );
}

fn main() {
    println!("# L3 microbenchmarks");

    // ---- radix match over a long cached context ----
    let pt = 16;
    let ctx_tokens = 4096;
    let mut pool = BlockPool::new(PoolSpec {
        n_pages: 2 * ctx_tokens / pt,
        page_tokens: pt,
        n_layers: 4,
        width: 128,
    });
    let mut tree = RadixTree::new(pt);
    let tokens = Rng::seeded(1).tokens(ctx_tokens, 2048);
    let pages: Vec<_> = (0..ctx_tokens / pt).map(|_| pool.alloc().unwrap()).collect();
    tree.insert(0, &tokens, &pages, &mut pool);
    for p in pages {
        pool.release(p);
    }
    timeit("radix match+lease+release 4K ctx", 2000, ctx_tokens as f64, "tok", || {
        let m = tree.match_lease(0, &tokens, &mut pool);
        tree.release_path(&m.path);
        for p in &m.pages {
            pool.release(*p);
        }
    });

    // ---- pool alloc/release cycle ----
    let mut pool2 = BlockPool::new(PoolSpec {
        n_pages: 4096,
        page_tokens: pt,
        n_layers: 4,
        width: 128,
    });
    timeit("pool alloc+release x256", 2000, 256.0, "page", || {
        let pages: Vec<_> = (0..256).map(|_| pool2.alloc().unwrap()).collect();
        for p in pages {
            pool2.release(p);
        }
    });

    // ---- scatter (chunk -> pages) + gather (pages -> slab) bandwidth ----
    let layers = 4;
    let width = 128;
    let chunk = 64;
    let mut pool3 = BlockPool::new(PoolSpec {
        n_pages: 64,
        page_tokens: pt,
        n_layers: layers,
        width,
    });
    let pages: Vec<_> = (0..chunk / pt).map(|_| pool3.alloc().unwrap()).collect();
    let k = vec![1.0f32; layers * chunk * width];
    let v = vec![2.0f32; layers * chunk * width];
    let bytes = (2 * layers * chunk * width * 4) as f64;
    timeit("scatter 64-token chunk", 5000, bytes / 1e9, "GB", || {
        scatter_chunk(&mut pool3, &pages, 0, chunk, chunk, width, &k, &v);
    });

    let mut slab = SeqSlab::new(SlabSpec {
        n_layers: layers,
        s_max: 768,
        base_width: width,
        res_width: 32,
    });
    timeit("gather 64 tokens into slab", 5000, bytes / 1e9, "GB", || {
        slab.load_base_pages(&pool3, &pages, chunk);
    });

    // ---- batched slab stacking (decode-step assembly) ----
    let row = vec![0.5f32; layers * 768 * width];
    let rows: Vec<&[f32]> = (0..8).map(|_| row.as_slice()).collect();
    let mut out = Vec::new();
    let stack_bytes = (8 * row.len() * 4) as f64;
    timeit("stack 8 decode slabs (1 thread)", 200, stack_bytes / 1e9, "GB", || {
        forkkv::batch::stack_slabs(rows.iter().copied(), row.len(), 8, &mut out);
    });
    // the engine's parallel assembly (4 tensors on scoped threads)
    let row_r = vec![0.25f32; 4 * 768 * 32];
    let rows_r: Vec<&[f32]> = (0..8).map(|_| row_r.as_slice()).collect();
    let (mut o1, mut o2, mut o3, mut o4) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    timeit("stack 4x8 slabs (scoped threads)", 200, 2.0 * stack_bytes / 1e9, "GB", || {
        std::thread::scope(|s| {
            s.spawn(|| forkkv::batch::stack_slabs(rows.iter().copied(), row.len(), 8, &mut o1));
            s.spawn(|| forkkv::batch::stack_slabs(rows.iter().copied(), row.len(), 8, &mut o2));
            s.spawn(|| forkkv::batch::stack_slabs(rows_r.iter().copied(), row_r.len(), 8, &mut o3));
            s.spawn(|| forkkv::batch::stack_slabs(rows_r.iter().copied(), row_r.len(), 8, &mut o4));
        });
    });

    // ---- json parse (manifest-sized) ----
    let manifest = std::fs::read_to_string("artifacts/llama3-8b-sim/manifest.json").ok();
    if let Some(text) = manifest {
        let bytes = text.len() as f64;
        timeit("parse manifest.json", 500, bytes / 1e6, "MB", || {
            let _ = json::parse(&text).unwrap();
        });
    }
}
