//! Fig. 1 — context memory vs number of concurrent agents on one shared
//! context (paper: 32K tokens, Llama3-8B, r=16; here paper-scale /10).
//!
//! N agents with distinct adapters all hold the same static context. We
//! measure the engine's actual pool usage at peak for the unified layout
//! vs the disaggregated layout, plus the Eq. 3 analytic curve.

use forkkv::config::CachePolicy;
use forkkv::engine::{Request, Tick};
use forkkv::util::rng::Rng;
use forkkv::workload::{presets, PAPER_S_MAX};

fn peak_bytes(policy: CachePolicy, n_agents: usize, ctx: &[u32]) -> (usize, usize) {
    // generous budget: this experiment measures footprint, not contention
    let mut e = presets::paper_sim_engine("llama3-8b-sim", policy, 4096, 16, 1).unwrap();
    for i in 0..n_agents {
        let mut tokens = ctx.to_vec();
        tokens.push(3000 + i as u32); // distinct final token per agent
        e.submit(Request {
            id: i as u64,
            tag: 0,
            adapter: i as u32,
            tokens,
            max_new: 8,
            arrival_us: 0,
            ignore_eos: true,
            fan: 0,
        });
    }
    let mut peak_base = 0usize;
    let mut peak_res = 0usize;
    for _ in 0..2_000_000 {
        match e.tick().unwrap() {
            Tick::Progress => {
                peak_base = peak_base.max(e.base_pool().used_bytes());
                peak_res = peak_res.max(e.res_pool().map_or(0, |p| p.used_bytes()));
            }
            Tick::Idle => break,
        }
    }
    (peak_base, peak_res)
}

fn main() {
    let ctx = Rng::seeded(11).tokens(3264, 2048);
    println!("# Fig. 1: context memory vs concurrent agents (shared 3.3K-token context, r/n = 1/64)");
    println!(
        "{:>7} {:>14} {:>16} {:>10} {:>12} {:>10}",
        "agents", "unified(MB)", "forkkv(MB)", "saving", "eq3 M_R", "meas M_R"
    );
    let mut max_saving = 0.0f64;
    for &n in &[1usize, 2, 4, 8, 16, 32] {
        let (u_base, _) = peak_bytes(CachePolicy::UnifiedPerAdapter, n, &ctx);
        let (f_base, f_res) = peak_bytes(CachePolicy::Disaggregated, n, &ctx);
        let unified = u_base as f64 / 1048576.0;
        let fork = (f_base + f_res) as f64 / 1048576.0;
        // Eq. 3: M_R = 1/N + r/n  (r_eff = 2, n = 128 at sim geometry)
        let eq3 = 1.0 / n as f64 + 2.0 / 128.0;
        let meas = fork / unified;
        max_saving = max_saving.max(unified / fork);
        println!(
            "{:>7} {:>14.1} {:>16.1} {:>9.1}x {:>12.3} {:>10.3}",
            n, unified, fork, unified / fork, eq3, meas
        );
    }
    println!("# paper: memory grows linearly with agents for prefix caching; ForkKV");
    println!("# stays near one shared copy (32x more agents in 8GB). max saving here: {max_saving:.1}x");
    let _ = PAPER_S_MAX;
}
