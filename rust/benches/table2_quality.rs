//! Table 2 / Fig. 5a — generation quality under the three sharing policies,
//! measured through the REAL PJRT artifacts (no simulation).
//!
//! With untrained sim weights, absolute F1 is meaningless; what Table 2
//! establishes is the *ordering* "ForkKV ≈ lossless prefix caching ≫ full
//! reuse". We therefore measure generation fidelity against the lossless
//! prefix-caching run of the identical workload (DESIGN.md §3):
//!   - greedy token agreement rate (exact-match fraction of generated ids)
//!   - cosine similarity of the first generated token's logits
//!
//! Skips gracefully when `make artifacts` has not run.

use std::path::Path;

use forkkv::config::{CacheConfig, CachePolicy, EngineConfig};
use forkkv::engine::{Engine, Request, Tick};
use forkkv::exec::PjrtExecutor;
use forkkv::metrics::FinishedRequest;
use forkkv::util::rng::Rng;
use forkkv::workload::dataset;

fn run_policy(
    dir: &Path,
    policy: CachePolicy,
    ds: &str,
    n_requests: usize,
) -> anyhow::Result<Vec<FinishedRequest>> {
    let exec = PjrtExecutor::load(dir)?;
    let cfg = EngineConfig {
        policy,
        cache: CacheConfig { page_tokens: 16, budget_bytes: 256 << 20, capacity_bytes: 0 },
        seed: 5,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg, Box::new(exec))?;
    engine.collect_first_logits = true;

    let d = dataset(ds)?;
    let shared = Rng::seeded(100).tokens(d.static_len, 2048);
    for i in 0..n_requests {
        let mut tokens = shared.clone();
        let mut r = Rng::seeded(200 + i as u64);
        tokens.extend(r.tokens(d.dynamic_len, 2048));
        engine.submit(Request {
            id: i as u64,
            tag: 0,
            // cycle 3 adapters so later requests fork caches created by
            // *different* adapters — the case the policies disagree on
            adapter: (i % 3) as u32,
            tokens,
            max_new: 16,
            arrival_us: i as u64, // strictly sequential admission order
            ignore_eos: true,
            fan: 0,
        });
    }
    let mut fin = Vec::new();
    while fin.len() < n_requests {
        match engine.tick()? {
            Tick::Progress => fin.extend(engine.drain_finished()),
            Tick::Idle => break,
        }
    }
    fin.sort_by_key(|f| f.id);
    Ok(fin)
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    if !cfg!(feature = "pjrt") {
        println!("# table2_quality: skipped (build with --features pjrt for real PJRT execution)");
        return Ok(());
    }
    let models = ["llama3-8b-sim", "qwen2.5-7b-sim", "qwen2.5-14b-sim"];
    let available: Vec<&str> = models
        .iter()
        .copied()
        .filter(|m| Path::new("artifacts").join(m).join("manifest.json").exists())
        .collect();
    if available.is_empty() {
        println!("# table2_quality: skipped (run `make artifacts` first)");
        return Ok(());
    }
    println!("# Table 2 / Fig. 5a: generation fidelity vs lossless prefix caching");
    println!("# (token agreement %, first-token logits cosine; real PJRT execution)");
    println!(
        "{:<18} {:<10} {:<12} {:>10} {:>12}",
        "model", "dataset", "policy", "agree(%)", "logit-cos"
    );
    let n_requests = 6;
    for model in available {
        let dir = Path::new("artifacts").join(model);
        for ds in ["hotpotqa", "apigen"] {
            let reference = run_policy(&dir, CachePolicy::UnifiedPerAdapter, ds, n_requests)?;
            for policy in [
                CachePolicy::UnifiedPerAdapter,
                CachePolicy::Disaggregated,
                CachePolicy::FullReuse,
            ] {
                let got = if policy == CachePolicy::UnifiedPerAdapter {
                    reference.clone()
                } else {
                    run_policy(&dir, policy, ds, n_requests)?
                };
                let mut agree = 0usize;
                let mut total = 0usize;
                let mut cos_sum = 0.0;
                let mut cos_n = 0usize;
                for (r, g) in reference.iter().zip(got.iter()) {
                    assert_eq!(r.id, g.id);
                    for (a, b) in r.generated.iter().zip(g.generated.iter()) {
                        total += 1;
                        agree += usize::from(a == b);
                    }
                    if let (Some(la), Some(lb)) = (&r.first_logits, &g.first_logits) {
                        cos_sum += cosine(la, lb);
                        cos_n += 1;
                    }
                }
                println!(
                    "{:<18} {:<10} {:<12} {:>10.1} {:>12.4}",
                    model,
                    ds,
                    policy.name(),
                    100.0 * agree as f64 / total.max(1) as f64,
                    cos_sum / cos_n.max(1) as f64
                );
            }
        }
        if std::env::var_os("FORKKV_ALL_MODELS").is_none() {
            println!("# (set FORKKV_ALL_MODELS=1 to evaluate the remaining models)");
            break;
        }
    }
    println!("# paper Table 2: ForkKV within 0.71 F1 points of prefix caching on");
    println!("# average; full reuse drops 5.40 points (21.95 worst case on APIGen)");
    Ok(())
}
