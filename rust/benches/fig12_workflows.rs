//! Fig. 12 — throughput vs number of concurrent workflows (Llama3-8B-sim,
//! LooGLE). The paper's signature shape: prefix caching is competitive (or
//! slightly ahead) while memory is abundant, then collapses as workflows
//! scale; ForkKV degrades gracefully.

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec};

fn run(n_wf: usize, policy: CachePolicy) -> (f64, f64) {
    let spec = WorkloadSpec::paper_react4("loogle", n_wf, (n_wf * 5).max(16));
    let mut driver = WorkflowDriver::new(spec);
    let mut engine = presets::paper_sim_engine("llama3-8b-sim", policy, 160, 16, 12).unwrap();
    engine.run_driver(&mut driver).unwrap();
    (driver.throughput_tasks_per_s(), engine.metrics.hit_rate())
}

fn main() {
    println!("# Fig. 12: throughput vs concurrent workflows (LooGLE, llama3-8b-sim, 160MB)");
    println!(
        "{:>10} {:>12} {:>12} {:>9} {:>10} {:>10}",
        "workflows", "prefix t/s", "forkkv t/s", "speedup", "hit(pfx)", "hit(fork)"
    );
    for &n in &[2usize, 4, 6, 8, 12, 16] {
        let (u_tps, u_hit) = run(n, CachePolicy::UnifiedPerAdapter);
        let (f_tps, f_hit) = run(n, CachePolicy::Disaggregated);
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>8.2}x {:>10.2} {:>10.2}",
            n,
            u_tps,
            f_tps,
            f_tps / u_tps,
            u_hit,
            f_hit
        );
    }
    println!("# paper: baselines ahead at 4 workflows (abundant memory), ForkKV");
    println!("# 1.84-2.33x ahead under contention");
}
