//! Fig. 15 — sensitivity of ForkKV's advantage to (a) LoRA rank and
//! (b) agent output length (ReAct, Llama3-8B-sim, LooGLE).

use forkkv::config::CachePolicy;
use forkkv::workload::{presets, WorkflowDriver, WorkloadSpec};

fn run(policy: CachePolicy, paper_rank: usize, output_len: usize) -> f64 {
    let mut spec = WorkloadSpec::paper_react4("loogle", 8, 32);
    spec.output_len = output_len;
    let mut driver = WorkflowDriver::new(spec);
    let mut engine =
        presets::paper_sim_engine("llama3-8b-sim", policy, 160, paper_rank, 15).unwrap();
    engine.run_driver(&mut driver).unwrap();
    driver.throughput_tasks_per_s()
}

fn main() {
    println!("# Fig. 15a: varying LoRA rank (ReAct, LooGLE)");
    println!("{:>6} {:>12} {:>12} {:>9}", "rank", "prefix t/s", "forkkv t/s", "speedup");
    for &rank in &[8usize, 16, 32] {
        let u = run(CachePolicy::UnifiedPerAdapter, rank, 256);
        let f = run(CachePolicy::Disaggregated, rank, 256);
        println!("{:>6} {:>12.2} {:>12.2} {:>8.2}x", rank, u, f, f / u);
    }
    println!("# paper: 2.36-2.88x; absolute ForkKV throughput decreases with rank");
    println!("# (larger rCache per agent)");
    println!();
    println!("# Fig. 15b: varying output length (ReAct, LooGLE, r=16)");
    println!("{:>8} {:>12} {:>12} {:>9}", "out_len", "prefix t/s", "forkkv t/s", "speedup");
    for &out in &[128usize, 256, 512] {
        let u = run(CachePolicy::UnifiedPerAdapter, 16, out);
        let f = run(CachePolicy::Disaggregated, 16, out);
        println!("{:>8} {:>12.2} {:>12.2} {:>8.2}x", out, u, f, f / u);
    }
    println!("# paper: 2.69-3.36x across output lengths");
}
