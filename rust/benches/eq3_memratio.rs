//! Eq. 3 — M_R = Mem_disagg / Mem_unified = 1/N + r/n, validated against
//! the allocator's actual page accounting across N, r, and model geometry.

use forkkv::kvcache::{pages_for, BlockPool, PoolSpec};
use forkkv::radix::DualRadixTree;
use forkkv::util::rng::Rng;

/// Simulate N agents sharing one `ctx_tokens` context directly at the
/// pool/tree layer (no scheduler noise): one shared base insert + N
/// residual inserts vs N unified inserts.
fn measure(n_agents: usize, ctx_tokens: usize, n: usize, r: usize) -> (usize, usize) {
    let pt = 16;
    let pages = pages_for(ctx_tokens, pt);
    let layers = 4;
    let mk = |width: usize| {
        BlockPool::new(PoolSpec {
            n_pages: pages * (n_agents + 1),
            page_tokens: pt,
            n_layers: layers,
            width,
        })
    };
    let tokens = Rng::seeded(7).tokens(ctx_tokens, 2048);

    // ---- unified: one full-width copy per agent ----
    let mut unified_pool = mk(n);
    let mut unified = DualRadixTree::new(pt);
    for a in 0..n_agents as u32 {
        let ps: Vec<_> = (0..pages).map(|_| unified_pool.alloc().unwrap()).collect();
        unified.base.insert(1 + a, &tokens, &ps, &mut unified_pool);
        for p in ps {
            unified_pool.release(p);
        }
    }
    let unified_bytes = unified_pool.used_bytes();

    // ---- disaggregated: one shared base + N residuals ----
    let mut base_pool = mk(n);
    let mut res_pool = mk(r);
    let mut dual = DualRadixTree::new(pt);
    for a in 0..n_agents as u32 {
        // base insert is deduped after the first agent (zero-copy sharing)
        let ps: Vec<_> = (0..pages).map(|_| base_pool.alloc().unwrap()).collect();
        dual.base.insert(0, &tokens, &ps, &mut base_pool);
        for p in ps {
            base_pool.release(p);
        }
        let rs: Vec<_> = (0..pages).map(|_| res_pool.alloc().unwrap()).collect();
        dual.residual.insert(a, &tokens, &rs, &mut res_pool);
        for p in rs {
            res_pool.release(p);
        }
    }
    let disagg_bytes = base_pool.used_bytes() + res_pool.used_bytes();
    (unified_bytes, disagg_bytes)
}

fn main() {
    println!("# Eq. 3: M_R = 1/N + r/n (allocator-level validation)");
    println!(
        "{:>7} {:>5} {:>6} {:>12} {:>12} {:>10} {:>10}",
        "agents", "r", "n", "unified(MB)", "disagg(MB)", "analytic", "measured"
    );
    for &(n_agents, r, n) in &[
        (1usize, 16usize, 128usize),
        (2, 16, 128),
        (4, 16, 128),
        (8, 16, 128),
        (16, 16, 128),
        (32, 16, 128),
        (16, 8, 128),
        (16, 32, 128),
        (16, 16, 192), // qwen2.5-14b-sim geometry
    ] {
        let (u, d) = measure(n_agents, 3264, n, r);
        let analytic = 1.0 / n_agents as f64 + r as f64 / n as f64;
        let measured = d as f64 / u as f64;
        println!(
            "{:>7} {:>5} {:>6} {:>12.2} {:>12.2} {:>10.4} {:>10.4}",
            n_agents,
            r,
            n,
            u as f64 / 1048576.0,
            d as f64 / 1048576.0,
            analytic,
            measured
        );
        assert!(
            (measured - analytic).abs() < 0.02,
            "allocator disagrees with Eq. 3"
        );
    }
    println!("# asymptote r/n as N grows; paper's example: 11.8x at N=16, r=16, n=1024");
}
