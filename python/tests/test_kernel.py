"""L1 correctness: Pallas ResidualAttention vs the pure-jnp oracle.

hypothesis sweeps shapes (seq lens, q lens, GQA ratios, ranks), block sizes
and dtypes; every property asserts allclose against `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    apply_rope,
    reconstruct_k,
    reconstruct_v,
    residual_attention_ref,
    rope_tables,
    unified_attention_ref,
)
from compile.kernels.residual_attention import residual_attention

jax.config.update("jax_platform_name", "cpu")


def make_inputs(seed, m, h, kh, hd, s, r, dtype=jnp.float32, pos_offset=None):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    q = jax.random.normal(ks[0], (m, h, hd), dtype)
    kb = jax.random.normal(ks[1], (s, kh, hd), dtype)
    vb = jax.random.normal(ks[2], (s, kh, hd), dtype)
    kr = (jax.random.normal(ks[3], (s, r), jnp.float32) * 0.3).astype(dtype)
    vr = (jax.random.normal(ks[4], (s, r), jnp.float32) * 0.3).astype(dtype)
    bk = (jax.random.normal(ks[5], (r, kh, hd), jnp.float32) * 0.1).astype(dtype)
    bv = (jax.random.normal(ks[6], (r, kh, hd), jnp.float32) * 0.1).astype(dtype)
    if pos_offset is None:
        pos_offset = s - m
    qpos = (pos_offset + jnp.arange(m)).astype(jnp.int32)
    sin, cos = rope_tables(s, hd, dtype=dtype)
    return q, kb, vb, kr, vr, bk, bv, qpos, sin, cos


def run_both(args, block_q=64, block_k=64, atol=3e-5):
    ref = residual_attention_ref(*args)
    out = residual_attention(*args, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=atol, rtol=atol
    )


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([1, 2, 5, 17, 64]),
    heads=st.sampled_from([(4, 4), (8, 4), (8, 2), (12, 6), (8, 1)]),
    s_blocks=st.integers(1, 6),
    r=st.sampled_from([4, 8, 16, 32]),
)
def test_kernel_matches_ref_shapes(seed, m, heads, s_blocks, r):
    h, kh = heads
    hd = 32
    s = 64 * s_blocks
    if m > s:
        m = s
    args = make_inputs(seed, m, h, kh, hd, s, r)
    run_both(args)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    block_q=st.sampled_from([2, 4, 16, 64]),
    block_k=st.sampled_from([32, 64, 128]),
)
def test_kernel_block_size_invariance(seed, block_q, block_k):
    args = make_inputs(seed, m=33, h=8, kh=4, hd=32, s=384, r=16)
    run_both(args, block_q=block_q, block_k=block_k)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), hd=st.sampled_from([16, 32, 64]))
def test_kernel_head_dims(seed, hd):
    args = make_inputs(seed, m=7, h=4, kh=2, hd=hd, s=128, r=8)
    run_both(args)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_bf16_inputs(seed):
    """bf16 inputs accumulate in f32 inside the kernel; loose tolerance."""
    args = make_inputs(seed, m=9, h=4, kh=2, hd=32, s=128, r=8, dtype=jnp.bfloat16)
    ref = residual_attention_ref(*args).astype(jnp.float32)
    out = residual_attention(*args).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# algebraic properties of the decomposition
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rope_linearity(seed):
    """RoPE(a + b) == RoPE(a) + RoPE(b): the reconstruction identity that
    makes splitting K into bCache + rCache exact (DESIGN.md §1)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    s, hd = 64, 32
    a = jax.random.normal(k1, (s, hd))
    b = jax.random.normal(k2, (s, hd))
    sin, cos = rope_tables(s, hd)
    lhs = apply_rope(a + b, sin, cos)
    rhs = apply_rope(a, sin, cos) + apply_rope(b, sin, cos)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), r=st.sampled_from([1, 8, 32]))
def test_disaggregated_equals_unified(seed, r):
    """Attention over (bCache, rCache) == standard attention over the merged
    cache reconstructed in HBM — the end-to-end statement of paper Eq. 2+4."""
    m, h, kh, hd, s = 6, 8, 4, 32, 128
    args = make_inputs(seed, m, h, kh, hd, s, r)
    q, kb, vb, kr, vr, bk, bv, qpos, sin, cos = args
    k_merged = reconstruct_k(kb, kr, bk, sin, cos)
    v_merged = reconstruct_v(vb, vr, bv)
    unified = unified_attention_ref(q, k_merged, v_merged, qpos)
    fused = residual_attention(*args)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(unified), atol=3e-5, rtol=3e-5
    )


def test_zero_residual_reduces_to_base_attention():
    """rCache == 0  =>  ResidualAttention == plain attention over bCache.
    This is exactly how the Rust engine runs the unified baselines through
    the same artifact (DESIGN.md §6)."""
    args = make_inputs(3, m=5, h=8, kh=4, hd=32, s=128, r=16)
    q, kb, vb, kr, vr, bk, bv, qpos, sin, cos = args
    zr = jnp.zeros_like(kr)
    out = residual_attention(q, kb, vb, zr, zr, bk, bv, qpos, sin, cos)
    base = unified_attention_ref(q, kb, vb, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=3e-5, rtol=3e-5)


def test_causal_mask_strict():
    """Changing keys strictly in the future of all queries must not change
    the output (mask correctness under padded caches)."""
    args = make_inputs(7, m=4, h=4, kh=2, hd=32, s=128, r=8, pos_offset=50)
    q, kb, vb, kr, vr, bk, bv, qpos, sin, cos = args
    out1 = residual_attention(q, kb, vb, kr, vr, bk, bv, qpos, sin, cos)
    # scribble over future slots (> max qpos = 53)
    kb2 = kb.at[60:].set(1e4)
    vb2 = vb.at[60:].set(-1e4)
    kr2 = kr.at[60:].set(1e4)
    vr2 = vr.at[60:].set(1e4)
    out2 = residual_attention(q, kb2, vb2, kr2, vr2, bk, bv, qpos, sin, cos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_self_attention_slot_visible():
    """A query at position p must see slot p (its own freshly-written K/V)."""
    m, h, kh, hd, s, r = 1, 2, 1, 32, 64, 4
    args = make_inputs(11, m, h, kh, hd, s, r, pos_offset=0)
    q, kb, vb, kr, vr, bk, bv, qpos, sin, cos = args
    # only slot 0 is visible; output must equal V[0] reconstructed
    out = residual_attention(q, kb, vb, kr, vr, bk, bv, qpos, sin, cos, block_k=64)
    v_merged = reconstruct_v(vb, vr, bv)
    expect = jnp.repeat(v_merged[:1], h // kh, axis=1)[0]
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect), atol=3e-5, rtol=3e-5)


def test_rejects_unaligned_seq():
    args = make_inputs(0, m=2, h=4, kh=2, hd=32, s=100, r=8)
    with pytest.raises(ValueError):
        residual_attention(*args, block_k=64)
