"""L2 model invariants: chunked==full prefill, adapter gating, merged-cache
reconstruction, GQA/bias variants, decode vmap consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import MODELS

jax.config.update("jax_platform_name", "cpu")


def tiny(name="llama3-8b-sim", **kw):
    base = dict(n_layers=2, s_max=128, chunk=8, vocab=256, d_model=64,
                d_ff=128, n_heads=4, n_kv_heads=2)
    base.update(kw)
    return dataclasses.replace(MODELS[name], **base)


def zero_caches(cfg):
    L, S, KH, HD, R = (cfg.n_layers, cfg.s_max, cfg.n_kv_heads,
                       cfg.head_dim, cfg.rank_max)
    return (jnp.zeros((L, S, KH, HD)), jnp.zeros((L, S, KH, HD)),
            jnp.zeros((L, S, R)), jnp.zeros((L, S, R)))


@pytest.fixture(scope="module")
def setup():
    cfg = tiny()
    params = M.init_params(cfg, 0)
    bank = M.init_bank(cfg, rank=8, seed=1)
    return cfg, params, bank


def run_chunk(cfg, params, bank, tokens, cache_len, caches, adapter=2, on=1.0):
    return M.forward_chunk(
        cfg, params, bank, tokens, jnp.int32(cache_len), jnp.int32(adapter),
        jnp.float32(on), *caches,
    )


def write_chunk(caches, out, start, n):
    kb, vb, kr, vr = caches
    _, kbn, vbn, krn, vrn, _, _, _ = out
    for l in range(kb.shape[0]):
        kb = kb.at[l, start:start + n].set(kbn[l, :n])
        vb = vb.at[l, start:start + n].set(vbn[l, :n])
        kr = kr.at[l, start:start + n].set(krn[l, :n])
        vr = vr.at[l, start:start + n].set(vrn[l, :n])
    return kb, vb, kr, vr


def test_chunked_prefill_equals_monolithic(setup):
    cfg, params, bank = setup
    toks = (jnp.arange(16, dtype=jnp.int32) * 5 + 2) % cfg.vocab
    caches = zero_caches(cfg)
    # two chunks of 8
    out1 = run_chunk(cfg, params, bank, toks[:8], 0, caches)
    caches2 = write_chunk(caches, out1, 0, 8)
    out2 = run_chunk(cfg, params, bank, toks[8:], 8, caches2)
    # monolithic 16 (chunk fn accepts any C)
    outm = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg))
    np.testing.assert_allclose(
        np.asarray(out2[0]), np.asarray(outm[0][8:]), atol=2e-4, rtol=2e-4
    )


def test_adapter_off_equals_base_model(setup):
    cfg, params, bank = setup
    toks = jnp.arange(8, dtype=jnp.int32) + 3
    out_off = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg), on=0.0)
    # residuals must be exactly zero and merged == base
    assert float(jnp.abs(out_off[3]).max()) == 0.0  # kr
    assert float(jnp.abs(out_off[4]).max()) == 0.0  # vr
    np.testing.assert_allclose(np.asarray(out_off[1]), np.asarray(out_off[5]),
                               atol=1e-6)  # kb == km
    # different adapters with on=0 give identical logits
    out_off2 = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg),
                         adapter=7, on=0.0)
    np.testing.assert_allclose(np.asarray(out_off[0]), np.asarray(out_off2[0]),
                               atol=1e-6)


def test_adapters_differ(setup):
    cfg, params, bank = setup
    toks = jnp.arange(8, dtype=jnp.int32) + 3
    a = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg), adapter=1)
    b = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg), adapter=2)
    assert float(jnp.abs(a[0] - b[0]).max()) > 1e-4


def test_merged_equals_base_plus_residual(setup):
    """km == kb + RoPE(kr @ Bk): the Eq. 2 reconstruction the unified
    baselines persist."""
    from compile.kernels.ref import apply_rope, rope_tables
    cfg, params, bank = setup
    toks = jnp.arange(8, dtype=jnp.int32) + 5
    adapter = 3
    out = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg), adapter=adapter)
    _, kbn, vbn, krn, vrn, kmn, vmn, _ = out
    sin, cos = rope_tables(cfg.s_max, cfg.head_dim, cfg.rope_theta)
    C = 8
    for l in range(cfg.n_layers):
        bk = bank["bank.bk"][adapter, l].reshape(cfg.rank_max, cfg.n_kv_heads, cfg.head_dim)
        bv = bank["bank.bv"][adapter, l].reshape(cfg.rank_max, cfg.n_kv_heads, cfg.head_dim)
        k_lora = jnp.einsum("cr,rkh->ckh", krn[l], bk)
        k_lora = apply_rope(k_lora, sin[:C, None, :], cos[:C, None, :])
        np.testing.assert_allclose(np.asarray(kmn[l]), np.asarray(kbn[l] + k_lora),
                                   atol=2e-5, rtol=2e-5)
        v_lora = jnp.einsum("cr,rkh->ckh", vrn[l], bv)
        np.testing.assert_allclose(np.asarray(vmn[l]), np.asarray(vbn[l] + v_lora),
                                   atol=2e-5, rtol=2e-5)


def test_decode_matches_prefill_continuation(setup):
    cfg, params, bank = setup
    toks = jnp.arange(9, dtype=jnp.int32) + 2
    out = run_chunk(cfg, params, bank, toks[:8], 0, zero_caches(cfg))
    caches = write_chunk(zero_caches(cfg), out, 0, 8)
    dec = M.make_decode_fn(cfg, 2)
    pn = [params[n] for n, _ in M.param_specs(cfg)]
    bn = [bank[n] for n, _ in M.bank_specs(cfg)]
    kbB, vbB, krB, vrB = (jnp.stack([c, c]) for c in caches)
    res = dec(*pn, *bn,
              jnp.array([toks[8], 0], jnp.int32),
              jnp.array([8, 0], jnp.int32),
              jnp.array([2, 0], jnp.int32),
              jnp.array([1.0, 0.0], jnp.float32),
              kbB, vbB, krB, vrB)
    full = run_chunk(cfg, params, bank, toks, 0, zero_caches(cfg))
    np.testing.assert_allclose(np.asarray(res[0][0]), np.asarray(full[0][8]),
                               atol=2e-4, rtol=2e-4)


@settings(max_examples=6, deadline=None)
@given(name=st.sampled_from(["llama3-8b-sim", "qwen2.5-7b-sim", "qwen2.5-14b-sim"]),
       seed=st.integers(0, 1000))
def test_all_model_families_run(name, seed):
    """GQA ratios and qkv-bias variants all produce finite outputs."""
    cfg = tiny(name, n_heads=MODELS[name].n_heads,
               n_kv_heads=MODELS[name].n_kv_heads,
               d_model=MODELS[name].n_heads * 16)
    cfg = dataclasses.replace(cfg, head_dim=16)
    params = M.init_params(cfg, seed)
    bank = M.init_bank(cfg, rank=8, seed=seed + 1)
    toks = jnp.arange(8, dtype=jnp.int32) + 2
    out = M.forward_chunk(cfg, params, bank, toks, jnp.int32(0), jnp.int32(1),
                          jnp.float32(1.0), *zero_caches(cfg))
    assert np.isfinite(np.asarray(out[0])).all()


def test_param_specs_cover_init():
    for name in MODELS:
        cfg = MODELS[name]
        params = M.init_params(tiny(name), 0)
        assert set(params) == {n for n, _ in M.param_specs(tiny(name))}
