"""AOT contract tests: manifest schema, weights.bin offsets, HLO text
properties, golden self-consistency. These protect the cross-language
boundary the Rust runtime replays (rust/tests/runtime_golden.rs is the
other half)."""

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.configs import MODELS


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("aot")
    cfg = dataclasses.replace(
        MODELS["llama3-8b-sim"],
        n_layers=2, s_max=128, chunk=16, vocab=256, d_model=64, d_ff=128,
        n_heads=4, n_kv_heads=2, decode_batches=(1, 2),
    )
    manifest = aot.lower_model(cfg, rank=8, seed=0, lora_seed=1,
                               out_dir=str(out), verbose=False)
    return cfg, str(out / cfg.name), manifest


def test_manifest_schema(lowered):
    cfg, mdir, manifest = lowered
    j = json.load(open(os.path.join(mdir, "manifest.json")))
    assert j["model"]["name"] == cfg.name
    assert j["model"]["rank_effective"] == 8
    keys = {a["kind"] if a["kind"] == "prefill" else f'decode_b{a["batch"]}'
            for a in j["artifacts"]}
    assert keys == {"prefill", "decode_b1", "decode_b2"}
    for key in keys:
        assert key in j["runtime_inputs"]
        assert key in j["outputs"]


def test_weights_bin_offsets_round_trip(lowered):
    cfg, mdir, manifest = lowered
    j = json.load(open(os.path.join(mdir, "manifest.json")))
    raw = np.fromfile(os.path.join(mdir, "weights.bin"), dtype=np.float32)
    params = M.init_params(cfg, 0)
    bank = M.init_bank(cfg, rank=8, seed=1)
    total = 0
    for section, tree in (("params", params), ("bank", bank)):
        for entry in j[section]:
            arr = np.asarray(tree[entry["name"]], np.float32).reshape(-1)
            got = raw[entry["offset"]:entry["offset"] + arr.size]
            np.testing.assert_array_equal(got, arr, err_msg=entry["name"])
            total += arr.size
    assert total == raw.size, "weights.bin has no gaps or trailing data"


def test_hlo_text_is_parseable_hlo(lowered):
    _, mdir, _ = lowered
    text = open(os.path.join(mdir, "prefill.hlo.txt")).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the interchange must be text, never a serialized proto
    assert "\x00" not in text


def test_golden_self_consistency(lowered):
    cfg, mdir, _ = lowered
    g = json.load(open(os.path.join(mdir, "golden.json")))
    assert len(g["tokens"]) == cfg.chunk
    assert all(0 <= t < cfg.vocab for t in g["tokens"])
    assert 0 <= g["decode_argmax"] < cfg.vocab
    # replaying the golden recipe reproduces the recorded probes
    params = M.init_params(cfg, 0)
    bank = M.init_bank(cfg, rank=8, seed=1)
    g2 = aot.make_golden(cfg, params, bank)
    np.testing.assert_allclose(g["prefill_logits_last8"],
                               g2["prefill_logits_last8"], atol=1e-5)
    np.testing.assert_allclose(g["decode_logits8"], g2["decode_logits8"],
                               atol=1e-5)
    assert g["decode_argmax"] == g2["decode_argmax"]


def test_runtime_input_specs_match_model_shapes(lowered):
    cfg, _, _ = lowered
    for kind, batch in (("prefill", 1), ("decode", 2)):
        specs = M.runtime_input_specs(cfg, kind, batch)
        names = [n for n, _, _ in specs]
        if kind == "prefill":
            assert names == ["tokens", "cache_len", "adapter_id",
                             "adapter_on", "kb", "vb", "kr", "vr"]
        else:
            assert names[0] == "tokens"
            shapes = {n: s for n, s, _ in specs}
            assert shapes["kb"][0] == batch
            assert shapes["kr"][-1] == cfg.rank_max


def test_artifact_set_covers_decode_buckets(lowered):
    cfg, mdir, _ = lowered
    for b in cfg.decode_batches:
        assert os.path.exists(os.path.join(mdir, f"decode_b{b}.hlo.txt"))
