"""Fig. 5 — (a) generation-quality proxy and (b) per-layer input-x cosine
similarity of the three sharing policies, measured directly on the L2 model
(APIGen-like geometry, scaled).

Policies compared against lossless per-adapter prefix caching:
  - forkkv: agent B attends over agent A's bCache + its own rCache
  - full-reuse: agent B attends over agent A's *merged* cache (A's adapter)

Run: cd python && python -m experiments.fig5_similarity
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.configs import MODELS


def cosine(a, b, axis=-1):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    num = (a * b).sum(axis)
    den = np.sqrt((a * a).sum(axis) * (b * b).sum(axis)) + 1e-12
    return num / den


def main():
    cfg = dataclasses.replace(
        MODELS["llama3-8b-sim"], s_max=256, chunk=192, vocab=2048
    )
    params = M.init_params(cfg, 0)
    bank = M.init_bank(cfg, rank=16, seed=1)
    rng = np.random.default_rng(7)
    ctx_len, q_len = 160, 16
    n_cases = 8

    L = cfg.n_layers
    sims_fork = np.zeros(L)
    sims_full = np.zeros(L)
    agree_fork = agree_full = total = 0

    for case in range(n_cases):
        tokens = jnp.asarray(
            rng.integers(2, cfg.vocab, size=ctx_len + q_len), jnp.int32
        )
        zero = (
            jnp.zeros((L, cfg.s_max, cfg.n_kv_heads, cfg.head_dim)),
            jnp.zeros((L, cfg.s_max, cfg.n_kv_heads, cfg.head_dim)),
            jnp.zeros((L, cfg.s_max, cfg.rank_max)),
            jnp.zeros((L, cfg.s_max, cfg.rank_max)),
        )
        adapter_a, adapter_b = jnp.int32(1), jnp.int32(2 + case % 6)

        def prefill(adapter, caches, toks, cache_len):
            return M.forward_chunk(
                cfg, params, bank, toks, jnp.int32(cache_len), adapter,
                jnp.float32(1.0), *caches,
            )

        # agent A processes the shared context -> its bCache/merged cache
        out_a = prefill(adapter_a, zero, tokens[:ctx_len], 0)
        _, kb_a, vb_a, kr_a, vr_a, km_a, vm_a, _ = out_a

        def seed_caches(kb_c, vb_c, kr_c, vr_c):
            kb, vb, kr, vr = zero
            for l in range(L):
                kb = kb.at[l, :ctx_len].set(kb_c[l])
                vb = vb.at[l, :ctx_len].set(vb_c[l])
                if kr_c is not None:
                    kr = kr.at[l, :ctx_len].set(kr_c[l])
                    vr = vr.at[l, :ctx_len].set(vr_c[l])
            return kb, vb, kr, vr

        # reference: agent B recomputes the context itself (lossless)
        out_ref = prefill(adapter_b, zero, tokens, 0)
        x_ref, logits_ref = out_ref[7][:, ctx_len:], out_ref[0][ctx_len:]

        # forkkv: inherit A's bCache, compute own rCache over the context
        # (residual prefill, DESIGN.md §1), then answer the query
        fork_ctx = prefill(adapter_b, seed_caches(kb_a, vb_a, None, None),
                           tokens[:ctx_len], 0)
        caches_fork = seed_caches(kb_a, vb_a, fork_ctx[3], fork_ctx[4])
        out_fork = prefill(adapter_b, caches_fork, tokens[ctx_len:], ctx_len)
        x_fork, logits_fork = out_fork[7], out_fork[0]

        # full reuse: adopt A's merged cache wholesale (no B transformations)
        caches_full = seed_caches(km_a, vm_a, None, None)
        out_full = prefill(adapter_b, caches_full, tokens[ctx_len:], ctx_len)
        x_full, logits_full = out_full[7], out_full[0]

        for l in range(L):
            sims_fork[l] += cosine(x_fork[l], x_ref[l]).mean() / n_cases
            sims_full[l] += cosine(x_full[l], x_ref[l]).mean() / n_cases
        agree_fork += int(
            (np.argmax(logits_fork, -1) == np.argmax(logits_ref, -1)).sum()
        )
        agree_full += int(
            (np.argmax(logits_full, -1) == np.argmax(logits_ref, -1)).sum()
        )
        total += q_len

    print("# Fig. 5b: per-layer input-x cosine similarity vs prefix caching")
    print(f"{'layer':>6} {'forkkv':>10} {'full-reuse':>11}")
    for l in range(L):
        print(f"{l:>6} {sims_fork[l]:>10.4f} {sims_full[l]:>11.4f}")
    print("# paper: forkkv >= 0.994 at every layer; full reuse drops to ~0.924")
    print()
    print("# Fig. 5a: greedy next-token agreement with prefix caching (quality proxy)")
    print(f"forkkv     {100.0 * agree_fork / total:6.1f}%")
    print(f"full-reuse {100.0 * agree_full / total:6.1f}%")
    print("# paper: forkkv -1.60% F1 worst case; full reuse -21.0% on APIGen")


if __name__ == "__main__":
    main()
