"""Model + AOT geometry configs.

Three *sim* configs mirror the paper's three evaluation models
(Llama3-8B / Qwen2.5-7B / Qwen2.5-14B) at laptop scale: the architectural
family (GQA ratio, bias policy, depth/width ordering) is preserved, because
the systems behaviour under test depends on cache geometry — n = kv width,
r = LoRA rank, layer count — not on trained weights (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float
    qkv_bias: bool
    # --- AOT serving geometry ---
    s_max: int = 768          # padded KV-cache capacity per sequence
    chunk: int = 64           # prefill chunk length
    rank_max: int = 32        # padded LoRA rank (effective rank <= this)
    n_adapters: int = 16      # adapter-bank slots baked into the artifacts
    decode_batches: Tuple[int, ...] = (1, 2, 4, 8)

    @property
    def q_width(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_width(self) -> int:
        """n in the paper's Eq. 3: per-layer K (or V) width of the bCache."""
        return self.n_kv_heads * self.head_dim

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0
        assert self.head_dim % 2 == 0
        assert self.s_max % self.chunk == 0


MODELS = {
    # Llama3 family: GQA 2:1, no qkv bias.
    "llama3-8b-sim": ModelConfig(
        name="llama3-8b-sim",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=704, vocab=2048, rope_theta=10000.0, qkv_bias=False,
    ),
    # Qwen2.5 family: more aggressive GQA (4:1) and qkv bias.
    "qwen2.5-7b-sim": ModelConfig(
        name="qwen2.5-7b-sim",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=704, vocab=2048, rope_theta=10000.0, qkv_bias=True,
    ),
    # The "bigger" model of the eval: deeper + wider => more memory pressure.
    "qwen2.5-14b-sim": ModelConfig(
        name="qwen2.5-14b-sim",
        n_layers=6, d_model=384, n_heads=12, n_kv_heads=6, head_dim=32,
        d_ff=1024, vocab=2048, rope_theta=10000.0, qkv_bias=True,
    ),
}

DEFAULT_MODEL = "llama3-8b-sim"


def get(name: str) -> ModelConfig:
    cfg = MODELS[name]
    cfg.validate()
    return cfg
