"""AOT compile path: lower L2 entrypoints to HLO text + export weights.

Python runs ONCE here (`make artifacts`); the Rust coordinator then loads
`artifacts/<model>/*.hlo.txt` via the PJRT C API and never calls back into
Python on the request path.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs per model under --out-dir/<model>/:
  prefill.hlo.txt, decode_b{1,2,4,8}.hlo.txt   -- compiled by Rust at startup
  weights.bin                                   -- f32 LE, params then bank
  manifest.json                                 -- schema the Rust side replays
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import MODELS, get


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.int32 if dtype == "i32" else jnp.float32)


def lower_model(cfg, rank: int, seed: int, lora_seed: int, out_dir: str,
                verbose: bool = True) -> dict:
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)

    params = M.init_params(cfg, seed)
    bank = M.init_bank(cfg, rank=rank, seed=lora_seed)
    pspecs = M.param_specs(cfg)
    bspecs = M.bank_specs(cfg)

    # ---- weights.bin + offset table --------------------------------------
    offset = 0
    entries = {"params": [], "bank": []}
    with open(os.path.join(mdir, "weights.bin"), "wb") as f:
        for section, specs, tree in (
            ("params", pspecs, params),
            ("bank", bspecs, bank),
        ):
            for name, shape in specs:
                arr = np.asarray(tree[name], dtype=np.float32)
                assert arr.shape == tuple(shape), (name, arr.shape, shape)
                f.write(arr.tobytes())
                entries[section].append(
                    {"name": name, "shape": list(shape), "offset": offset}
                )
                offset += arr.size

    weight_args = [params[n] for n, _ in pspecs] + [bank[n] for n, _ in bspecs]
    weight_abstract = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in weight_args]

    # ---- lower entrypoints ------------------------------------------------
    artifacts = []
    runtime_inputs = {}
    outputs = {}

    def lower(kind: str, fn, batch: int, fname: str):
        rt = M.runtime_input_specs(cfg, kind, batch)
        args = weight_abstract + [_abstract(s, d) for _, s, d in rt]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(mdir, fname)
        with open(path, "w") as f:
            f.write(text)
        key = kind if kind == "prefill" else f"decode_b{batch}"
        runtime_inputs[key] = [[n, list(s), d] for n, s, d in rt]
        outputs[key] = [
            [n, list(s), d] for n, s, d in M.output_specs(cfg, kind, batch)
        ]
        artifacts.append({"kind": kind, "batch": batch, "file": fname})
        if verbose:
            print(f"  {cfg.name}/{fname}: {len(text) / 1e6:.1f} MB hlo text")

    lower("prefill", M.make_prefill_fn(cfg), 1, "prefill.hlo.txt")
    for b in cfg.decode_batches:
        lower("decode", M.make_decode_fn(cfg, b), b, f"decode_b{b}.hlo.txt")

    manifest = {
        "model": {
            "name": cfg.name,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "rope_theta": cfg.rope_theta,
            "qkv_bias": cfg.qkv_bias,
            "s_max": cfg.s_max,
            "chunk": cfg.chunk,
            "rank_max": cfg.rank_max,
            "n_adapters": cfg.n_adapters,
            "decode_batches": list(cfg.decode_batches),
            "rank_effective": rank,
            "seed": seed,
            "lora_seed": lora_seed,
        },
        "params": entries["params"],
        "bank": entries["bank"],
        "artifacts": artifacts,
        "runtime_inputs": runtime_inputs,
        "outputs": outputs,
    }
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # ---- golden outputs: the cross-language numerics contract -------------
    # Rust integration tests replay exactly this call through the PJRT
    # artifacts and must match within tolerance (tests/runtime_golden.rs).
    golden = make_golden(cfg, params, bank)
    with open(os.path.join(mdir, "golden.json"), "w") as f:
        json.dump(golden, f)
    return manifest


def make_golden(cfg, params, bank) -> dict:
    """Run one prefill chunk + one decode step in pure python (jnp) and
    record probe values for the Rust runtime to verify against."""
    L, S, KH, HD, R = (
        cfg.n_layers, cfg.s_max, cfg.n_kv_heads, cfg.head_dim, cfg.rank_max,
    )
    C = cfg.chunk
    kb = jnp.zeros((L, S, KH, HD)); vb = jnp.zeros((L, S, KH, HD))
    kr = jnp.zeros((L, S, R)); vr = jnp.zeros((L, S, R))
    tokens = (jnp.arange(C, dtype=jnp.int32) * 7 + 1) % cfg.vocab
    adapter_id, on = jnp.int32(2), jnp.float32(1.0)

    out = M.forward_chunk(
        cfg, params, bank, tokens, jnp.int32(0), adapter_id, on, kb, vb, kr, vr
    )
    logits, kbn, vbn, krn, vrn, kmn, vmn, xs = out
    n_keep = max(1, 3 * C // 4)  # pretend only these chunk tokens are "real"

    for l in range(L):
        kb = kb.at[l, :n_keep].set(kbn[l, :n_keep])
        vb = vb.at[l, :n_keep].set(vbn[l, :n_keep])
        kr = kr.at[l, :n_keep].set(krn[l, :n_keep])
        vr = vr.at[l, :n_keep].set(vrn[l, :n_keep])
    tok_d = jnp.array([5], jnp.int32)
    dec = M.forward_chunk(
        cfg, params, bank, tok_d, jnp.int32(n_keep), adapter_id, on,
        kb, vb, kr, vr,
    )
    probe = lambda a: [float(x) for x in np.asarray(a, np.float32).reshape(-1)[:8]]
    return {
        "tokens": [int(t) for t in np.asarray(tokens)],
        "adapter_id": 2,
        "n_keep": n_keep,
        "decode_token": 5,
        "prefill_logits_last8": probe(logits[C - 1]),
        "prefill_kb_l0": probe(kbn[0]),
        "prefill_kr_l0": probe(krn[0]),
        "prefill_km_l0": probe(kmn[0]),
        "decode_logits8": probe(dec[0][0]),
        "decode_argmax": int(jnp.argmax(dec[0][0])),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="llama3-8b-sim",
                    help="comma-separated, or 'all'")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lora-seed", type=int, default=1)
    args = ap.parse_args()

    names = list(MODELS) if args.models == "all" else args.models.split(",")
    for name in names:
        cfg = get(name)
        print(f"lowering {name} ...", flush=True)
        lower_model(cfg, args.rank, args.seed, args.lora_seed, args.out_dir)
    print("done")


if __name__ == "__main__":
    main()
